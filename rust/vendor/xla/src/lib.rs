//! Offline PJRT shim, API-compatible with the subset of the `xla` crate
//! (v0.1.6) that adaptlib's runtime uses.
//!
//! The real deployment links the PJRT CPU client and executes the
//! jax-lowered HLO artifacts natively.  This vendor crate keeps the repo
//! self-contained: it parses the *entry computation* of the HLO text the
//! AOT pipeline emits (`python/compile/model.py::to_hlo_text`) — five
//! parameters `(A, B, C, alpha[1], beta[1])`, optional operand
//! transposes, one tupled `f32[m,n]` result — and executes the BLAS GEMM
//! semantics `out = alpha * op(A) @ op(B) + beta * C` on the host.
//!
//! Two execution surfaces:
//!
//! * [`PjRtLoadedExecutable::execute`] — the xla-rs-shaped literal path
//!   (allocates per call, mirroring real host->device transfers);
//! * [`PjRtLoadedExecutable::execute_into`] — the shim-only extension the
//!   pooled runtime hot path uses: borrowed operands in, result written
//!   into a caller-owned buffer, zero heap allocations at steady state.
//!
//! Both drive the same kernel loop, so their outputs are bit-identical.

use std::borrow::Borrow;
use std::sync::Mutex;

/// Error type; adaptlib only formats it with `{:?}`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn err<T>(msg: impl Into<String>) -> Result<T, XlaError> {
    Err(XlaError(msg.into()))
}

// --------------------------------------------------------------- literals

/// A dense f32 literal (or a tuple of them).
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
    /// Non-empty => this literal is a tuple of the elements.
    elements: Vec<Literal>,
}

/// Element types `Literal::to_vec` can produce (f32 only in the shim).
pub trait NativeType: Copy {
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

impl Literal {
    /// Rank-1 literal copying the slice (mirrors a host->device transfer).
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
            elements: Vec::new(),
        }
    }

    /// Tuple literal.
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), data: Vec::new(), elements }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal, XlaError> {
        let count: i64 = dims.iter().product();
        if !self.elements.is_empty() {
            return err("cannot reshape a tuple literal");
        }
        if count < 0 || count as usize != self.data.len() {
            return err(format!(
                "reshape {:?} -> {:?}: element count mismatch ({})",
                self.dims,
                dims,
                self.data.len()
            ));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data, elements: Vec::new() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Unwrap a 1-element tuple literal.
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        let mut elements = self.elements;
        if elements.len() != 1 {
            return err(format!("expected 1-tuple, got {} elements", elements.len()));
        }
        Ok(elements.remove(0))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        if !self.elements.is_empty() {
            return err("cannot convert a tuple literal to a vec");
        }
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }
}

/// A borrowed operand for the zero-allocation execution path.
#[derive(Debug, Clone, Copy)]
pub struct RawOperand<'a> {
    pub data: &'a [f32],
    pub dims: &'a [i64],
}

// ------------------------------------------------------------ HLO parsing

/// Raw HLO-module text, as read from an artifact file.
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("reading {path}: {e}")))?;
        if !text.contains("HloModule") {
            return err(format!("{path} is not HLO text"));
        }
        Ok(HloModuleProto { text })
    }

    pub fn from_text(text: impl Into<String>) -> HloModuleProto {
        HloModuleProto { text: text.into() }
    }
}

/// An unverified computation; semantic extraction happens at compile.
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }
}

/// GEMM semantics extracted from the entry computation.
#[derive(Debug, Clone, PartialEq)]
struct GemmSemantics {
    /// Dims of the five entry parameters, by parameter index.
    param_dims: [Vec<usize>; 5],
    trans_a: bool,
    trans_b: bool,
    m: usize,
    n: usize,
    k: usize,
}

/// Parse `f32[R,C]{...}` (or `f32[N]{0}`) immediately before `parameter(i)`.
fn parse_shape(ty: &str) -> Option<Vec<usize>> {
    let rest = ty.strip_prefix("f32[")?;
    let close = rest.find(']')?;
    let inner = &rest[..close];
    if inner.is_empty() {
        return Some(Vec::new());
    }
    inner
        .split(',')
        .map(|d| d.trim().parse::<usize>().ok())
        .collect::<Option<Vec<usize>>>()
}

fn parse_entry(text: &str) -> Result<GemmSemantics, XlaError> {
    // Locate the ENTRY block (jax prints sub-computations first).
    let start = match text.find("\nENTRY ") {
        Some(i) => i + 1,
        None => {
            if text.starts_with("ENTRY ") {
                0
            } else {
                return err("no ENTRY computation in HLO text");
            }
        }
    };
    let body = &text[start..];
    let open = body.find('{').ok_or_else(|| XlaError("ENTRY has no body".into()))?;
    let close = body.find("\n}").ok_or_else(|| XlaError("unterminated ENTRY body".into()))?;
    if close < open {
        return err("malformed ENTRY body");
    }
    let entry = &body[open + 1..close];

    // Pass 1: parameters.  Lines look like
    //   `  Arg_0.1 = f32[64,64]{1,0} parameter(0)`
    let mut param_dims: [Option<Vec<usize>>; 5] = Default::default();
    let mut param_names: Vec<(String, usize)> = Vec::new();
    let mut saw_root = false;
    for line in entry.lines() {
        let line = line.trim();
        if line.starts_with("ROOT ") {
            saw_root = true;
        }
        let Some((lhs, rhs)) = line.split_once(" = ") else { continue };
        let Some(paren) = rhs.find("parameter(") else { continue };
        let idx_text = &rhs[paren + "parameter(".len()..];
        let Some(close_paren) = idx_text.find(')') else { continue };
        let Ok(idx) = idx_text[..close_paren].parse::<usize>() else { continue };
        if idx >= 5 {
            return err(format!("unexpected parameter index {idx} in entry"));
        }
        let dims = parse_shape(rhs.trim_start())
            .ok_or_else(|| XlaError(format!("unparseable parameter type in '{line}'")))?;
        if param_dims[idx].is_some() {
            return err(format!("duplicate parameter({idx}) in entry"));
        }
        param_dims[idx] = Some(dims);
        param_names.push((lhs.trim_start_matches("ROOT ").trim().to_string(), idx));
    }
    if !saw_root {
        return err("entry computation has no ROOT instruction");
    }
    let param_dims: [Vec<usize>; 5] = {
        let mut out: [Vec<usize>; 5] = Default::default();
        for (i, d) in param_dims.into_iter().enumerate() {
            out[i] = d.ok_or_else(|| {
                XlaError(format!("entry computation lacks parameter({i})"))
            })?;
        }
        out
    };
    if param_dims[0].len() != 2 || param_dims[1].len() != 2 || param_dims[2].len() != 2 {
        return err("operand parameters must be rank 2");
    }
    if param_dims[3].as_slice() != [1] || param_dims[4].as_slice() != [1] {
        return err("alpha/beta parameters must be f32[1]");
    }

    // Pass 2: operand transposes.  jax lowers `a.T` to
    //   `  transpose.9 = ... transpose(Arg_0.1), dimensions={1,0}`
    let mut trans = [false; 2];
    for line in entry.lines() {
        let Some(pos) = line.find(" transpose(") else { continue };
        if !line.contains("dimensions={1,0}") {
            continue;
        }
        let args = &line[pos + " transpose(".len()..];
        let Some(close_paren) = args.find(')') else { continue };
        let operand = args[..close_paren].trim();
        for (name, idx) in &param_names {
            if operand == name && *idx < 2 {
                trans[*idx] = true;
            }
        }
    }
    let (trans_a, trans_b) = (trans[0], trans[1]);

    let (m, n) = (param_dims[2][0], param_dims[2][1]);
    let k = if trans_a { param_dims[0][0] } else { param_dims[0][1] };

    // Cross-check operand shapes against (m, n, k).
    let expect_a = if trans_a { vec![k, m] } else { vec![m, k] };
    let expect_b = if trans_b { vec![n, k] } else { vec![k, n] };
    if param_dims[0] != expect_a || param_dims[1] != expect_b {
        return err(format!(
            "inconsistent GEMM operand shapes: a={:?} b={:?} c={:?} (trans_a={trans_a}, trans_b={trans_b})",
            param_dims[0], param_dims[1], param_dims[2]
        ));
    }
    Ok(GemmSemantics { param_dims, trans_a, trans_b, m, n, k })
}

// -------------------------------------------------------------- execution

/// A result buffer handle.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Ok(self.lit.clone())
    }
}

/// A compiled executable: validated GEMM semantics + a reusable f64
/// accumulator row so the steady-state pooled path never allocates.
pub struct PjRtLoadedExecutable {
    sem: GemmSemantics,
    acc: Mutex<Vec<f64>>,
}

impl PjRtLoadedExecutable {
    /// Allocation-free on the success path (the pooled runtime hot path
    /// calls this every request).
    fn check_operand(&self, idx: usize, data_len: usize, dims: &[i64]) -> Result<(), XlaError> {
        let expect = &self.sem.param_dims[idx];
        let shape_ok = dims.len() == expect.len()
            && dims.iter().zip(expect).all(|(&d, &e)| d >= 0 && d as usize == e);
        let count: usize = expect.iter().product();
        if !shape_ok || data_len != count {
            return err(format!(
                "operand {idx}: expected f32{expect:?}, got f32{dims:?} ({data_len} elements)"
            ));
        }
        Ok(())
    }

    /// The shared kernel loop: `out = alpha * op(A) @ op(B) + beta * C`,
    /// writing into `out` (cleared + resized, capacity reused).
    fn run_gemm(
        &self,
        a: &[f32],
        b: &[f32],
        c: &[f32],
        alpha: f32,
        beta: f32,
        out: &mut Vec<f32>,
    ) {
        let GemmSemantics { trans_a, trans_b, m, n, k, .. } = self.sem;
        out.clear();
        out.resize(m * n, 0.0);
        let mut acc = self.acc.lock().unwrap();
        acc.clear();
        acc.resize(n, 0.0);
        for i in 0..m {
            acc.iter_mut().for_each(|x| *x = 0.0);
            for l in 0..k {
                let av = if trans_a { a[l * m + i] } else { a[i * k + l] } as f64;
                if trans_b {
                    for j in 0..n {
                        acc[j] += av * b[j * k + l] as f64;
                    }
                } else {
                    let brow = &b[l * n..(l + 1) * n];
                    for (j, &bv) in brow.iter().enumerate() {
                        acc[j] += av * bv as f64;
                    }
                }
            }
            let crow = &c[i * n..(i + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for ((o, &s), &cv) in orow.iter_mut().zip(acc.iter()).zip(crow) {
                *o = alpha * s as f32 + beta * cv;
            }
        }
    }

    /// xla-rs-shaped execution: literals in, buffers out (allocating).
    pub fn execute<T: Borrow<Literal>>(
        &self,
        args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        if args.len() != 5 {
            return err(format!("expected 5 operands, got {}", args.len()));
        }
        for (i, arg) in args.iter().enumerate() {
            let lit = arg.borrow();
            self.check_operand(i, lit.data.len(), &lit.dims)?;
        }
        let (a, b, c) = (args[0].borrow(), args[1].borrow(), args[2].borrow());
        let alpha = args[3].borrow().data[0];
        let beta = args[4].borrow().data[0];
        let mut out = Vec::new();
        self.run_gemm(&a.data, &b.data, &c.data, alpha, beta, &mut out);
        let (m, n) = (self.sem.m, self.sem.n);
        let lit = Literal {
            dims: vec![m as i64, n as i64],
            data: out,
            elements: Vec::new(),
        };
        Ok(vec![vec![PjRtBuffer { lit: Literal::tuple(vec![lit]) }]])
    }

    /// Shim-only zero-allocation execution: borrowed operands, result
    /// written into `out` (the single tupled f32 output, row-major).
    /// At steady state (same artifact, same shapes) no heap allocation
    /// occurs — `out` and the internal accumulator reuse their capacity.
    pub fn execute_into(
        &self,
        operands: &[RawOperand<'_>],
        out: &mut Vec<f32>,
    ) -> Result<(), XlaError> {
        if operands.len() != 5 {
            return err(format!("expected 5 operands, got {}", operands.len()));
        }
        for (i, op) in operands.iter().enumerate() {
            self.check_operand(i, op.data.len(), op.dims)?;
        }
        let alpha = operands[3].data[0];
        let beta = operands[4].data[0];
        self.run_gemm(
            operands[0].data,
            operands[1].data,
            operands[2].data,
            alpha,
            beta,
            out,
        );
        Ok(())
    }
}

// ----------------------------------------------------------------- client

/// The CPU PJRT client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        let sem = parse_entry(&comp.text)?;
        Ok(PjRtLoadedExecutable { sem, acc: Mutex::new(Vec::new()) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-written entry mirroring `to_hlo_text` output for a 2x3x4 GEMM
    /// (m=2, n=3, k=4), no transposes.
    const PLAIN: &str = "HloModule jit_fn, entry_computation_layout={(f32[2,4]{1,0}, f32[4,3]{1,0}, f32[2,3]{1,0}, f32[1]{0}, f32[1]{0})->(f32[2,3]{1,0})}

helper.1 {
  Arg_0.2 = f32[2,3]{1,0} parameter(0)
  ROOT neg.3 = f32[2,3]{1,0} negate(Arg_0.2)
}

ENTRY main.10 {
  Arg_0.1 = f32[2,4]{1,0} parameter(0)
  Arg_1.2 = f32[4,3]{1,0} parameter(1)
  Arg_2.3 = f32[2,3]{1,0} parameter(2)
  Arg_3.4 = f32[1]{0} parameter(3)
  Arg_4.5 = f32[1]{0} parameter(4)
  dot.6 = f32[2,3]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT tuple.9 = (f32[2,3]{1,0}) tuple(dot.6)
}
";

    /// Transposed-A variant: A arrives as f32[4,2] (k x m).
    const TRANS_A: &str = "HloModule jit_fn

ENTRY main.10 {
  Arg_0.1 = f32[4,2]{1,0} parameter(0)
  transpose.6 = f32[2,4]{0,1} transpose(Arg_0.1), dimensions={1,0}
  Arg_1.2 = f32[4,3]{1,0} parameter(1)
  Arg_2.3 = f32[2,3]{1,0} parameter(2)
  Arg_3.4 = f32[1]{0} parameter(3)
  Arg_4.5 = f32[1]{0} parameter(4)
  dot.7 = f32[2,3]{1,0} dot(transpose.6, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT tuple.9 = (f32[2,3]{1,0}) tuple(dot.7)
}
";

    fn compile(text: &str) -> PjRtLoadedExecutable {
        PjRtClient::cpu()
            .unwrap()
            .compile(&XlaComputation::from_proto(&HloModuleProto::from_text(text)))
            .unwrap()
    }

    #[allow(clippy::too_many_arguments)]
    fn lits(
        a: &[f32],
        ad: [i64; 2],
        b: &[f32],
        bd: [i64; 2],
        c: &[f32],
        cd: [i64; 2],
        alpha: f32,
        beta: f32,
    ) -> Vec<Literal> {
        vec![
            Literal::vec1(a).reshape(&ad).unwrap(),
            Literal::vec1(b).reshape(&bd).unwrap(),
            Literal::vec1(c).reshape(&cd).unwrap(),
            Literal::vec1(&[alpha]),
            Literal::vec1(&[beta]),
        ]
    }

    #[test]
    fn parses_plain_gemm() {
        let exe = compile(PLAIN);
        assert_eq!((exe.sem.m, exe.sem.n, exe.sem.k), (2, 3, 4));
        assert!(!exe.sem.trans_a && !exe.sem.trans_b);
    }

    #[test]
    fn executes_gemm_with_alpha_beta() {
        let exe = compile(PLAIN);
        // A = row-major 2x4 of ones; B = 4x3 of twos; C = 2x3 of threes.
        let a = [1.0f32; 8];
        let b = [2.0f32; 12];
        let c = [3.0f32; 6];
        let bufs = exe
            .execute::<Literal>(&lits(&a, [2, 4], &b, [4, 3], &c, [2, 3], 0.5, 2.0))
            .unwrap();
        let out = bufs[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<f32>()
            .unwrap();
        // 0.5 * (1*2*4) + 2.0 * 3 = 4 + 6 = 10 everywhere.
        assert_eq!(out, vec![10.0; 6]);
    }

    #[test]
    fn transpose_a_detected_and_applied() {
        let exe = compile(TRANS_A);
        assert!(exe.sem.trans_a && !exe.sem.trans_b);
        assert_eq!((exe.sem.m, exe.sem.n, exe.sem.k), (2, 3, 4));
        // A^T stored as 4x2: column i of storage is row i of op(A).
        // op(A) = [[1,2,3,4],[5,6,7,8]] => stored a[l*2 + i].
        let a = [1.0, 5.0, 2.0, 6.0, 3.0, 7.0, 4.0, 8.0];
        let b = [1.0f32; 12]; // 4x3 ones
        let c = [0.0f32; 6];
        let bufs = exe
            .execute::<Literal>(&lits(&a, [4, 2], &b, [4, 3], &c, [2, 3], 1.0, 0.0))
            .unwrap();
        let out = bufs[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<f32>()
            .unwrap();
        assert_eq!(out, vec![10.0, 10.0, 10.0, 26.0, 26.0, 26.0]);
    }

    #[test]
    fn execute_into_matches_execute_bit_identically() {
        let exe = compile(PLAIN);
        let a: Vec<f32> = (0..8).map(|i| i as f32 * 0.37 - 1.0).collect();
        let b: Vec<f32> = (0..12).map(|i| i as f32 * -0.21 + 0.5).collect();
        let c: Vec<f32> = (0..6).map(|i| i as f32 * 0.11).collect();
        let bufs = exe
            .execute::<Literal>(&lits(&a, [2, 4], &b, [4, 3], &c, [2, 3], 1.25, -0.75))
            .unwrap();
        let via_literals = bufs[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<f32>()
            .unwrap();
        let (ad, bd, cd, sd) = ([2i64, 4], [4i64, 3], [2i64, 3], [1i64]);
        let alpha = [1.25f32];
        let beta = [-0.75f32];
        let ops = [
            RawOperand { data: &a, dims: &ad },
            RawOperand { data: &b, dims: &bd },
            RawOperand { data: &c, dims: &cd },
            RawOperand { data: &alpha, dims: &sd },
            RawOperand { data: &beta, dims: &sd },
        ];
        let mut out = Vec::new();
        exe.execute_into(&ops, &mut out).unwrap();
        assert_eq!(out, via_literals);
        // Steady state: capacity reused, output identical.
        let cap = out.capacity();
        exe.execute_into(&ops, &mut out).unwrap();
        assert_eq!(out, via_literals);
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn rejects_wrong_operand_shapes() {
        let exe = compile(PLAIN);
        let a = [0.0f32; 8];
        let bad = lits(&a, [2, 4], &a, [2, 4], &a[..6], [2, 3], 1.0, 0.0);
        assert!(exe.execute::<Literal>(&bad).is_err());
        assert!(exe.execute::<Literal>(&bad[..3]).is_err());
    }

    #[test]
    fn rejects_corrupt_hlo() {
        let client = PjRtClient::cpu().unwrap();
        for bad in [
            "",
            "HloModule x\n\nENTRY main {\n  Arg_0.1 = f32[2,4]{1,0} parameter(0)\n", // truncated
            &PLAIN[..PLAIN.len() / 3],
        ] {
            let comp = XlaComputation::from_proto(&HloModuleProto::from_text(bad));
            assert!(client.compile(&comp).is_err(), "should reject: {bad:.40}");
        }
    }

    #[test]
    fn literal_reshape_checks_counts() {
        assert!(Literal::vec1(&[1.0, 2.0]).reshape(&[3, 1]).is_err());
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
    }

    #[test]
    fn from_text_file_errors_on_missing() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
