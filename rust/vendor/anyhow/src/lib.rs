//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements exactly the API surface adaptlib uses: [`Error`] with a
//! context chain, [`Result`], the [`Context`] extension trait for
//! `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros.  Display follows anyhow's convention: `{e}` prints the
//! outermost message, `{e:#}` prints the whole chain separated by `: `.

use std::fmt;

/// An error with an optional chain of underlying causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Construct from a displayable message (the `anyhow!` entry point).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string(), cause: Some(Box::new(self)) }
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.cause.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        msgs.into_iter()
    }

    /// The outermost message (anyhow's `root_cause` analogue is the last).
    pub fn root_cause_msg(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, m) in self.chain().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(m)?;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            f.write_str("\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that keeps the blanket `From` below coherent, exactly like anyhow.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error { msg, cause: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension trait for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e:#}").contains("outer"));
        assert!(format!("{e:#}").contains("gone"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(1).context("x").unwrap(), 1);
    }

    #[test]
    fn macros_build_errors() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(format!("{}", inner(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", inner(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::from(io_err()).context("ctx");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("ctx"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("gone"));
    }
}
