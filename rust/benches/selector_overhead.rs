//! `cargo bench --bench selector_overhead` — the §5.4 microbenchmark:
//! cost of the generated if-then-else selector (flattened decision tree)
//! vs the GEMM it fronts.  The paper reports <2% on small matrices
//! (deepest leaf) and <1% on average.

use adaptlib::codegen::FlatTree;
use adaptlib::dataset::DatasetKind;
use adaptlib::device::DeviceId;
use adaptlib::experiments::{microbench, Context};
use adaptlib::harness::{black_box, Suite};

fn main() {
    let mut suite = Suite::from_args();
    let mut ctx = Context::new();

    // The paper's model: hMax-L1 on go2 @ P100 (~1200 leaves, depth ~19).
    let sweep = ctx.sweep(DeviceId::NvidiaP100, DatasetKind::Go2);
    let best = sweep.best_model();
    let flat = FlatTree::from_tree(&best.tree);
    println!(
        "model {} | {} leaves | depth {}",
        best.scores.model,
        best.tree.n_leaves(),
        best.tree.depth()
    );

    suite.section("selector traversal");
    // Deepest-leaf path: small matrices (the paper's worst case).
    suite.bench("flat:predict:small(64,64,64)", || {
        black_box(flat.predict(64, 64, 64))
    });
    suite.bench("flat:predict:large(3840^3)", || {
        black_box(flat.predict(3840, 3840, 3840))
    });
    // Pointer-tree traversal for comparison (the naive representation the
    // serving path no longer uses — ModelPolicy always executes the
    // flattened chain).
    let tree = &best.tree;
    suite.bench("tree:predict:small(64,64,64)", || {
        black_box(tree.predict(adaptlib::config::Triple::new(64, 64, 64)))
    });
    suite.bench("tree:predict:large(3840^3)", || {
        black_box(tree.predict(adaptlib::config::Triple::new(3840, 3840, 3840)))
    });
    // Mixed workload (test set), both representations.
    let triples: Vec<(u32, u32, u32)> = sweep
        .test_idx
        .iter()
        .map(|&i| {
            let t = sweep.labeled.entries[i].0;
            (t.m, t.n, t.k)
        })
        .collect();
    let mut i = 0usize;
    suite.bench("flat:predict:test-set-mix", || {
        let (m, n, k) = triples[i % triples.len()];
        i += 1;
        black_box(flat.predict(m, n, k))
    });
    let mut j = 0usize;
    suite.bench("tree:predict:test-set-mix", || {
        let (m, n, k) = triples[j % triples.len()];
        j += 1;
        black_box(tree.predict(adaptlib::config::Triple::new(m, n, k)))
    });

    suite.section("overhead vs kernel time (paper §5.4 table)");
    let r = microbench::selector_overhead(&mut ctx);
    println!("{}", r.ascii);
    r.save(std::path::Path::new("results")).unwrap();
}
