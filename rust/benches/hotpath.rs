//! `cargo bench --bench hotpath [-- --quick]` — the on-line request path,
//! measured on the real PJRT runtime: pad/unpad helpers, allocating vs
//! pooled (zero-allocation) GEMM execution, heap-allocation counts on the
//! steady-state indirect path, and aggregate server throughput at 1/2/4
//! dispatcher shards over the mixed test-set workload.
//!
//! Emits machine-readable `BENCH_hotpath.json` next to the working
//! directory so subsequent PRs have a perf trajectory to regress against.

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use adaptlib::config::{KernelConfig, SimdTier};
use adaptlib::coordinator::{
    DefaultPolicy, GemmRequest, GemmServer, PolicyHandle, ServerConfig,
};
use adaptlib::device::microkernel;
use adaptlib::engine::{ExecutionEngine, RuntimeEngine};
use adaptlib::experiments::e2e;
use adaptlib::harness::{black_box, BenchConfig, Suite};
use adaptlib::net::wire;
use adaptlib::runtime::{
    pad, ArtifactId, ArtifactKind, BatchScratch, GemmInput, GemmRuntime,
    PjrtBackend, ScratchBuffers,
};
use adaptlib::util::json::Json;
use adaptlib::util::prng::Rng;

// ----------------------------------------------------- counting allocator

/// Global allocator wrapper counting every allocation — the instrument
/// behind the "zero heap allocations at steady state" acceptance check.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to `System` — every allocator contract
// (layout validity, pointer provenance) is forwarded verbatim; the
// counter bump has no effect on the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: delegates to `System.alloc` under the caller's layout.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // RELAXED: statistics counter; read only between timed phases.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: delegates to `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        // RELAXED: statistics counter; read only between timed phases.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: delegates to `System.realloc` with the caller's
    // (ptr, layout, new_size) triple unchanged.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // RELAXED: statistics counter; read only between timed phases.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: delegates to `System.dealloc` with the caller's pair.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Total allocations across `iters` steady-state calls of `f`.  The raw
/// delta (not a truncated mean) so even one allocation over the whole run
/// is visible to the zero-allocation gate.
fn allocs_total(iters: u64, mut f: impl FnMut()) -> u64 {
    for _ in 0..5 {
        f(); // warm: let every pool reach its steady-state capacity
    }
    // RELAXED: single-threaded bench; the delta only needs program
    // order, not cross-thread visibility.
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..iters {
        f();
    }
    // RELAXED: same single-threaded delta read as above.
    ALLOCS.load(Ordering::Relaxed) - before
}

fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.f32() - 0.5).collect()
}

/// Aggregate throughput of the sharded server over a fixed mixed-shape
/// request stream (the e2e test-set workload).
fn shard_throughput(dir: &Path, shards: usize, n_requests: usize) -> (f64, f64) {
    let backend = PjrtBackend::open(dir).expect("artifacts");
    let policy = DefaultPolicy::from_roster(&backend.roster_configs())
        .expect("roster has both kernel kinds");
    drop(backend);
    let server = GemmServer::start(dir, Box::new(policy), ServerConfig::with_shards(shards))
        .expect("server");
    let handle = server.handle();

    // Warm every shard's compile cache: each distinct triple is sent once
    // per shard (round-robin routing spreads consecutive submissions).
    let mut warm = Vec::new();
    for t in e2e::workload_triples() {
        for _ in 0..shards {
            let (m, n, k) = (t.m as usize, t.n as usize, t.k as usize);
            warm.push(GemmRequest {
                m,
                n,
                k,
                a: vec![0.5; m * k],
                b: vec![0.5; k * n],
                c: vec![0.0; m * n],
                alpha: 1.0,
                beta: 0.0,
            });
        }
    }
    let pending: Vec<_> = warm.into_iter().map(|r| handle.submit(r)).collect();
    for rx in pending {
        let _ = rx.recv();
    }

    let requests = e2e::request_stream(n_requests, 0xBEEF);
    let total_flops: f64 = requests.iter().map(|r| r.triple().flops()).sum();
    let t0 = Instant::now();
    let pending: Vec<_> = requests.into_iter().map(|r| handle.submit(r)).collect();
    for rx in pending {
        rx.recv().expect("response").out.expect("request served");
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(handle);
    let _ = server.shutdown();
    (n_requests as f64 / wall, total_flops / wall / 1e9)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("ADAPTLIB_BENCH_QUICK").is_ok();
    let mut suite = if quick {
        Suite::with_config(BenchConfig::quick())
    } else {
        Suite::from_args()
    };
    let mut rng = Rng::new(1);
    let mut extra: Vec<(&str, Json)> = Vec::new();

    suite.section("helper (pad/unpad) cost — the O(n^2) indirect tax");
    let src = rand_vec(&mut rng, 200 * 200);
    suite.bench("pad:200x200->256x256", || {
        black_box(pad::pad(&src, 200, 200, 256, 256))
    });
    let mut pad_buf = Vec::new();
    suite.bench("pad_into:200x200->256x256", || {
        pad::pad_into(&src, 200, 200, 256, 256, &mut pad_buf);
        black_box(pad_buf[0])
    });
    let padded = pad::pad(&src, 200, 200, 256, 256);
    suite.bench("unpad:256x256->200x200", || {
        black_box(pad::unpad(&padded, 256, 200, 200))
    });
    let mut out = vec![0f32; 200 * 200];
    suite.bench("unpad_into:256x256->200x200", || {
        pad::unpad_into(&padded, 256, 200, 200, &mut out);
        black_box(out[0])
    });

    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        bench_pjrt(&mut suite, artifacts, quick, &mut extra, &mut rng);
    } else {
        eprintln!("skipping PJRT sections: run `make artifacts` first");
    }

    // Runtime capability context, top-level so bench-compare can explain
    // a simd/packed floor miss on a limited runner (scalar-only hardware,
    // `ADAPTLIB_SIMD` clamp, `ADAPTLIB_PACK=off` leg) without guessing.
    extra.push(("simd_tier", Json::str(microkernel::detected_tier().name())));
    extra.push(("pack_enabled", Json::Bool(microkernel::pack_enabled())));

    write_json(&suite, &extra, quick);
}

fn bench_pjrt(
    suite: &mut Suite,
    artifacts: &Path,
    quick: bool,
    extra: &mut Vec<(&'static str, Json)>,
    rng: &mut Rng,
) {
    suite.section("PJRT execution (real kernels)");
    let mut rt = GemmRuntime::open(artifacts).expect("artifacts");
    let is_direct_128 = |k: &ArtifactKind| {
        matches!(
            k,
            ArtifactKind::Direct { m: 128, n: 128, k: 128, trans_a: false, trans_b: false }
        )
    };
    let direct = rt
        .manifest
        .artifacts
        .iter()
        .find(|a| is_direct_128(&a.kind))
        .expect("128^3 direct artifact")
        .clone();
    let indirect = rt
        .manifest
        .artifacts
        .iter()
        .find(|a| matches!(a.kind, ArtifactKind::Indirect { mb: 128, nb: 128, kb: 128 }))
        .expect("128^3 bucket")
        .clone();
    let direct_id = rt.manifest.id_of(&direct.name).unwrap();
    let indirect_id = rt.manifest.id_of(&indirect.name).unwrap();
    let (m, n, k) = (128usize, 128usize, 128usize);
    let (a, b, c) = (
        rand_vec(rng, m * k),
        rand_vec(rng, k * n),
        rand_vec(rng, m * n),
    );
    let input = GemmInput { m, n, k, a: &a, b: &b, c: &c, alpha: 1.0, beta: 0.0 };
    rt.gemm(&direct.name, &input).unwrap(); // compile outside timing
    rt.gemm(&indirect.name, &input).unwrap();
    suite.bench("gemm:direct:128^3", || {
        black_box(rt.gemm(&direct.name, &input).unwrap().out[0])
    });
    suite.bench("gemm:indirect:128^3(no-pad-needed)", || {
        black_box(rt.gemm(&indirect.name, &input).unwrap().out[0])
    });
    // In-bucket (pays padding).
    let (m2, n2, k2) = (100usize, 100usize, 100usize);
    let (a2, b2, c2) = (
        rand_vec(rng, m2 * k2),
        rand_vec(rng, k2 * n2),
        rand_vec(rng, m2 * n2),
    );
    let input2 = GemmInput {
        m: m2, n: n2, k: k2, a: &a2, b: &b2, c: &c2, alpha: 1.0, beta: 0.0,
    };
    suite.bench("gemm:indirect:100^3(padded-into-128)", || {
        black_box(rt.gemm(&indirect.name, &input2).unwrap().out[0])
    });

    suite.section("pooled (zero-allocation) path");
    let mut scratch = ScratchBuffers::new();
    suite.bench("gemm_pooled:direct:128^3", || {
        rt.gemm_pooled(direct_id, &input, &mut scratch).unwrap();
        black_box(scratch.out[0])
    });
    suite.bench("gemm_pooled:indirect:100^3(padded-into-128)", || {
        rt.gemm_pooled(indirect_id, &input2, &mut scratch).unwrap();
        black_box(scratch.out[0])
    });

    // Heap allocations per steady-state indirect request: the allocating
    // literal path pays per-call Vecs + literal copies; the pooled path
    // must pay exactly zero.
    let iters = if quick { 20 } else { 200 };
    let alloc_allocating = allocs_total(iters, || {
        black_box(rt.gemm(&indirect.name, &input2).unwrap().out[0]);
    });
    let alloc_pooled = allocs_total(iters, || {
        rt.gemm_pooled(indirect_id, &input2, &mut scratch).unwrap();
        black_box(scratch.out[0]);
    });
    println!(
        "allocs/request indirect 100^3 over {iters} requests: \
         allocating path {:.1}, pooled path {:.1}",
        alloc_allocating as f64 / iters as f64,
        alloc_pooled as f64 / iters as f64,
    );
    assert_eq!(
        alloc_pooled, 0,
        "pooled indirect path must not allocate at steady state \
         ({alloc_pooled} allocations over {iters} requests)"
    );

    // The adaptation loop puts a PolicyHandle in front of every select:
    // refresh (epoch check) + select + id resolution + pooled execute
    // must still be allocation-free at steady state, or the hot-swap
    // machinery would tax every request.  The roster configs come from
    // the already-open runtime's manifest (no second artifact load).
    let mut roster: Vec<KernelConfig> =
        rt.manifest.artifacts.iter().map(|a| a.config).collect();
    roster.sort_by_key(|c| c.name());
    roster.dedup();
    let policy =
        DefaultPolicy::from_roster(&roster).expect("roster has both kernel kinds");
    let handle = PolicyHandle::new(std::sync::Arc::new(policy));
    let mut cached = handle.snapshot();
    let triple2 = input2.triple();
    let alloc_pooled_handle = allocs_total(iters, || {
        handle.refresh(&mut cached);
        let cfg = cached.select(triple2);
        let id = rt
            .manifest
            .artifact_id_for_config(&cfg, triple2)
            .or_else(|| rt.manifest.eligible_id(triple2))
            .expect("triple servable");
        rt.gemm_pooled(id, &input2, &mut scratch).unwrap();
        black_box(scratch.out[0]);
    });
    println!(
        "allocs/request with policy handle in place: {:.1}",
        alloc_pooled_handle as f64 / iters as f64,
    );
    assert_eq!(
        alloc_pooled_handle, 0,
        "select-through-PolicyHandle must not allocate at steady state"
    );

    // The coordinator now executes through the ExecutionEngine trait
    // (refresh + select + engine.resolve + engine.execute_pooled): the
    // abstraction seam must not reintroduce allocations — the real-engine
    // path is required to stay bit-identical and alloc-free.
    let mut engine: Box<dyn ExecutionEngine> =
        Box::new(RuntimeEngine::open(artifacts).expect("artifacts"));
    let warm_id = engine
        .resolve(&cached.select(triple2), triple2)
        .expect("triple servable");
    engine.ensure_ready(warm_id).expect("compile");
    let alloc_engine = allocs_total(iters, || {
        handle.refresh(&mut cached);
        let cfg = cached.select(triple2);
        let id = engine.resolve(&cfg, triple2).expect("triple servable");
        engine.execute_pooled(id, &input2, &mut scratch).unwrap();
        black_box(scratch.out[0]);
    });
    println!(
        "allocs/request through the ExecutionEngine trait: {:.1}",
        alloc_engine as f64 / iters as f64,
    );
    assert_eq!(
        alloc_engine, 0,
        "engine-trait pooled path must not allocate at steady state"
    );
    drop(engine);

    // ------------------------------------------------------------------
    // Shape-bucketed request fusion: the batched pooled surface vs B
    // sequential pooled calls, at B ∈ {1, 4, 16} (full runs sweep to 64).
    // Every fused slot is bit-identical to the sequential path (pinned by
    // tests/fusion_equivalence.rs); here we gate its *cost*: per-request
    // time no worse than sequential, and zero steady-state allocations.
    suite.section("fused (batched) pooled path — shape-bucketed request fusion");
    let mut batch = BatchScratch::new();
    let fuse_sizes: &[usize] = if quick { &[1, 4, 16] } else { &[1, 4, 16, 64] };
    for &bsz in fuse_sizes {
        let inputs: Vec<GemmInput> = vec![input2.clone(); bsz];
        suite.bench(&format!("gemm_batch_pooled:indirect:100^3:B{bsz}"), || {
            rt.gemm_batch_pooled(indirect_id, &inputs, &mut batch).unwrap();
            black_box(batch.out[0])
        });
        suite.bench(&format!("gemm_pooled:sequential:indirect:100^3:B{bsz}"), || {
            for input in &inputs {
                rt.gemm_pooled(indirect_id, input, &mut scratch).unwrap();
            }
            black_box(scratch.out[0])
        });
    }
    let median_of = |suite: &Suite, name: &str| {
        suite
            .results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.summary.median)
            .expect("bench just ran")
    };
    let mut fusion_rows = Vec::new();
    for &bsz in fuse_sizes {
        let fused = median_of(suite, &format!("gemm_batch_pooled:indirect:100^3:B{bsz}"))
            / bsz as f64;
        let seq = median_of(suite, &format!("gemm_pooled:sequential:indirect:100^3:B{bsz}"))
            / bsz as f64;
        let speedup = if fused > 0.0 { seq / fused } else { 0.0 };
        println!(
            "fusion B={bsz}: {fused:.3e}s/req fused vs {seq:.3e}s/req sequential \
             ({speedup:.2}x), occupancy {bsz}"
        );
        fusion_rows.push(Json::obj(vec![
            ("b", Json::num(bsz as f64)),
            ("occupancy", Json::num(bsz as f64)),
            ("fused_per_request_s", Json::num(fused)),
            ("seq_per_request_s", Json::num(seq)),
            ("speedup", Json::num(speedup)),
        ]));
    }
    extra.push(("fusion", Json::Arr(fusion_rows)));

    // Zero-allocation gate on the fused surface: staging + execute +
    // per-slot unpad of a steady-state B=16 batch must not allocate.
    let inputs16: Vec<GemmInput> = vec![input2.clone(); 16];
    let batch_iters = iters.max(16) / 8;
    let alloc_fused = allocs_total(batch_iters, || {
        rt.gemm_batch_pooled(indirect_id, &inputs16, &mut batch).unwrap();
        black_box(batch.out[0]);
    });
    println!(
        "allocs/request fused pooled B=16 over {batch_iters} batches: {:.1}",
        alloc_fused as f64 / (batch_iters * 16) as f64,
    );
    assert_eq!(
        alloc_fused, 0,
        "fused pooled path must not allocate at steady state \
         ({alloc_fused} allocations over {batch_iters} B=16 batches)"
    );

    // ------------------------------------------------------------------
    // Host SIMD microkernel variants: per-shape speedup of the best
    // servable tier over the scalar reference variant through
    // `gemm_pooled` (same padded buffers, same unpad — only the inner
    // kernel differs, and every tier is bit-identical by construction),
    // plus the fused-path speedup over sequential scalar dispatches.
    // `bench-compare` gates these ratios against the baseline floors.
    suite.section("host SIMD microkernel variants (128-bucket)");
    let host_ids: Vec<(adaptlib::config::HostParams, ArtifactId)> = rt
        .manifest
        .artifacts
        .iter()
        .enumerate()
        .filter_map(|(i, a)| match (a.kind, a.config) {
            (
                ArtifactKind::Indirect { mb: 128, nb: 128, kb: 128 },
                KernelConfig::HostSimd(p),
            ) => Some((p, ArtifactId(i as u32))),
            _ => None,
        })
        .collect();
    let scalar_id = host_ids
        .iter()
        .find(|(p, _)| p.tier == SimdTier::Scalar && !p.packed)
        .expect("manifest expansion provides an unpacked scalar variant")
        .1;
    let (best_p, best_id) = host_ids
        .iter()
        .filter(|(p, _)| microkernel::tier_supported(p.tier) && !p.packed)
        .max_by_key(|(p, _)| (p.tier, p.mr * p.nr, p.ku))
        .copied()
        .expect("the scalar tier is always servable");
    // The packed twin of the best unpacked variant — same tier/tile/
    // unroll, panel-packed operands.  When `ADAPTLIB_PACK=off`, its
    // dispatch degrades to the unpacked path, so the packed legs still
    // run (and their speedup sits near 1.0 — the `pack_enabled` field
    // below is what makes that explainable in the gate output).
    let (packed_p, packed_id) = host_ids
        .iter()
        .find(|(p, _)| {
            p.packed
                && (p.tier, p.mr, p.nr, p.ku)
                    == (best_p.tier, best_p.mr, best_p.nr, best_p.ku)
        })
        .copied()
        .expect("manifest expansion provides the packed twin");
    println!(
        "detected simd tier: {} (packing {}) — benchmarking {} and {} \
         against the scalar variant",
        microkernel::detected_tier(),
        if microkernel::pack_enabled() { "on" } else { "off" },
        best_p.name(),
        packed_p.name(),
    );
    let mut simd_rows = Vec::new();
    for (label, shape_input) in
        [("128^3(m==mb)", &input), ("100^3(padded)", &input2)]
    {
        let scalar_name = format!("gemm_pooled:simd:scalar:{label}");
        suite.bench(&scalar_name, || {
            rt.gemm_pooled(scalar_id, shape_input, &mut scratch).unwrap();
            black_box(scratch.out[0])
        });
        // Stable names across hosts (the detected tier varies by machine;
        // it is recorded in the `simd` object, not the result name).  The
        // packing axis *is* in the name — best vs best_packed — so the
        // missing-gated-key detection covers the packed path.
        let best_name = format!("gemm_pooled:simd:best:{label}");
        suite.bench(&best_name, || {
            rt.gemm_pooled(best_id, shape_input, &mut scratch).unwrap();
            black_box(scratch.out[0])
        });
        let packed_name = format!("gemm_pooled:simd:best_packed:{label}");
        suite.bench(&packed_name, || {
            rt.gemm_pooled(packed_id, shape_input, &mut scratch).unwrap();
            black_box(scratch.out[0])
        });
        let scalar_s = median_of(suite, &scalar_name);
        let best_s = median_of(suite, &best_name);
        let best_packed_s = median_of(suite, &packed_name);
        let speedup = if best_s > 0.0 { scalar_s / best_s } else { 0.0 };
        let packed_speedup =
            if best_packed_s > 0.0 { best_s / best_packed_s } else { 0.0 };
        println!(
            "simd {label}: scalar {scalar_s:.3e}s vs {} {best_s:.3e}s \
             ({speedup:.2}x); packed {best_packed_s:.3e}s \
             ({packed_speedup:.2}x vs unpacked)",
            best_p.tier,
        );
        simd_rows.push(Json::obj(vec![
            ("shape", Json::str(label)),
            ("scalar_s", Json::num(scalar_s)),
            ("best_s", Json::num(best_s)),
            ("best_packed_s", Json::num(best_packed_s)),
            ("speedup", Json::num(speedup)),
            ("packed_speedup", Json::num(packed_speedup)),
        ]));
    }
    // Fused floor: a B=8 fused dispatch of the best variant, per
    // request, against sequential scalar-variant dispatches.
    let inputs8: Vec<GemmInput> = vec![input2.clone(); 8];
    suite.bench("gemm_batch_pooled:simd:best:100^3:B8", || {
        rt.gemm_batch_pooled(best_id, &inputs8, &mut batch).unwrap();
        black_box(batch.out[0])
    });
    let fused_per_req =
        median_of(suite, "gemm_batch_pooled:simd:best:100^3:B8") / 8.0;
    let scalar_per_req = median_of(suite, "gemm_pooled:simd:scalar:100^3(padded)");
    let fused_speedup =
        if fused_per_req > 0.0 { scalar_per_req / fused_per_req } else { 0.0 };
    println!(
        "simd fused B=8: {fused_per_req:.3e}s/req vs scalar \
         {scalar_per_req:.3e}s/req ({fused_speedup:.2}x)"
    );
    // Packed fused leg: all 8 slots share one raw B operand (the batched-
    // inference shape), so the packed B panels are built once and reused
    // across the batch — the B-repack amortization path.
    suite.bench("gemm_batch_pooled:simd:best_packed:100^3:B8", || {
        rt.gemm_batch_pooled(packed_id, &inputs8, &mut batch).unwrap();
        black_box(batch.out[0])
    });
    let fused_packed_per_req =
        median_of(suite, "gemm_batch_pooled:simd:best_packed:100^3:B8") / 8.0;
    let fused_packed_speedup = if fused_packed_per_req > 0.0 {
        scalar_per_req / fused_packed_per_req
    } else {
        0.0
    };
    println!(
        "simd fused packed B=8: {fused_packed_per_req:.3e}s/req \
         ({fused_packed_speedup:.2}x vs scalar)"
    );
    extra.push((
        "simd",
        Json::obj(vec![
            ("tier", Json::str(microkernel::detected_tier().name())),
            ("variant", Json::str(best_p.name())),
            ("packed_variant", Json::str(packed_p.name())),
            ("shapes", Json::Arr(simd_rows)),
            ("fused_speedup_vs_scalar", Json::num(fused_speedup)),
            ("fused_packed_speedup_vs_scalar", Json::num(fused_packed_speedup)),
        ]),
    ));
    // The variant dispatch rides the same pooled scratch: it must keep
    // the zero-allocation contract (stack accumulators only).
    let alloc_simd = allocs_total(iters, || {
        rt.gemm_pooled(best_id, &input2, &mut scratch).unwrap();
        black_box(scratch.out[0]);
    });
    println!(
        "allocs/request simd pooled over {iters} requests: {:.1}",
        alloc_simd as f64 / iters as f64,
    );
    assert_eq!(
        alloc_simd, 0,
        "microkernel pooled path must not allocate at steady state \
         ({alloc_simd} allocations over {iters} requests)"
    );
    // Same contract for the packed path: pack buffers are pools too —
    // once at steady-state capacity, a packed dispatch (pack A + pack B
    // + packed kernel + unpad) performs zero heap allocations.
    let alloc_simd_packed = allocs_total(iters, || {
        rt.gemm_pooled(packed_id, &input2, &mut scratch).unwrap();
        black_box(scratch.out[0]);
    });
    println!(
        "allocs/request simd packed pooled over {iters} requests: {:.1}",
        alloc_simd_packed as f64 / iters as f64,
    );
    assert_eq!(
        alloc_simd_packed, 0,
        "packed microkernel pooled path must not allocate at steady state \
         ({alloc_simd_packed} allocations over {iters} requests)"
    );

    // Wire decode: the network front door's request hot path.  A frame
    // decodes by offset-scanning into borrowed views (no parse tree),
    // and the borrowed operand bytes land in caller-pooled buffers —
    // once those pools reach steady-state capacity, decoding a request
    // off the wire performs exactly zero heap allocations, the same
    // contract the pooled/fused execution legs are held to.
    suite.section("wire decode (network front door request path)");
    let net_req = GemmRequest {
        m: m2,
        n: n2,
        k: k2,
        a: a2.clone(),
        b: b2.clone(),
        c: c2.clone(),
        alpha: 1.0,
        beta: 0.0,
    };
    let mut net_frame = Vec::new();
    wire::encode_request_into(&mut net_frame, 7, 0, "xgemm_128", &net_req)
        .expect("encode 100^3 request");
    let net_body = &net_frame[4..];
    let (mut pa, mut pb, mut pc) = (Vec::new(), Vec::new(), Vec::new());
    let decode_step = |pa: &mut Vec<f32>, pb: &mut Vec<f32>, pc: &mut Vec<f32>| {
        match wire::decode(net_body).expect("valid frame") {
            wire::Frame::Request(rf) => {
                rf.a.copy_into(pa);
                rf.b.copy_into(pb);
                rf.c.copy_into(pc);
                black_box((rf.request_id, rf.hint.len(), pa[0], pb[0], pc[0]));
            }
            wire::Frame::Response(_) | wire::Frame::Status(_) => {
                unreachable!("request frame was encoded above")
            }
        }
    };
    suite.bench("net_decode:100^3", || decode_step(&mut pa, &mut pb, &mut pc));
    let alloc_net = allocs_total(iters, || decode_step(&mut pa, &mut pb, &mut pc));
    println!(
        "allocs/request net decode over {iters} requests: {:.1}",
        alloc_net as f64 / iters as f64,
    );
    assert_eq!(
        alloc_net, 0,
        "wire request decode must not allocate at steady state \
         ({alloc_net} allocations over {iters} requests)"
    );

    extra.push((
        "allocs_per_request",
        Json::obj(vec![
            ("allocating", Json::num(alloc_allocating as f64 / iters as f64)),
            ("pooled", Json::num(alloc_pooled as f64 / iters as f64)),
            (
                "pooled_with_policy_handle",
                Json::num(alloc_pooled_handle as f64 / iters as f64),
            ),
            ("engine_pooled", Json::num(alloc_engine as f64 / iters as f64)),
            ("simd_pooled", Json::num(alloc_simd as f64 / iters as f64)),
            (
                "simd_packed_pooled",
                Json::num(alloc_simd_packed as f64 / iters as f64),
            ),
            (
                "fused_pooled",
                Json::num(alloc_fused as f64 / (batch_iters * 16) as f64),
            ),
            ("net_decode", Json::num(alloc_net as f64 / iters as f64)),
            ("iters", Json::num(iters as f64)),
        ]),
    ));
    drop(rt);

    suite.section("server shard scaling (mixed test-set workload)");
    let n_requests = if quick { 48 } else { 240 };
    let mut scaling = Vec::new();
    let mut rps1 = 0.0;
    for shards in [1usize, 2, 4] {
        let (rps, gflops) = shard_throughput(artifacts, shards, n_requests);
        if shards == 1 {
            rps1 = rps;
        }
        let speedup = if rps1 > 0.0 { rps / rps1 } else { 0.0 };
        println!(
            "shards={shards}: {rps:.1} req/s, {gflops:.2} GFLOP/s, {speedup:.2}x vs 1 shard"
        );
        scaling.push(Json::obj(vec![
            ("shards", Json::num(shards as f64)),
            ("rps", Json::num(rps)),
            ("gflops", Json::num(gflops)),
            ("speedup_vs_1", Json::num(speedup)),
        ]));
    }
    extra.push(("shard_scaling", Json::Arr(scaling)));
}

fn write_json(suite: &Suite, extra: &[(&str, Json)], quick: bool) {
    let results: Vec<Json> = suite
        .results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(r.name.clone())),
                ("median_s", Json::num(r.summary.median)),
                ("mean_s", Json::num(r.summary.mean)),
                ("iterations", Json::num(r.iterations as f64)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("bench", Json::str("hotpath")),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
    ];
    for (k, v) in extra {
        fields.push((*k, v.clone()));
    }
    let json = Json::obj(fields);
    let path = "BENCH_hotpath.json";
    match std::fs::write(path, json.to_string()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
