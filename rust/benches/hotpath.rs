//! `cargo bench --bench hotpath` — the on-line request path, measured on
//! the real PJRT runtime: pad/unpad helpers, literal round-trips, direct
//! vs indirect artifact execution, end-to-end server round trip.
//! Feeds the §Perf optimization log in EXPERIMENTS.md.

use std::path::Path;

use adaptlib::coordinator::{DefaultPolicy, GemmRequest, GemmServer, ServerConfig};
use adaptlib::harness::{black_box, Suite};
use adaptlib::runtime::{pad, ArtifactKind, GemmInput, GemmRuntime, PjrtBackend};
use adaptlib::util::prng::Rng;

fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.f32() - 0.5).collect()
}

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping hotpath bench: run `make artifacts` first");
        return;
    }
    let mut suite = Suite::from_args();
    let mut rng = Rng::new(1);

    suite.section("helper (pad/unpad) cost — the O(n^2) indirect tax");
    let src = rand_vec(&mut rng, 200 * 200);
    suite.bench("pad:200x200->256x256", || {
        black_box(pad::pad(&src, 200, 200, 256, 256))
    });
    let padded = pad::pad(&src, 200, 200, 256, 256);
    suite.bench("unpad:256x256->200x200", || {
        black_box(pad::unpad(&padded, 256, 200, 200))
    });
    let mut out = vec![0f32; 200 * 200];
    suite.bench("unpad_into:256x256->200x200", || {
        pad::unpad_into(&padded, 256, 200, 200, &mut out);
        black_box(out[0])
    });

    suite.section("PJRT execution (real kernels)");
    let mut rt = GemmRuntime::open(artifacts).expect("artifacts");
    let direct = rt
        .manifest
        .artifacts
        .iter()
        .find(|a| matches!(a.kind, ArtifactKind::Direct { m: 128, n: 128, k: 128, trans_a: false, trans_b: false }))
        .expect("128^3 direct artifact")
        .clone();
    let indirect = rt
        .manifest
        .artifacts
        .iter()
        .find(|a| matches!(a.kind, ArtifactKind::Indirect { mb: 128, nb: 128, kb: 128 }))
        .expect("128^3 bucket")
        .clone();
    let (m, n, k) = (128usize, 128usize, 128usize);
    let (a, b, c) = (
        rand_vec(&mut rng, m * k),
        rand_vec(&mut rng, k * n),
        rand_vec(&mut rng, m * n),
    );
    let input = GemmInput { m, n, k, a: &a, b: &b, c: &c, alpha: 1.0, beta: 0.0 };
    rt.gemm(&direct.name, &input).unwrap(); // compile outside timing
    rt.gemm(&indirect.name, &input).unwrap();
    suite.bench("gemm:direct:128^3", || {
        black_box(rt.gemm(&direct.name, &input).unwrap().out[0])
    });
    suite.bench("gemm:indirect:128^3(no-pad-needed)", || {
        black_box(rt.gemm(&indirect.name, &input).unwrap().out[0])
    });
    // In-bucket (pays padding).
    let (m2, n2, k2) = (100usize, 100usize, 100usize);
    let (a2, b2, c2) = (
        rand_vec(&mut rng, m2 * k2),
        rand_vec(&mut rng, k2 * n2),
        rand_vec(&mut rng, m2 * n2),
    );
    let input2 = GemmInput {
        m: m2, n: n2, k: k2, a: &a2, b: &b2, c: &c2, alpha: 1.0, beta: 0.0,
    };
    suite.bench("gemm:indirect:100^3(padded-into-128)", || {
        black_box(rt.gemm(&indirect.name, &input2).unwrap().out[0])
    });

    suite.section("server round trip");
    let backend = PjrtBackend::open(artifacts).unwrap();
    let policy = DefaultPolicy::from_roster(&backend.roster_configs()).unwrap();
    drop(backend);
    let server =
        GemmServer::start(artifacts, Box::new(policy), ServerConfig::default())
            .expect("server");
    let handle = server.handle();
    // Warm the executable cache.
    let mk_req = || GemmRequest {
        m, n, k,
        a: a.clone(), b: b.clone(), c: c.clone(),
        alpha: 1.0, beta: 0.0,
    };
    handle.call(mk_req()).unwrap();
    suite.bench("server:call:128^3", || {
        black_box(handle.call(mk_req()).unwrap().service)
    });
    drop(handle);
    if let Some(stats) = server.shutdown() {
        println!("{}", stats.report());
    }
}
