//! `cargo bench --bench figures` — regenerates Figures 3-7 (accuracy,
//! DTPR/DTTR, per-triple GFLOPS series) and prints the paper's headline
//! comparisons (max speedup vs default per device).

use adaptlib::device::DeviceId;
use adaptlib::experiments::{figures, Context};

fn main() {
    let mut ctx = Context::new();
    let out = std::path::Path::new("results");

    for device in [DeviceId::NvidiaP100, DeviceId::MaliT860] {
        let f3 = figures::fig3(&mut ctx, device);
        println!("{}", f3.ascii);
        f3.save(out).unwrap();

        let f45 = figures::fig45(&mut ctx, device);
        println!("{}", f45.ascii);
        f45.save(out).unwrap();

        let f67 = figures::fig67(&mut ctx, device);
        println!("{}", f67.ascii);
        f67.save(out).unwrap();
    }
    eprintln!("figures saved under results/");
}
