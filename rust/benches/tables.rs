//! `cargo bench --bench tables` — regenerates the paper's Tables 1-6 and
//! times each pipeline stage (tuning, training, evaluation) per table.
//! The printed rows are the reproduction artifact; the timings are the
//! harness's own cost accounting.

use adaptlib::dataset::{Dataset, DatasetKind};
use adaptlib::device::{DeviceId, DeviceProfile};
use adaptlib::dtree::{train, TrainParams};
use adaptlib::experiments::{tables, Context};
use adaptlib::harness::Suite;
use adaptlib::tuner::{Backend, SimBackend, Tuner, TuningDb};

fn main() {
    let mut suite = Suite::from_args();

    suite.section("Table 1/2 (static)");
    suite.bench("table1:render", tables::table1);
    suite.bench("table2:render", tables::table2);
    println!("{}", tables::table1().ascii);
    println!("{}", tables::table2().ascii);

    suite.section("pipeline stage costs");
    // Tuning one po2 dataset exhaustively on each simulated device.
    for device in [DeviceId::NvidiaP100, DeviceId::MaliT860] {
        suite.bench(&format!("tune:po2:{device}"), || {
            let mut backend = SimBackend::new(DeviceProfile::get(device));
            let ds = Dataset::generate(DatasetKind::Po2);
            let mut db = TuningDb::new(backend.device_name());
            Tuner::default().label_dataset(&mut backend, &ds, &mut db).len()
        });
    }
    // Training the paper's heaviest model shape.
    {
        let mut backend = SimBackend::new(DeviceProfile::nvidia_p100());
        let ds = Dataset::generate(DatasetKind::Po2);
        let mut db = TuningDb::new(backend.device_name());
        let labeled = Tuner::default().label_dataset(&mut backend, &ds, &mut db);
        let hmax_l1 = TrainParams::paper_sweep()
            .into_iter()
            .find(|p| p.name() == "hMax-L1")
            .unwrap();
        suite.bench("train:hMax-L1:po2", || {
            train(&labeled.entries, labeled.classes.len(), hmax_l1).n_leaves()
        });
    }

    suite.section("Tables 3-6 (full sweeps, cached between tables)");
    let mut ctx = Context::new();
    let t0 = std::time::Instant::now();
    let t3 = tables::table3(&mut ctx);
    println!("{}", t3.ascii);
    println!(
        "table3 computed in {:.1}s (3 datasets x 40 models)",
        t0.elapsed().as_secs_f64()
    );
    let t4 = tables::table4(&mut ctx);
    println!("{}", t4.ascii);
    let t5 = tables::table5(&mut ctx);
    println!("{}", t5.ascii);
    let t6 = tables::table6(&mut ctx);
    println!("{}", t6.ascii);

    let out = std::path::Path::new("results");
    for r in [&t3, &t4, &t5, &t6] {
        r.save(out).expect("saving results");
    }
    eprintln!("tables saved under results/");
}
