//! `adaptd` — the adaptive-GEMM library daemon / CLI.
//!
//! Subcommands drive the whole paper pipeline:
//!
//! ```text
//! adaptd exp <table1|table2|table3|table4|table5|table6|fig3|fig4|fig5|fig6|fig7|micro|all>
//! adaptd tune      --device <p100|mali> --dataset <po2|go2|antonnet> --out tuned.json
//! adaptd train     --device ... --dataset ... --model h8-L1 --out model.json
//! adaptd codegen   --device ... --dataset ... --model hMax-L1 --lang <rust|cpp>
//! adaptd e2e       --artifacts artifacts --requests 400
//! adaptd serve-demo --artifacts artifacts --requests 200 --policy <model|default>
//! adaptd serve     --artifacts artifacts --listen 127.0.0.1:7070 --policy default
//! adaptd drift     --artifacts artifacts --requests 32 --waves 3
//! adaptd hetero    --artifacts artifacts --devices host-cpu,p100,mali --waves 2
//! adaptd overload  --artifacts artifacts --requests 120 --capacity 24 --load 1,2,4
//! adaptd chaos     --artifacts artifacts --chaos-devices p100,mali --device p100
//! adaptd bench-compare --baseline BENCH_baseline.json --current BENCH_hotpath.json
//! adaptd lint      [--root rust]
//! adaptd info      --artifacts artifacts
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context as _, Result};

use adaptlib::cli::{self, OptSpec};
use adaptlib::codegen;
use adaptlib::dataset::{Dataset, DatasetKind};
use adaptlib::device::DeviceId;
use adaptlib::dtree::{MinSamples, TrainParams};
use adaptlib::experiments::{self, Context};
use adaptlib::runtime::GemmRuntime;
use adaptlib::tuner::{Backend, SimBackend, Tuner, TuningDb};
use adaptlib::device::DeviceProfile;

fn opt(
    name: &'static str,
    help: &'static str,
    default: Option<&'static str>,
) -> OptSpec {
    OptSpec { name, help, takes_value: true, default }
}

fn opt_specs() -> Vec<OptSpec> {
    vec![
        opt("device", "device profile (host-cpu|p100|mali|t860)", Some("p100")),
        opt("devices", "hetero: fleet device classes (csv)", Some("host-cpu,p100,mali")),
        opt("dataset", "dataset (po2|go2|antonnet)", Some("po2")),
        opt("model", "model name, e.g. hMax-L1", Some("hMax-L1")),
        opt("lang", "codegen language (rust|cpp)", Some("rust")),
        opt("out", "output file/directory", None),
        opt("artifacts", "artifact directory", Some("artifacts")),
        opt("requests", "number of requests to serve (per wave for drift)", Some("200")),
        opt("reps", "tuner measurement repetitions", Some("3")),
        opt("policy", "serving policy (model|default)", Some("model")),
        opt("shards", "dispatcher shards for serving", Some("1")),
        opt("max-fuse", "max same-shape requests fused per dispatch (1 = off)", Some("16")),
        opt("waves", "drift: adaptation waves on the shifted mix", Some("3")),
        opt("sample", "drift: telemetry sampling fraction", Some("1.0")),
        opt("shadow", "drift: shadow-execution budget fraction", Some("1.0")),
        opt("capacity", "overload: per-class queue bound", Some("24")),
        opt("load", "overload: offered-load factors (csv)", Some("1,2,4")),
        opt("pressure-ms", "overload: pressure threshold ms (0 = auto)", Some("0")),
        opt("slowdown", "overload: pressure-pick slowdown bound", Some("1.25")),
        opt("chaos-devices", "chaos: fleet device classes (csv, sim-only)", Some("p100,mali")),
        opt("rate", "chaos: transient per-dispatch failure probability", Some("0.25")),
        opt("seed", "chaos: fault-plan seed", Some("3298844397")),
        opt("listen", "serve: listen address (<ip>:<port>)", Some("127.0.0.1:7070")),
        opt("inflight", "serve: per-connection in-flight request cap", Some("32")),
        opt("duration", "serve: seconds before graceful drain (0 = run until killed)", Some("0")),
        opt("baseline", "bench-compare: committed baseline JSON", None),
        opt("current", "bench-compare: freshly produced bench JSON", None),
        opt("tolerance", "bench-compare: relative regression tolerance", Some("0.15")),
        opt("root", "lint: crate directory containing src/ (auto-detected)", None),
    ]
}

fn switch_specs() -> Vec<(&'static str, &'static str)> {
    vec![
        ("quiet", "suppress progress output"),
        ("verbose", "print per-step progress"),
        ("require-recovered", "bench-compare: fail unless current reports recovered=true"),
        ("no-net", "overload: skip the loopback network arm"),
    ]
}

fn commands() -> Vec<(&'static str, &'static str)> {
    vec![
        ("exp <id|all>", "regenerate a paper table/figure (or all)"),
        ("tune", "run the exhaustive tuner on a simulated device"),
        ("train", "train one decision-tree model and print its stats"),
        ("codegen", "emit the if-then-else selector source for a model"),
        ("e2e", "end-to-end adaptive serving on the CPU PJRT runtime"),
        ("serve-demo", "serve a request stream under one policy"),
        ("serve", "listen on a socket: the framed network front door"),
        ("drift", "workload-shift experiment: online adaptation vs frozen model"),
        ("hetero", "heterogeneous fleet: mixed workload across device classes"),
        ("overload", "offered-load sweep: admission, shedding, pressure picks"),
        ("chaos", "fault-injection sweep: breakers, retry/failover, recovery"),
        ("bench-compare", "diff bench JSONs and fail on perf regressions"),
        ("lint", "source-level convention lint over the crate tree"),
        ("info", "describe the artifact roster"),
    ]
}

fn parse_model_name(s: &str) -> Result<TrainParams> {
    // "h8-L0.1" | "hMax-L2"
    let (h, l) = s.split_once("-L").context("model name must be h<H>-L<L>")?;
    let max_depth = match h {
        "hMax" => None,
        _ => Some(
            h.strip_prefix('h')
                .context("model name must start with h")?
                .parse::<u32>()?,
        ),
    };
    let min_samples_leaf = if l.contains('.') {
        MinSamples::Frac(l.parse::<f64>()?)
    } else {
        MinSamples::Count(l.parse::<usize>()?)
    };
    Ok(TrainParams { max_depth, min_samples_leaf })
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{}", cli::usage("adaptd", &commands(), &opt_specs(), &switch_specs()));
        return;
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let switches: Vec<&str> = switch_specs().iter().map(|(n, _)| *n).collect();
    let args = cli::parse(argv, &opt_specs(), &switches, 2)?;
    let cmd = args.command.first().map(String::as_str).unwrap_or("");
    match cmd {
        "exp" => cmd_exp(&args),
        "tune" => cmd_tune(&args),
        "train" => cmd_train(&args),
        "codegen" => cmd_codegen(&args),
        "e2e" => cmd_e2e(&args),
        "serve-demo" => cmd_serve_demo(&args),
        "serve" => cmd_serve(&args),
        "drift" => cmd_drift(&args),
        "hetero" => cmd_hetero(&args),
        "overload" => cmd_overload(&args),
        "chaos" => cmd_chaos(&args),
        "bench-compare" => cmd_bench_compare(&args),
        "lint" => cmd_lint(&args),
        "info" => cmd_info(&args),
        other => bail!(
            "unknown command '{other}'\n{}",
            cli::usage("adaptd", &commands(), &opt_specs(), &switch_specs())
        ),
    }
}

// Every device flag goes through DeviceId::parse_flag / parse_list — the
// one parse+error path, which lists the valid spellings on a bad value.
fn device_of(args: &cli::Args) -> Result<DeviceId> {
    DeviceId::parse_flag(args.get_or("device", "p100"))
}

fn devices_of(args: &cli::Args) -> Result<Vec<DeviceId>> {
    DeviceId::parse_list(args.get_or("devices", "host-cpu,p100,mali"))
}

fn dataset_of(args: &cli::Args) -> Result<DatasetKind> {
    DatasetKind::parse(args.get_or("dataset", "po2"))
        .context("unknown dataset; use po2|go2|antonnet")
}

fn cmd_exp(args: &cli::Args) -> Result<()> {
    let which = args
        .command
        .get(1)
        .map(String::as_str)
        .context("exp requires an experiment id (or 'all')")?;
    let out = PathBuf::from(args.get_or("out", "results"));
    let mut ctx = Context::new();
    ctx.verbose = args.has("verbose");

    let mut renders = Vec::new();
    match which {
        "all" => {
            renders = experiments::run_all(&mut ctx, &out)?;
        }
        "table1" => renders.push(experiments::tables::table1()),
        "table2" => renders.push(experiments::tables::table2()),
        "table3" => renders.push(experiments::tables::table3(&mut ctx)),
        "table4" => renders.push(experiments::tables::table4(&mut ctx)),
        "table5" => renders.push(experiments::tables::table5(&mut ctx)),
        "table6" => renders.push(experiments::tables::table6(&mut ctx)),
        "fig3" => {
            renders.push(experiments::figures::fig3(&mut ctx, DeviceId::NvidiaP100));
            renders.push(experiments::figures::fig3(&mut ctx, DeviceId::MaliT860));
        }
        "fig4" => renders.push(experiments::figures::fig45(&mut ctx, DeviceId::NvidiaP100)),
        "fig5" => renders.push(experiments::figures::fig45(&mut ctx, DeviceId::MaliT860)),
        "fig6" => renders.push(experiments::figures::fig67(&mut ctx, DeviceId::NvidiaP100)),
        "fig7" => renders.push(experiments::figures::fig67(&mut ctx, DeviceId::MaliT860)),
        "micro" => renders.push(experiments::microbench::selector_overhead(&mut ctx)),
        "ablation" => renders.extend(experiments::ablation::run_all(&mut ctx)),
        other => bail!("unknown experiment '{other}'"),
    }
    for r in &renders {
        println!("{}", r.ascii);
        r.save(&out)?;
    }
    eprintln!("saved {} experiment artifact(s) under {}", renders.len(), out.display());
    Ok(())
}

fn cmd_tune(args: &cli::Args) -> Result<()> {
    let device = device_of(args)?;
    let kind = dataset_of(args)?;
    let mut backend = SimBackend::new(DeviceProfile::get(device));
    let dataset = Dataset::generate(kind);
    let mut db = TuningDb::new(backend.device_name());
    let t0 = std::time::Instant::now();
    let labeled = Tuner::default().label_dataset(&mut backend, &dataset, &mut db);
    let (ux, ud) = labeled.classes.unique_per_kernel();
    println!(
        "tuned {} triples on {device} in {:.1}s: {} classes ({ux} xgemm, {ud} direct)",
        labeled.len(),
        t0.elapsed().as_secs_f64(),
        labeled.classes.len(),
    );
    if let Some(out) = args.get("out") {
        labeled.save(Path::new(out))?;
        db.save(Path::new(&format!("{out}.db.json")))?;
        println!("saved labeled dataset to {out} (+ .db.json)");
    }
    Ok(())
}

fn offline(args: &cli::Args) -> Result<(Context, DeviceId, DatasetKind)> {
    let device = device_of(args)?;
    let kind = dataset_of(args)?;
    let mut ctx = Context::new();
    ctx.verbose = args.has("verbose");
    ctx.sweep(device, kind);
    Ok((ctx, device, kind))
}

fn cmd_train(args: &cli::Args) -> Result<()> {
    let params = parse_model_name(args.get_or("model", "hMax-L1"))?;
    let (mut ctx, device, kind) = offline(args)?;
    let sweep = ctx.sweep(device, kind);
    let row = sweep
        .model(&params.name())
        .context("model not in the paper sweep")?;
    println!(
        "model {} on {device}/{kind}: accuracy {:.1}% DTPR {:.3} DTTR {:.3} | {} leaves, depth {}",
        row.scores.model,
        row.scores.accuracy,
        row.scores.dtpr,
        row.scores.dttr,
        row.stats.n_leaves,
        row.stats.height,
    );
    if let Some(out) = args.get("out") {
        row.tree.save(Path::new(out))?;
        println!("saved model to {out}");
    }
    Ok(())
}

fn cmd_codegen(args: &cli::Args) -> Result<()> {
    let params = parse_model_name(args.get_or("model", "hMax-L1"))?;
    let (mut ctx, device, kind) = offline(args)?;
    let sweep = ctx.sweep(device, kind);
    let row = sweep
        .model(&params.name())
        .context("model not in the paper sweep")?;
    let src = match args.get_or("lang", "rust") {
        "rust" => codegen::emit_rust(&row.tree, &sweep.labeled.classes),
        "cpp" => codegen::emit_cpp(&row.tree, &sweep.labeled.classes),
        other => bail!("unknown language '{other}'"),
    };
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, &src)?;
            eprintln!("wrote {} bytes to {out}", src.len());
        }
        None => print!("{src}"),
    }
    Ok(())
}

fn cmd_e2e(args: &cli::Args) -> Result<()> {
    use adaptlib::coordinator::ServerConfig;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n: usize = args.get_parse("requests", 200)?;
    let reps: usize = args.get_parse("reps", 3)?;
    let shards: usize = args.get_parse("shards", 1)?;
    let max_fuse: usize = args.get_parse("max-fuse", 16)?;
    let report = experiments::e2e::run_with(
        &artifacts,
        n,
        reps,
        ServerConfig { max_fuse, ..ServerConfig::with_shards(shards) },
    )?;
    println!("{}", report.render());
    Ok(())
}

fn cmd_serve_demo(args: &cli::Args) -> Result<()> {
    use adaptlib::coordinator::{DefaultPolicy, ModelPolicy, SelectPolicy, ServerConfig};
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n: usize = args.get_parse("requests", 200)?;
    let reps: usize = args.get_parse("reps", 1)?;
    let policy: Box<dyn SelectPolicy> = match args.get_or("policy", "model") {
        "model" => {
            let m = experiments::e2e::offline_train(&artifacts, reps)?;
            Box::new(ModelPolicy::new(&m.tree, &m.classes))
        }
        "default" => {
            let backend = adaptlib::runtime::PjrtBackend::open(&artifacts)?;
            Box::new(
                DefaultPolicy::from_roster(&backend.roster_configs())
                    .context("roster lacks a kernel kind")?,
            )
        }
        other => bail!("unknown policy '{other}'"),
    };
    let shards: usize = args.get_parse("shards", 1)?;
    let max_fuse: usize = args.get_parse("max-fuse", 16)?;
    let requests = experiments::e2e::request_stream(n, 42);
    let stats = experiments::e2e::serve(
        &artifacts,
        policy,
        requests,
        ServerConfig { max_fuse, ..ServerConfig::with_shards(shards) },
    )?;
    println!("{}", stats.report());
    Ok(())
}

/// The network front door: bind the framed TCP listener in front of a
/// `GemmServer` and serve until `--duration` elapses (then drain
/// gracefully) or forever when it is 0.
fn cmd_serve(args: &cli::Args) -> Result<()> {
    use adaptlib::coordinator::{
        DefaultPolicy, GemmServer, ModelPolicy, SelectPolicy, ServerConfig,
    };
    use adaptlib::net::{NetConfig, NetServer};
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let listen = cli::parse_addr("listen", args.get_or("listen", "127.0.0.1:7070"))?;
    let max_inflight: usize = args.get_parse("inflight", 32)?;
    let duration_secs: u64 = args.get_parse("duration", 0)?;
    let reps: usize = args.get_parse("reps", 1)?;
    let policy: Box<dyn SelectPolicy> = match args.get_or("policy", "model") {
        "model" => {
            let m = experiments::e2e::offline_train(&artifacts, reps)?;
            Box::new(ModelPolicy::new(&m.tree, &m.classes))
        }
        "default" => {
            let backend = adaptlib::runtime::PjrtBackend::open(&artifacts)?;
            Box::new(
                DefaultPolicy::from_roster(&backend.roster_configs())
                    .context("roster lacks a kernel kind")?,
            )
        }
        other => bail!("unknown policy '{other}'"),
    };
    let cfg = ServerConfig {
        max_fuse: args.get_parse("max-fuse", 16)?,
        queue_capacity: args.get_parse("capacity", 24)?,
        ..ServerConfig::with_shards(args.get_parse("shards", 1)?)
    };
    let server = GemmServer::start(&artifacts, policy, cfg)?;
    let net = NetServer::bind(
        listen,
        server.handle(),
        NetConfig { max_inflight, ..NetConfig::default() },
    )
    .with_context(|| format!("binding {listen}"))?;
    println!("listening on {}", net.local_addr());
    if duration_secs == 0 {
        // Run until killed; park cheaply and surface counters hourly.
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
            eprintln!("{:?}", net.stats());
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration_secs));
    let net_stats = net.shutdown();
    println!("front door: {net_stats:?}");
    if let Some(stats) = server.shutdown() {
        println!("{}", stats.report());
    }
    Ok(())
}

/// Workload-shift experiment: frozen model vs the online adaptation loop
/// on the same shifted traffic; writes the machine-readable summary the
/// CI bench gate consumes.
fn cmd_drift(args: &cli::Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    // The in-code fallbacks mirror the OptSpec defaults (cli::parse
    // pre-populates those, so these only document the effective values).
    let cfg = experiments::drift::DriftConfig {
        requests_per_wave: args.get_parse("requests", 200)?,
        waves: args.get_parse("waves", 3)?,
        reps: args.get_parse("reps", 3)?,
        shards: args.get_parse("shards", 1)?,
        telemetry_fraction: args.get_parse("sample", 1.0)?,
        shadow_fraction: args.get_parse("shadow", 1.0)?,
    };
    let report = experiments::drift::run(&artifacts, cfg)?;
    println!("{}", report.render());
    let out = PathBuf::from(args.get_or("out", "BENCH_drift.json"));
    report.save(&out)?;
    eprintln!("wrote {}", out.display());
    Ok(())
}

/// Heterogeneous-fleet experiment: serve a mixed AntonNet workload across
/// {host-cpu, p100, mali} with per-device policies and adaptation; score
/// per-device selection accuracy against each device's oracle and write
/// the machine-readable summary the CI hetero gate consumes.
fn cmd_hetero(args: &cli::Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    // The in-code fallbacks mirror the OptSpec defaults (cli::parse
    // pre-populates those, so these only document the effective values);
    // CI and `make hetero` pass the quick presets explicitly.
    let cfg = experiments::hetero::HeteroConfig {
        requests_per_wave: args.get_parse("requests", 200)?,
        waves: args.get_parse("waves", 3)?,
        shards_per_class: args.get_parse("shards", 1)?,
        reps: args.get_parse("reps", 3)?,
        telemetry_fraction: args.get_parse("sample", 1.0)?,
        shadow_fraction: args.get_parse("shadow", 1.0)?,
        devices: devices_of(args)?,
    };
    let report = experiments::hetero::run(&artifacts, cfg)?;
    println!("{}", report.render());
    let out = PathBuf::from(args.get_or("out", "BENCH_hetero.json"));
    report.save(&out)?;
    eprintln!("wrote {}", out.display());
    Ok(())
}

/// Overload experiment: open-loop offered-load sweep at multiples of the
/// calibrated capacity, policy-only vs pressure-pick selection; writes
/// the machine-readable summary the CI overload gate consumes
/// (shed rate at 1x, bounded peak queue depth, p99 floor).
fn cmd_overload(args: &cli::Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut load_factors = Vec::new();
    for part in args
        .get_or("load", "1,2,4")
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
    {
        let f: f64 = part
            .parse()
            .with_context(|| format!("invalid load factor '{part}'"))?;
        load_factors.push(f);
    }
    let cfg = experiments::overload::OverloadConfig {
        requests: args.get_parse("requests", 120)?,
        load_factors,
        shards: args.get_parse("shards", 1)?,
        queue_capacity: args.get_parse("capacity", 24)?,
        reps: args.get_parse("reps", 1)?,
        pressure_threshold_ms: args.get_parse("pressure-ms", 0.0)?,
        pressure_slowdown: args.get_parse("slowdown", 1.25)?,
        max_fuse: args.get_parse("max-fuse", 16)?,
        net: !args.has("no-net"),
        // 0 = auto-size the per-connection cap to the sweep (the arm
        // measures fleet admission, not the socket cap; `serve` is
        // where --inflight applies).
        net_inflight: 0,
    };
    let report = experiments::overload::run(&artifacts, cfg)?;
    println!("{}", report.render());
    let out = PathBuf::from(args.get_or("out", "BENCH_overload.json"));
    report.save(&out)?;
    eprintln!("wrote {}", out.display());
    Ok(())
}

/// Chaos experiment: fault injection against a simulated fleet — breaker
/// quarantine, deadline-aware retry/failover, and HalfOpen recovery;
/// writes the machine-readable summary the CI chaos gate consumes
/// (availability floor, zero post-recovery errors, bit-identity, no
/// hung replies).
fn cmd_chaos(args: &cli::Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    // The in-code fallbacks mirror the OptSpec defaults (cli::parse
    // pre-populates those, so these only document the effective values).
    let cfg = experiments::chaos::ChaosConfig {
        requests_per_wave: args.get_parse("requests", 24)?,
        waves: args.get_parse("waves", 2)?,
        shards_per_class: args.get_parse("shards", 1)?,
        devices: DeviceId::parse_list(args.get_or("chaos-devices", "p100,mali"))?,
        victim: device_of(args)?,
        seed: args.get_parse("seed", 0xC4A0_5EEDu64)?,
        transient_rate: args.get_parse("rate", 0.25)?,
        ..experiments::chaos::ChaosConfig::default()
    };
    let report = experiments::chaos::run(&artifacts, cfg)?;
    println!("{}", report.render());
    let out = PathBuf::from(args.get_or("out", "BENCH_chaos.json"));
    report.save(&out)?;
    eprintln!("wrote {}", out.display());
    Ok(())
}

/// The CI bench-regression gate: diff a fresh bench summary against the
/// committed baseline and exit non-zero on regressions beyond tolerance.
fn cmd_bench_compare(args: &cli::Args) -> Result<()> {
    use adaptlib::util::benchcmp;
    use adaptlib::util::json::Json;
    let current = args
        .get("current")
        .context("bench-compare requires --current <fresh bench JSON>")?;
    let tolerance: f64 = args.get_parse("tolerance", 0.15)?;
    let require_recovered = args.has("require-recovered");

    if require_recovered {
        let text = std::fs::read_to_string(current)
            .with_context(|| format!("reading {current}"))?;
        let json = Json::parse(&text)?;
        let recovered = json
            .get("recovered")
            .ok()
            .and_then(|r| r.as_bool().ok())
            .context("--require-recovered: current file has no 'recovered' bool")?;
        if !recovered {
            bail!("{current}: drift experiment did not recover (recovered=false)");
        }
        println!("{current}: recovered=true");
    }

    let Some(baseline) = args.get("baseline") else {
        // Recovery-only invocation (drift files have no baseline).
        anyhow::ensure!(
            require_recovered,
            "bench-compare requires --baseline (or --require-recovered)"
        );
        return Ok(());
    };
    let diff = benchcmp::compare_files(baseline, current, tolerance)?;
    for line in &diff.lines {
        println!("  {line}");
    }
    println!(
        "compared {} metric(s) against {baseline} (tolerance {:.0}%)",
        diff.compared,
        tolerance * 100.0
    );
    if diff.regressions.is_empty() {
        println!("no regressions beyond tolerance");
    } else {
        let verdict = if diff.provisional {
            "WARNING (provisional baseline — not failing; see README to refresh)"
        } else {
            "REGRESSION"
        };
        for r in &diff.regressions {
            eprintln!("{verdict}: {r}");
        }
    }
    // The verdict itself lives (and is unit-tested) in BenchDiff.
    if !diff.passes() {
        bail!("{} bench regression(s) beyond tolerance", diff.regressions.len());
    }
    Ok(())
}

/// The CI lint gate: scan the crate's own sources for the concurrency
/// and hot-path conventions `rustc` cannot check (SAFETY comments on
/// `unsafe`, RELAXED justifications, allocation-free fenced functions,
/// exhaustive matches over the protocol enums).  Exits non-zero on any
/// finding, printing each as `file:line: [rule] message`.
fn cmd_lint(args: &cli::Args) -> Result<()> {
    use adaptlib::analysis::lint;
    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        // Work from either the repo root or the crate directory.
        None if Path::new("src").is_dir() => PathBuf::from("."),
        None => PathBuf::from("rust"),
    };
    anyhow::ensure!(
        root.join("src").is_dir(),
        "no src/ under '{}' — pass --root <crate dir>",
        root.display()
    );
    let findings = lint::lint_paths(&root, lint::default_paths())?;
    for f in &findings {
        println!("{f}");
    }
    if !findings.is_empty() {
        bail!("lint: {} finding(s)", findings.len());
    }
    println!("lint: clean under '{}' (scanned src, benches, tests)", root.display());
    Ok(())
}

fn cmd_info(args: &cli::Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = GemmRuntime::open(&artifacts)?;
    println!(
        "artifact roster '{}': {} artifacts",
        rt.manifest.roster,
        rt.manifest.artifacts.len()
    );
    for a in &rt.manifest.artifacts {
        println!("  {:<56} {:<12} {}", a.name, a.config.kind().name(), a.file);
    }
    Ok(())
}
