//! Source-level static analysis over the crate's own tree.
//!
//! The only pass today is [`lint`], a dependency-free scanner behind the
//! `adaptd lint` subcommand.  It enforces the concurrency and hot-path
//! conventions that `rustc` cannot see: safety comments on `unsafe`,
//! justification comments on relaxed atomics, allocation-free fenced
//! functions, and exhaustive matches on the protocol enums.

pub mod lint;
