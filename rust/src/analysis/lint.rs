//! A dependency-free lint pass over the repo's own Rust sources.
//!
//! Four rules, all source-level (no type information, no `syn`):
//!
//! 1. **unsafe-needs-safety** — every `unsafe` token must carry a
//!    `SAFETY:` comment on the same line or within the six lines above
//!    it, stating the proof obligation being discharged.
//! 2. **relaxed-needs-justification** — every `Relaxed` atomic ordering
//!    must carry a `RELAXED:` comment in the same window, explaining why
//!    no happens-before edge is needed.
//! 3. **hot-path-alloc** — a function fenced by the [`FENCE_TAG`] marker
//!    comment must not allocate: the body is scanned for the usual
//!    allocation spellings (`vec!`, `format!`, `.to_string(`, …).
//!    Individual sites are waived with [`ALLOW_ALLOC_TAG`].
//! 4. **wildcard-match** — a `match` that names one of the protocol
//!    enums (`KernelConfig`, `Admission`, `RequestOutcome`,
//!    `WireStatus`) in an arm must not also have a bare `_` arm; adding
//!    a variant must be a compile error, not a silent fallthrough.
//!    Waived per-arm with [`ALLOW_WILDCARD_TAG`].
//!
//! The scanner first scrubs comments and string/char literals out of the
//! source (preserving line structure), so rule tokens inside literals —
//! including the fixtures in this file's tests — are invisible.  Comment
//! text is kept in a per-line side table for the marker lookups.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Comment tag that discharges rule 1.
pub const SAFETY_TAG: &str = "SAFETY:";
/// Comment tag that discharges rule 2.
pub const RELAXED_TAG: &str = "RELAXED:";
/// Comment marker that fences the next `fn` as allocation-free.
pub const FENCE_TAG: &str = "LINT: hot-path";
/// Comment marker waiving rule 3 for the line it sits on (or the next).
pub const ALLOW_ALLOC_TAG: &str = "LINT: allow(alloc)";
/// Comment marker waiving rule 4 for the arm it sits on (or the next).
pub const ALLOW_WILDCARD_TAG: &str = "LINT: allow(wildcard)";

/// How many lines above a token a justification comment may sit.
const COMMENT_WINDOW: usize = 6;
/// How many lines below a fence comment the fenced `fn` may start.
const FENCE_REACH: usize = 20;

/// Allocation spellings rule 3 looks for inside a fenced body.
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "String::new",
    "Box::new",
    "vec!",
    "format!",
    ".push(",
    ".to_string(",
    ".to_owned(",
    ".to_vec(",
    ".clone()",
    ".collect(",
];

/// Enums whose matches must stay exhaustive (rule 4).
const TARGET_ENUMS: &[&str] =
    &["KernelConfig::", "Admission::", "RequestOutcome::", "WireStatus::"];

/// Which rule a finding came from.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Rule {
    UnsafeNeedsSafety,
    RelaxedNeedsJustification,
    HotPathAlloc,
    WildcardMatch,
}

impl Rule {
    pub fn tag(self) -> &'static str {
        match self {
            Rule::UnsafeNeedsSafety => "unsafe-needs-safety",
            Rule::RelaxedNeedsJustification => "relaxed-needs-justification",
            Rule::HotPathAlloc => "hot-path-alloc",
            Rule::WildcardMatch => "wildcard-match",
        }
    }
}

/// One lint violation, addressable as `file:line`.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Finding {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.rule.tag(),
            self.message
        )
    }
}

/// Source text split into per-line code (literals and comments blanked
/// out) and per-line comment text.
struct Scrubbed {
    code: Vec<String>,
    comments: Vec<String>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum ScrubState {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    CharLit,
}

fn scrub(src: &str) -> Scrubbed {
    let chars: Vec<char> = src.chars().collect();
    let mut code: Vec<String> = vec![String::new()];
    let mut comments: Vec<String> = vec![String::new()];
    let mut state = ScrubState::Code;
    let mut prev_word = false;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if state == ScrubState::LineComment {
                state = ScrubState::Code;
            }
            code.push(String::new());
            comments.push(String::new());
            prev_word = false;
            i += 1;
            continue;
        }
        match state {
            ScrubState::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    state = ScrubState::LineComment;
                    code.last_mut().unwrap().push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '/' && next == Some('*') {
                    state = ScrubState::BlockComment(1);
                    code.last_mut().unwrap().push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '"' {
                    state = ScrubState::Str;
                    code.last_mut().unwrap().push(' ');
                    i += 1;
                    continue;
                }
                // Raw / byte string prefixes: r"", r#""#, b"", br"".
                if (c == 'r' || c == 'b') && !prev_word {
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let raw = j > i + 1 || c == 'r';
                    let mut hashes = 0u32;
                    while raw && chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        for _ in i..=j {
                            code.last_mut().unwrap().push(' ');
                        }
                        state = if raw { ScrubState::RawStr(hashes) } else { ScrubState::Str };
                        i = j + 1;
                        continue;
                    }
                }
                if c == '\'' {
                    // Char literal vs lifetime: a literal is '\…' or 'x'
                    // followed by a closing quote; anything else is a
                    // lifetime and stays in the code channel.
                    let is_lit = next == Some('\\')
                        || (next.is_some() && chars.get(i + 2) == Some(&'\''));
                    if is_lit {
                        state = ScrubState::CharLit;
                        code.last_mut().unwrap().push(' ');
                        i += 1;
                        continue;
                    }
                }
                code.last_mut().unwrap().push(c);
                prev_word = c.is_alphanumeric() || c == '_';
                i += 1;
            }
            ScrubState::LineComment => {
                comments.last_mut().unwrap().push(c);
                code.last_mut().unwrap().push(' ');
                i += 1;
            }
            ScrubState::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = ScrubState::BlockComment(depth + 1);
                    comments.last_mut().unwrap().push_str("/*");
                    code.last_mut().unwrap().push_str("  ");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 {
                        ScrubState::Code
                    } else {
                        ScrubState::BlockComment(depth - 1)
                    };
                    code.last_mut().unwrap().push_str("  ");
                    i += 2;
                } else {
                    comments.last_mut().unwrap().push(c);
                    code.last_mut().unwrap().push(' ');
                    i += 1;
                }
            }
            ScrubState::Str => {
                code.last_mut().unwrap().push(' ');
                if c == '\\' {
                    if chars.get(i + 1) == Some(&'\n') {
                        // Line continuation: let the top of the loop see
                        // the newline so line structure is preserved.
                        i += 1;
                    } else {
                        code.last_mut().unwrap().push(' ');
                        i += 2;
                    }
                } else if c == '"' {
                    state = ScrubState::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            ScrubState::RawStr(hashes) => {
                code.last_mut().unwrap().push(' ');
                if c == '"' {
                    let mut n = 0u32;
                    while n < hashes && chars.get(i + 1 + n as usize) == Some(&'#') {
                        n += 1;
                    }
                    if n == hashes {
                        for _ in 0..n {
                            code.last_mut().unwrap().push(' ');
                        }
                        state = ScrubState::Code;
                        i += 1 + n as usize;
                        continue;
                    }
                }
                i += 1;
            }
            ScrubState::CharLit => {
                code.last_mut().unwrap().push(' ');
                if c == '\\' {
                    code.last_mut().unwrap().push(' ');
                    i += 2;
                } else if c == '\'' {
                    state = ScrubState::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    Scrubbed { code, comments }
}

fn is_word(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Word-boundary token search over one scrubbed line.
fn has_token(line: &str, tok: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(off) = line[from..].find(tok) {
        let start = from + off;
        let end = start + tok.len();
        let before_ok = start == 0 || !is_word(bytes[start - 1] as char);
        let after_ok = end >= bytes.len() || !is_word(bytes[end] as char);
        if before_ok && after_ok {
            return true;
        }
        from = end;
    }
    false
}

/// Does any comment on `line` or the `window` lines above contain `tag`?
fn window_has(comments: &[String], line: usize, tag: &str, window: usize) -> bool {
    let lo = line.saturating_sub(window);
    comments[lo..=line].iter().any(|c| c.contains(tag))
}

fn near_has(comments: &[String], line: usize, tag: &str) -> bool {
    window_has(comments, line, tag, 1)
}

/// Rules 1 and 2: tokens that demand a justification comment nearby.
fn check_comment_tags(file: &str, s: &Scrubbed, out: &mut Vec<Finding>) {
    for (i, code) in s.code.iter().enumerate() {
        if has_token(code, "unsafe") && !window_has(&s.comments, i, SAFETY_TAG, COMMENT_WINDOW) {
            out.push(Finding {
                file: file.to_string(),
                line: i + 1,
                rule: Rule::UnsafeNeedsSafety,
                message: format!(
                    "`unsafe` without a `{SAFETY_TAG}` comment within {COMMENT_WINDOW} lines"
                ),
            });
        }
        if has_token(code, "Relaxed") && !window_has(&s.comments, i, RELAXED_TAG, COMMENT_WINDOW) {
            out.push(Finding {
                file: file.to_string(),
                line: i + 1,
                rule: Rule::RelaxedNeedsJustification,
                message: format!(
                    "`Relaxed` ordering without a `{RELAXED_TAG}` comment within \
                     {COMMENT_WINDOW} lines"
                ),
            });
        }
    }
}

/// Index of the line holding the matching close brace, given the line on
/// which to start looking for the first open brace.
fn brace_span(code: &[String], start: usize) -> Option<(usize, usize)> {
    let mut depth = 0i32;
    let mut open_line = None;
    for (i, line) in code.iter().enumerate().skip(start) {
        for c in line.chars() {
            if c == '{' {
                depth += 1;
                if open_line.is_none() {
                    open_line = Some(i);
                }
            } else if c == '}' && open_line.is_some() {
                depth -= 1;
                if depth == 0 {
                    return Some((open_line.unwrap(), i));
                }
            }
        }
    }
    None
}

/// Rule 3: fenced functions must not allocate.
fn check_hot_paths(file: &str, s: &Scrubbed, out: &mut Vec<Finding>) {
    for fence in 0..s.comments.len() {
        if !s.comments[fence].contains(FENCE_TAG) {
            continue;
        }
        // The marker may span a multi-line comment; only act on its
        // first line so one fence maps to one function.
        if fence > 0 && s.comments[fence - 1].contains(FENCE_TAG) {
            continue;
        }
        let hi = (fence + FENCE_REACH).min(s.code.len() - 1);
        let Some(fn_line) = (fence..=hi).find(|&j| has_token(&s.code[j], "fn")) else {
            continue;
        };
        let Some((open, close)) = brace_span(&s.code, fn_line) else {
            continue;
        };
        for k in open..=close {
            for tok in ALLOC_TOKENS {
                if !s.code[k].contains(tok) {
                    continue;
                }
                if s.comments[k].contains(ALLOW_ALLOC_TAG)
                    || (k > 0 && s.comments[k - 1].contains(ALLOW_ALLOC_TAG))
                {
                    continue;
                }
                out.push(Finding {
                    file: file.to_string(),
                    line: k + 1,
                    rule: Rule::HotPathAlloc,
                    message: format!(
                        "`{tok}` inside hot-path fn fenced at line {}",
                        fence + 1
                    ),
                });
            }
        }
    }
}

/// Rule 4: matches over the protocol enums must be exhaustive.
fn check_wildcard_matches(file: &str, s: &Scrubbed, out: &mut Vec<Finding>) {
    for start in 0..s.code.len() {
        let mut from = 0;
        while let Some(off) = s.code[start][from..].find("match") {
            let pos = from + off;
            from = pos + 5;
            let line = &s.code[start];
            let before_ok = pos == 0 || !is_word(line.as_bytes()[pos - 1] as char);
            let after_ok =
                pos + 5 >= line.len() || !is_word(line.as_bytes()[pos + 5] as char);
            if before_ok && after_ok {
                check_one_match(file, s, start, pos + 5, out);
            }
        }
    }
}

/// Scan one `match` body starting after the keyword at
/// (`start_line`, `start_col`); collect top-level arm patterns.
fn check_one_match(
    file: &str,
    s: &Scrubbed,
    start_line: usize,
    start_col: usize,
    out: &mut Vec<Finding>,
) {
    let mut brace = 0i32;
    let mut paren = 0i32;
    let mut bracket = 0i32;
    let mut in_body = false;
    let mut entered = false;
    let mut cur = String::new();
    let mut arms: Vec<(usize, String)> = Vec::new();
    for i in start_line..s.code.len() {
        let line = &s.code[i];
        let lo = if i == start_line { start_col } else { 0 };
        let chars: Vec<char> = line.chars().collect();
        let mut j = lo;
        while j < chars.len() {
            let c = chars[j];
            match c {
                '{' => {
                    brace += 1;
                    entered = true;
                }
                '}' => {
                    brace -= 1;
                    if entered && brace == 0 {
                        finish_match(file, s, &arms, out);
                        return;
                    }
                    if in_body && brace == 1 && paren == 0 && bracket == 0 {
                        // A braced arm body just closed.
                        in_body = false;
                        cur.clear();
                        j += 1;
                        continue;
                    }
                }
                '(' => paren += 1,
                ')' => paren -= 1,
                '[' => bracket += 1,
                ']' => bracket -= 1,
                _ => {}
            }
            let top = entered && brace == 1 && paren == 0 && bracket == 0;
            if top && !in_body && c == '=' && chars.get(j + 1) == Some(&'>') {
                let pat: String = cur
                    .trim()
                    .trim_start_matches([',', '|'])
                    .trim()
                    .to_string();
                arms.push((i, pat));
                in_body = true;
                cur.clear();
                j += 2;
                continue;
            }
            if top && in_body && c == ',' {
                in_body = false;
                cur.clear();
            } else if !in_body && entered && brace >= 1 && c != '{' && c != '}' {
                cur.push(c);
            }
            j += 1;
        }
        if !in_body {
            cur.push(' ');
        }
    }
}

fn finish_match(file: &str, s: &Scrubbed, arms: &[(usize, String)], out: &mut Vec<Finding>) {
    let names_target = arms
        .iter()
        .any(|(_, p)| TARGET_ENUMS.iter().any(|e| p.starts_with(e)));
    if !names_target {
        return;
    }
    for (line, pat) in arms {
        if pat == "_" && !near_has(&s.comments, *line, ALLOW_WILDCARD_TAG) {
            out.push(Finding {
                file: file.to_string(),
                line: line + 1,
                rule: Rule::WildcardMatch,
                message: "bare `_` arm in a match over a protocol enum; spell the \
                          remaining variants out"
                    .to_string(),
            });
        }
    }
}

/// Lint one file's source text.
pub fn lint_source(file: &str, src: &str) -> Vec<Finding> {
    let s = scrub(src);
    let mut out = Vec::new();
    check_comment_tags(file, &s, &mut out);
    check_hot_paths(file, &s, &mut out);
    check_wildcard_matches(file, &s, &mut out);
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    out
}

/// The directories `adaptd lint` scans by default, relative to the crate
/// root.
pub fn default_paths() -> &'static [&'static str] {
    &["src", "benches", "tests"]
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root`/`rels[i]`; missing directories are
/// skipped silently so the default set works from any checkout shape.
pub fn lint_paths(root: &Path, rels: &[&str]) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for rel in rels {
        let dir = root.join(rel);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        } else if dir.is_file() {
            files.push(dir);
        }
    }
    files.sort();
    let mut out = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path)?;
        let name = path
            .strip_prefix(root)
            .unwrap_or(path)
            .display()
            .to_string();
        out.extend(lint_source(&name, &src));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(findings: &[Finding]) -> Vec<Rule> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unsafe_without_safety_fires_with_line() {
        let src = "fn f() {\n    let p = 0 as *const u8;\n    unsafe { p.read() };\n}\n";
        let f = lint_source("fixture.rs", src);
        assert_eq!(rules(&f), vec![Rule::UnsafeNeedsSafety]);
        assert_eq!(f[0].line, 3);
        assert_eq!(f[0].file, "fixture.rs");
        assert_eq!(
            f[0].to_string(),
            "fixture.rs:3: [unsafe-needs-safety] `unsafe` without a `SAFETY:` \
             comment within 6 lines"
        );
    }

    #[test]
    fn unsafe_with_safety_comment_is_clean() {
        let src = "fn f() {\n    let p = 0 as *const u8;\n    \
                   // SAFETY: p is valid for reads.\n    unsafe { p.read() };\n}\n";
        assert!(lint_source("fixture.rs", src).is_empty());
    }

    #[test]
    fn safety_comment_outside_window_does_not_count() {
        let mut src = String::from("// SAFETY: too far away.\n");
        for _ in 0..COMMENT_WINDOW {
            src.push_str("fn pad() {}\n");
        }
        src.push_str("fn f() { unsafe {} }\n");
        let f = lint_source("fixture.rs", &src);
        assert_eq!(rules(&f), vec![Rule::UnsafeNeedsSafety]);
        assert_eq!(f[0].line, COMMENT_WINDOW + 2);
    }

    #[test]
    fn unsafe_inside_string_or_identifier_is_ignored() {
        let src = "fn f() { let unsafe_ish = \"unsafe { }\"; let _ = unsafe_ish; }\n";
        assert!(lint_source("fixture.rs", src).is_empty());
    }

    #[test]
    fn relaxed_without_justification_fires() {
        let src = "fn f(a: &std::sync::atomic::AtomicU64) {\n    \
                   a.load(std::sync::atomic::Ordering::Relaxed);\n}\n";
        let f = lint_source("fixture.rs", src);
        assert_eq!(rules(&f), vec![Rule::RelaxedNeedsJustification]);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn relaxed_with_justification_is_clean() {
        let src = "fn f(a: &std::sync::atomic::AtomicU64) {\n    \
                   // RELAXED: stats counter, read only by reporting.\n    \
                   a.load(std::sync::atomic::Ordering::Relaxed);\n}\n";
        assert!(lint_source("fixture.rs", src).is_empty());
    }

    #[test]
    fn fenced_fn_with_alloc_fires() {
        let src = format!(
            "// {FENCE_TAG} — per-request, must not allocate.\n\
             fn hot(xs: &mut Vec<u32>) {{\n    xs.push(1);\n}}\n"
        );
        let f = lint_source("fixture.rs", &src);
        assert_eq!(rules(&f), vec![Rule::HotPathAlloc]);
        assert_eq!(f[0].line, 3);
        assert!(f[0].message.contains("fenced at line 1"));
    }

    #[test]
    fn fenced_alloc_waived_by_allow_comment() {
        let src = format!(
            "// {FENCE_TAG}\nfn hot(xs: &mut Vec<u32>) {{\n    \
             // {ALLOW_ALLOC_TAG} — capacity retained across calls.\n    xs.push(1);\n}}\n"
        );
        assert!(lint_source("fixture.rs", &src).is_empty());
    }

    #[test]
    fn unfenced_fn_may_allocate() {
        let src = "fn cold() -> String {\n    format!(\"x\")\n}\n";
        assert!(lint_source("fixture.rs", src).is_empty());
    }

    #[test]
    fn fence_does_not_reach_past_the_window() {
        let mut src = format!("// {FENCE_TAG}\n");
        for _ in 0..=FENCE_REACH {
            src.push_str("const PAD: u32 = 0;\n");
        }
        src.push_str("fn far() -> String { String::new() }\n");
        assert!(lint_source("fixture.rs", &src).is_empty());
    }

    #[test]
    fn wildcard_over_protocol_enum_fires() {
        let src = "fn f(c: KernelConfig) -> bool {\n    match c {\n        \
                   KernelConfig::Xgemm(_) => true,\n        _ => false,\n    }\n}\n";
        let f = lint_source("fixture.rs", src);
        assert_eq!(rules(&f), vec![Rule::WildcardMatch]);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn wildcard_waived_or_off_target_is_clean() {
        let waived = format!(
            "fn f(c: Admission) -> bool {{\n    match c {{\n        \
             Admission::Accepted {{ .. }} => true,\n        \
             // {ALLOW_WILDCARD_TAG} — refusal shapes are all terminal here.\n        \
             _ => false,\n    }}\n}}\n"
        );
        assert!(lint_source("fixture.rs", &waived).is_empty());
        // A match over a non-protocol enum may use `_` freely.
        let other = "fn f(x: Option<u32>) -> bool {\n    match x {\n        \
                     Some(1) => true,\n        _ => false,\n    }\n}\n";
        assert!(lint_source("fixture.rs", other).is_empty());
    }

    #[test]
    fn named_binding_arm_is_not_a_wildcard() {
        let src = "fn f(o: RequestOutcome) -> u32 {\n    match o {\n        \
                   RequestOutcome::Ok => 0,\n        other => id(other),\n    }\n}\n";
        assert!(lint_source("fixture.rs", src).is_empty());
    }

    #[test]
    fn nested_match_wildcard_is_still_found() {
        let src = "fn f(a: Option<Admission>) -> bool {\n    match a {\n        \
                   Some(inner) => match inner {\n            \
                   Admission::Accepted { .. } => true,\n            \
                   _ => false,\n        },\n        None => false,\n    }\n}\n";
        let f = lint_source("fixture.rs", src);
        assert_eq!(rules(&f), vec![Rule::WildcardMatch]);
        assert_eq!(f[0].line, 5);
    }

    #[test]
    fn findings_are_sorted_by_line() {
        let src = "fn f() { unsafe {} }\n\
                   fn g(a: &std::sync::atomic::AtomicU64) {\n    \
                   a.load(std::sync::atomic::Ordering::Relaxed);\n}\n\
                   fn h() { unsafe {} }\n";
        let f = lint_source("fixture.rs", src);
        let lines: Vec<usize> = f.iter().map(|x| x.line).collect();
        assert_eq!(lines, vec![1, 3, 5]);
    }

    #[test]
    fn scrubber_handles_raw_strings_and_chars() {
        let src = "fn f() -> (char, &'static str) {\n    \
                   let s = r#\"unsafe Relaxed vec!\"#;\n    let _ = s;\n    \
                   ('{', \"} match KernelConfig::\")\n}\n";
        assert!(lint_source("fixture.rs", src).is_empty());
    }
}
