//! CART training: greedy binary splits minimizing weighted Gini impurity,
//! bounded by the paper's H (max height) and L (min samples per leaf).

use crate::config::Triple;
use crate::dataset::ClassId;

use super::{features_of, model_name, DecisionTree, MinSamples, Node};

/// Training hyper-parameters — the paper's (H, L) sweep axes.
#[derive(Debug, Clone, Copy)]
pub struct TrainParams {
    /// Max height; `None` is the paper's "hMax" (grow until pure / L).
    pub max_depth: Option<u32>,
    pub min_samples_leaf: MinSamples,
}

impl TrainParams {
    pub fn name(&self) -> String {
        model_name(self.max_depth, self.min_samples_leaf)
    }

    /// The paper's full sweep: H in {1,2,4,8,Max} x L in
    /// {1,2,4,0.1,0.2,0.3,0.4,0.5} (Tables 5/6: 40 models).
    pub fn paper_sweep() -> Vec<TrainParams> {
        let heights = [Some(1), Some(2), Some(4), Some(8), None];
        let leaves = [
            MinSamples::Count(1),
            MinSamples::Count(2),
            MinSamples::Count(4),
            MinSamples::Frac(0.1),
            MinSamples::Frac(0.2),
            MinSamples::Frac(0.3),
            MinSamples::Frac(0.4),
            MinSamples::Frac(0.5),
        ];
        let mut out = Vec::new();
        for h in heights {
            for l in leaves {
                out.push(TrainParams { max_depth: h, min_samples_leaf: l });
            }
        }
        out
    }
}

/// Gini impurity of a class histogram.
fn gini(counts: &[u32], total: u32) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts
        .iter()
        .map(|&c| {
            let p = c as f64 / t;
            p * p
        })
        .sum::<f64>()
}

fn majority(counts: &[u32]) -> ClassId {
    counts
        .iter()
        .enumerate()
        .max_by_key(|(_, &c)| c)
        .map(|(i, _)| i as ClassId)
        .unwrap_or(0)
}

struct Builder<'a> {
    samples: &'a [([f64; 3], ClassId)],
    n_classes: usize,
    min_leaf: usize,
    max_depth: Option<u32>,
    nodes: Vec<Node>,
}

impl<'a> Builder<'a> {
    fn counts(&self, idx: &[u32]) -> Vec<u32> {
        let mut c = vec![0u32; self.n_classes];
        for &i in idx {
            c[self.samples[i as usize].1 as usize] += 1;
        }
        c
    }

    /// Find the best (feature, threshold) split of `idx`, or None.
    fn best_split(&self, idx: &[u32], parent_gini: f64) -> Option<(u8, f64, f64)> {
        let total = idx.len() as u32;
        let mut best: Option<(u8, f64, f64)> = None; // (feature, thresh, gini)
        for feature in 0..3u8 {
            // Sort sample indices by this feature.
            let mut order: Vec<u32> = idx.to_vec();
            order.sort_by(|&a, &b| {
                self.samples[a as usize].0[feature as usize]
                    .partial_cmp(&self.samples[b as usize].0[feature as usize])
                    .unwrap()
            });
            // Sweep split positions, maintaining left/right histograms.
            let mut left = vec![0u32; self.n_classes];
            let mut right = self.counts(idx);
            for i in 0..order.len() - 1 {
                let s = &self.samples[order[i] as usize];
                left[s.1 as usize] += 1;
                right[s.1 as usize] -= 1;
                let v = s.0[feature as usize];
                let v_next = self.samples[order[i + 1] as usize].0[feature as usize];
                if v == v_next {
                    continue; // can't split between equal values
                }
                let n_left = (i + 1) as u32;
                let n_right = total - n_left;
                if (n_left as usize) < self.min_leaf
                    || (n_right as usize) < self.min_leaf
                {
                    continue;
                }
                let g = (n_left as f64 * gini(&left, n_left)
                    + n_right as f64 * gini(&right, n_right))
                    / total as f64;
                // Like sklearn's CART, zero-improvement splits are
                // allowed (g == parent): XOR-like label patterns need
                // them to eventually purify.  Recursion still terminates
                // because both children are strictly smaller.
                if g < best.map_or(parent_gini + 1e-12, |(_, _, bg)| bg) {
                    best = Some((feature, (v + v_next) / 2.0, g));
                }
            }
        }
        best
    }

    /// Recursively build the subtree over `idx`; returns the node index.
    fn build(&mut self, idx: &[u32], depth: u32) -> u32 {
        let counts = self.counts(idx);
        let total = idx.len() as u32;
        let parent_gini = gini(&counts, total);

        let mut make_leaf = parent_gini == 0.0 || idx.len() < 2 * self.min_leaf;
        if let Some(h) = self.max_depth {
            if depth >= h {
                make_leaf = true;
            }
        }
        let split = if make_leaf { None } else { self.best_split(idx, parent_gini) };

        let node_i = self.nodes.len() as u32;
        match split {
            None => {
                self.nodes.push(Node::Leaf {
                    class: majority(&counts),
                    n_samples: total,
                });
            }
            Some((feature, threshold, _)) => {
                // Placeholder; fixed up after children are built.
                self.nodes.push(Node::Split { feature, threshold, left: 0, right: 0 });
                let (li, ri): (Vec<u32>, Vec<u32>) = idx.iter().partition(|&&i| {
                    self.samples[i as usize].0[feature as usize] < threshold
                });
                let left = self.build(&li, depth + 1);
                let right = self.build(&ri, depth + 1);
                if let Node::Split { left: l, right: r, .. } = &mut self.nodes[node_i as usize] {
                    *l = left;
                    *r = right;
                }
            }
        }
        node_i
    }
}

/// Train a CART tree on `(triple, class)` samples.
pub fn train(
    entries: &[(Triple, ClassId)],
    n_classes: usize,
    params: TrainParams,
) -> DecisionTree {
    assert!(!entries.is_empty(), "train on empty dataset");
    let samples: Vec<([f64; 3], ClassId)> = entries
        .iter()
        .map(|(t, c)| (features_of(*t), *c))
        .collect();
    let min_leaf = params.min_samples_leaf.resolve(samples.len());
    let mut b = Builder {
        samples: &samples,
        n_classes,
        min_leaf,
        max_depth: params.max_depth,
        nodes: Vec::new(),
    };
    let idx: Vec<u32> = (0..samples.len() as u32).collect();
    b.build(&idx, 0);
    DecisionTree { nodes: b.nodes, name: params.name() }
}

/// Train directly from a labeled dataset — the retrain entry point of the
/// online adaptation loop (`dtree::online`), which folds telemetry into a
/// [`LabeledDataset`](crate::dataset::LabeledDataset) and rebuilds the
/// tree from the merged data.
pub fn train_dataset(
    dataset: &crate::dataset::LabeledDataset,
    params: TrainParams,
) -> DecisionTree {
    train(&dataset.entries, dataset.classes.len(), params)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(m: u32, n: u32, k: u32) -> Triple {
        Triple::new(m, n, k)
    }

    #[test]
    fn learns_simple_cut() {
        // class 0 iff M < 100.
        let data: Vec<(Triple, ClassId)> = (1..50)
            .map(|i| (t(i, 10, 10), 0))
            .chain((100..150).map(|i| (t(i, 10, 10), 1)))
            .collect();
        let tree = train(
            &data,
            2,
            TrainParams { max_depth: None, min_samples_leaf: MinSamples::Count(1) },
        );
        assert_eq!(tree.predict(t(5, 10, 10)), 0);
        assert_eq!(tree.predict(t(120, 10, 10)), 1);
        assert_eq!(tree.n_leaves(), 2);
        assert_eq!(tree.depth(), 1);
    }

    #[test]
    fn pure_node_stops() {
        let data = vec![(t(1, 1, 1), 0), (t(2, 2, 2), 0), (t(3, 3, 3), 0)];
        let tree = train(
            &data,
            1,
            TrainParams { max_depth: None, min_samples_leaf: MinSamples::Count(1) },
        );
        assert_eq!(tree.n_leaves(), 1);
        assert_eq!(tree.depth(), 0);
    }

    #[test]
    fn max_depth_bounds_height() {
        // Alternating classes along M force deep trees if unbounded.
        let data: Vec<(Triple, ClassId)> =
            (0..64).map(|i| (t(i + 1, 1, 1), (i % 2) as ClassId)).collect();
        for h in [1u32, 2, 4] {
            let tree = train(
                &data,
                2,
                TrainParams {
                    max_depth: Some(h),
                    min_samples_leaf: MinSamples::Count(1),
                },
            );
            assert!(tree.depth() <= h, "depth {} > h {h}", tree.depth());
        }
    }

    #[test]
    fn min_samples_leaf_enforced() {
        let data: Vec<(Triple, ClassId)> =
            (0..100).map(|i| (t(i + 1, 1, 1), (i % 2) as ClassId)).collect();
        let tree = train(
            &data,
            2,
            TrainParams {
                max_depth: None,
                min_samples_leaf: MinSamples::Frac(0.4), // 40 samples per leaf
            },
        );
        for n in &tree.nodes {
            if let Node::Leaf { n_samples, .. } = n {
                assert!(*n_samples >= 40, "leaf with {n_samples} < 40");
            }
        }
    }

    #[test]
    fn frac_half_yields_stump_or_single_leaf() {
        // L = 0.5: every leaf needs half the data -> at most one split.
        let data: Vec<(Triple, ClassId)> =
            (0..40).map(|i| (t(i + 1, 1, 1), (i / 20) as ClassId)).collect();
        let tree = train(
            &data,
            2,
            TrainParams { max_depth: None, min_samples_leaf: MinSamples::Frac(0.5) },
        );
        assert!(tree.n_leaves() <= 2);
    }

    #[test]
    fn training_accuracy_perfect_when_separable() {
        // Separable in (M, K) — needs two levels.
        let mut data = Vec::new();
        for m in [10u32, 20, 200, 300] {
            for k in [10u32, 500] {
                let class = if m < 100 { 0 } else if k < 100 { 1 } else { 2 };
                data.push((t(m, 7, k), class));
            }
        }
        let tree = train(
            &data,
            3,
            TrainParams { max_depth: None, min_samples_leaf: MinSamples::Count(1) },
        );
        for (tr, c) in &data {
            assert_eq!(tree.predict(*tr), *c);
        }
    }

    #[test]
    fn train_dataset_matches_train_on_entries() {
        use crate::config::{DirectParams, KernelConfig, XgemmParams};
        use crate::dataset::{ClassTable, DatasetKind, LabeledDataset};
        let mut classes = ClassTable::new();
        let c0 = classes.intern(KernelConfig::Direct(DirectParams::default()));
        let c1 = classes.intern(KernelConfig::Xgemm(XgemmParams::default()));
        let ds = LabeledDataset {
            kind: DatasetKind::Po2,
            device: "sim".into(),
            entries: (1..40)
                .map(|i| (t(i * 16, 8, 8), if i < 20 { c0 } else { c1 }))
                .collect(),
            classes,
        };
        let params =
            TrainParams { max_depth: None, min_samples_leaf: MinSamples::Count(1) };
        let a = train_dataset(&ds, params);
        let b = train(&ds.entries, ds.classes.len(), params);
        assert_eq!(a.nodes, b.nodes);
    }

    #[test]
    fn paper_sweep_is_40_models() {
        assert_eq!(TrainParams::paper_sweep().len(), 40);
        let names: std::collections::HashSet<String> =
            TrainParams::paper_sweep().iter().map(|p| p.name()).collect();
        assert_eq!(names.len(), 40);
        assert!(names.contains("hMax-L1") && names.contains("h8-L0.1"));
    }
}
