//! CART decision-tree classifier, from scratch (paper §2.1, §4.2).
//!
//! Implements exactly the knobs the paper sweeps: `H` (max height, `None`
//! = unbounded, "hMax") and `L` (min samples per leaf, either an absolute
//! count or a fraction of the training set, as in scikit-learn).  Gini
//! impurity, binary splits on the three features (M, N, K).
//!
//! The trained model ships as data (flattened node array) *and* as
//! generated source (see `codegen`); no ML framework exists on-line —
//! which is the paper's deployment argument.

pub mod classifiers;
pub mod online;
mod train;

pub use classifiers::{classifier_accuracy, cross_validate, Classifier, KNearest, MajorityClass};
pub use online::{FoldReport, OnlineObservation, OnlineTrainer};
pub use train::{train, train_dataset, TrainParams};

use anyhow::{Context, Result};

use crate::config::Triple;
use crate::dataset::ClassId;
use crate::util::json::Json;

/// Minimum-samples-per-leaf policy (scikit-learn semantics: a fraction is
/// interpreted as `ceil(frac * n_samples)`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MinSamples {
    Count(usize),
    Frac(f64),
}

impl MinSamples {
    pub fn resolve(&self, n_samples: usize) -> usize {
        match self {
            MinSamples::Count(c) => (*c).max(1),
            MinSamples::Frac(f) => ((f * n_samples as f64).ceil() as usize).max(1),
        }
    }

    /// The paper's label for this setting ("L1", "L0.1", ...).
    pub fn label(&self) -> String {
        match self {
            MinSamples::Count(c) => format!("L{c}"),
            MinSamples::Frac(f) => format!("L{f}"),
        }
    }
}

/// One tree node, flattened into an array (cache-friendly traversal; the
/// on-line selector uses this directly).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Node {
    /// feature < threshold ? goto left : goto right
    Split { feature: u8, threshold: f64, left: u32, right: u32 },
    Leaf { class: ClassId, n_samples: u32 },
}

/// A trained decision tree over (M, N, K) features.
#[derive(Debug, Clone)]
pub struct DecisionTree {
    pub nodes: Vec<Node>,
    /// Model name in the paper's convention, e.g. "h8-L0.1".
    pub name: String,
}

pub const FEATURE_NAMES: [&str; 3] = ["M", "N", "K"];

pub fn features_of(t: Triple) -> [f64; 3] {
    [t.m as f64, t.n as f64, t.k as f64]
}

impl DecisionTree {
    /// Predict the class for a triple (iterative traversal, no allocation).
    pub fn predict(&self, t: Triple) -> ClassId {
        let f = features_of(t);
        let mut i = 0usize;
        loop {
            match self.nodes[i] {
                Node::Leaf { class, .. } => return class,
                Node::Split { feature, threshold, left, right } => {
                    i = if f[feature as usize] < threshold {
                        left as usize
                    } else {
                        right as usize
                    };
                }
            }
        }
    }

    /// Depth of the tree (root-only tree has depth 0, as in the paper's
    /// Table 5 where the single-leaf trees report height 0).
    pub fn depth(&self) -> u32 {
        fn rec(nodes: &[Node], i: usize) -> u32 {
            match nodes[i] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => {
                    1 + rec(nodes, left as usize).max(rec(nodes, right as usize))
                }
            }
        }
        rec(&self.nodes, 0)
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Leaf { .. })).count()
    }

    /// All leaf classes (with multiplicity).
    pub fn leaf_classes(&self) -> Vec<ClassId> {
        self.nodes
            .iter()
            .filter_map(|n| match n {
                Node::Leaf { class, .. } => Some(*class),
                _ => None,
            })
            .collect()
    }

    /// Depth of the deepest leaf reachable for a given triple domain —
    /// the §5.4 microbenchmark traverses to the deepest leaf.
    pub fn deepest_leaf_path(&self) -> Vec<usize> {
        fn rec(nodes: &[Node], i: usize, path: &mut Vec<usize>, best: &mut Vec<usize>) {
            path.push(i);
            match nodes[i] {
                Node::Leaf { .. } => {
                    if path.len() > best.len() {
                        *best = path.clone();
                    }
                }
                Node::Split { left, right, .. } => {
                    rec(nodes, left as usize, path, best);
                    rec(nodes, right as usize, path, best);
                }
            }
            path.pop();
        }
        let mut best = Vec::new();
        rec(&self.nodes, 0, &mut Vec::new(), &mut best);
        best
    }

    // ------------------------------------------------------- persistence

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            (
                "nodes",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| match n {
                            Node::Split { feature, threshold, left, right } => {
                                Json::obj(vec![
                                    ("f", Json::num(*feature as f64)),
                                    ("t", Json::num(*threshold)),
                                    ("l", Json::num(*left)),
                                    ("r", Json::num(*right)),
                                ])
                            }
                            Node::Leaf { class, n_samples } => Json::obj(vec![
                                ("c", Json::num(*class)),
                                ("n", Json::num(*n_samples)),
                            ]),
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let name = v.get("name")?.as_str()?.to_string();
        let mut nodes = Vec::new();
        for nj in v.get("nodes")?.as_arr()? {
            let obj = nj.as_obj()?;
            if obj.contains_key("c") {
                nodes.push(Node::Leaf {
                    class: nj.get("c")?.as_u32()?,
                    n_samples: nj.get("n")?.as_u32()?,
                });
            } else {
                nodes.push(Node::Split {
                    feature: nj.get("f")?.as_u32()? as u8,
                    threshold: nj.get("t")?.as_f64()?,
                    left: nj.get("l")?.as_u32()?,
                    right: nj.get("r")?.as_u32()?,
                });
            }
        }
        anyhow::ensure!(!nodes.is_empty(), "empty tree");
        // Validate child indices.
        for n in &nodes {
            if let Node::Split { left, right, .. } = n {
                anyhow::ensure!(
                    (*left as usize) < nodes.len() && (*right as usize) < nodes.len(),
                    "child index out of range"
                );
            }
        }
        Ok(DecisionTree { nodes, name })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// The paper's model-name convention: "h4-L1", "hMax-L0.1", ...
pub fn model_name(max_depth: Option<u32>, min_samples: MinSamples) -> String {
    let h = match max_depth {
        Some(h) => format!("h{h}"),
        None => "hMax".to_string(),
    };
    format!("{h}-{}", min_samples.label())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(class: ClassId) -> Node {
        Node::Leaf { class, n_samples: 1 }
    }

    #[test]
    fn predict_traverses_splits() {
        // if M < 100 then class 0 else (if K < 50 then 1 else 2)
        let tree = DecisionTree {
            nodes: vec![
                Node::Split { feature: 0, threshold: 100.0, left: 1, right: 2 },
                leaf(0),
                Node::Split { feature: 2, threshold: 50.0, left: 3, right: 4 },
                leaf(1),
                leaf(2),
            ],
            name: "t".into(),
        };
        assert_eq!(tree.predict(Triple::new(64, 1, 1)), 0);
        assert_eq!(tree.predict(Triple::new(128, 1, 10)), 1);
        assert_eq!(tree.predict(Triple::new(128, 1, 99)), 2);
        assert_eq!(tree.depth(), 2);
        assert_eq!(tree.n_leaves(), 3);
        assert_eq!(tree.deepest_leaf_path().len(), 3);
    }

    #[test]
    fn min_samples_resolution() {
        assert_eq!(MinSamples::Count(2).resolve(100), 2);
        assert_eq!(MinSamples::Frac(0.1).resolve(100), 10);
        assert_eq!(MinSamples::Frac(0.5).resolve(3), 2);
        assert_eq!(MinSamples::Count(0).resolve(5), 1);
    }

    #[test]
    fn model_names_match_paper() {
        assert_eq!(model_name(Some(4), MinSamples::Count(1)), "h4-L1");
        assert_eq!(model_name(None, MinSamples::Frac(0.1)), "hMax-L0.1");
    }

    #[test]
    fn json_roundtrip() {
        let tree = DecisionTree {
            nodes: vec![
                Node::Split { feature: 1, threshold: 12.5, left: 1, right: 2 },
                leaf(3),
                leaf(4),
            ],
            name: "h1-L1".into(),
        };
        let back = DecisionTree::from_json(&tree.to_json()).unwrap();
        assert_eq!(back.nodes, tree.nodes);
        assert_eq!(back.name, tree.name);
    }

    #[test]
    fn from_json_rejects_dangling_children() {
        let j = Json::parse(
            r#"{"name":"x","nodes":[{"f":0,"t":1,"l":5,"r":1},{"c":0,"n":1}]}"#,
        )
        .unwrap();
        assert!(DecisionTree::from_json(&j).is_err());
    }
}
