//! Alternative classifiers + cross-validation — the paper's §3 ("the
//! [CART] model ... can be replaced with any other suitable technique";
//! "traditional machine learning techniques, such as cross validation,
//! can also be applied") and §7 future work ("investigating advanced ML
//! techniques").  Used by the `adaptd exp ablation` study comparing
//! CART against simpler baselines on accuracy *and* DTPR.

use crate::config::Triple;
use crate::dataset::ClassId;

use super::{features_of, train, DecisionTree, TrainParams};

/// A trained input->class model.
pub trait Classifier {
    fn name(&self) -> String;
    fn predict(&self, t: Triple) -> ClassId;
}

impl Classifier for DecisionTree {
    fn name(&self) -> String {
        format!("cart:{}", self.name)
    }

    fn predict(&self, t: Triple) -> ClassId {
        DecisionTree::predict(self, t)
    }
}

/// Majority-class baseline: always predicts the most frequent label.
/// Any useful model must beat this.
pub struct MajorityClass {
    class: ClassId,
}

impl std::fmt::Debug for MajorityClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MajorityClass").finish_non_exhaustive()
    }
}

impl MajorityClass {
    pub fn fit(data: &[(Triple, ClassId)], n_classes: usize) -> MajorityClass {
        let mut counts = vec![0u32; n_classes];
        for (_, c) in data {
            counts[*c as usize] += 1;
        }
        let class = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i as ClassId)
            .unwrap_or(0);
        MajorityClass { class }
    }
}

impl Classifier for MajorityClass {
    fn name(&self) -> String {
        "majority".to_string()
    }

    fn predict(&self, _t: Triple) -> ClassId {
        self.class
    }
}

/// k-nearest-neighbours in log2 feature space: a natural competitor for
/// this problem (nearby triples often share best configs — paper §5.2),
/// but undeployable in a library (it must ship the training set), which
/// is the paper's argument for tree->codegen.
pub struct KNearest {
    k: usize,
    points: Vec<([f64; 3], ClassId)>,
    n_classes: usize,
}

impl std::fmt::Debug for KNearest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KNearest").finish_non_exhaustive()
    }
}

impl KNearest {
    pub fn fit(data: &[(Triple, ClassId)], n_classes: usize, k: usize) -> KNearest {
        KNearest {
            k: k.max(1),
            points: data.iter().map(|(t, c)| (log_features(*t), *c)).collect(),
            n_classes,
        }
    }
}

fn log_features(t: Triple) -> [f64; 3] {
    let f = features_of(t);
    [f[0].max(1.0).log2(), f[1].max(1.0).log2(), f[2].max(1.0).log2()]
}

impl Classifier for KNearest {
    fn name(&self) -> String {
        format!("knn-{}", self.k)
    }

    fn predict(&self, t: Triple) -> ClassId {
        let q = log_features(t);
        // Partial selection of the k nearest (training sets are small
        // enough that a full sort is fine; kept simple on purpose).
        let mut dists: Vec<(f64, ClassId)> = self
            .points
            .iter()
            .map(|(p, c)| {
                let d = (p[0] - q[0]).powi(2)
                    + (p[1] - q[1]).powi(2)
                    + (p[2] - q[2]).powi(2);
                (d, *c)
            })
            .collect();
        dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut votes = vec![0u32; self.n_classes];
        for (_, c) in dists.iter().take(self.k) {
            votes[*c as usize] += 1;
        }
        votes
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i as ClassId)
            .unwrap_or(0)
    }
}

/// Plain accuracy (%) of any classifier over a labeled set.
pub fn classifier_accuracy(c: &dyn Classifier, test: &[(Triple, ClassId)]) -> f64 {
    if test.is_empty() {
        return 0.0;
    }
    let right = test.iter().filter(|(t, l)| c.predict(*t) == *l).count();
    100.0 * right as f64 / test.len() as f64
}

/// k-fold cross-validation of a CART configuration: mean ± stddev of the
/// fold accuracies (the paper's suggested model-selection refinement).
pub fn cross_validate(
    data: &[(Triple, ClassId)],
    n_classes: usize,
    params: TrainParams,
    folds: usize,
    seed: u64,
) -> (f64, f64) {
    assert!(folds >= 2, "need at least 2 folds");
    let mut idx: Vec<usize> = (0..data.len()).collect();
    crate::util::prng::Rng::new(seed).shuffle(&mut idx);
    let fold_size = data.len().div_ceil(folds);
    let mut accs = Vec::with_capacity(folds);
    for f in 0..folds {
        let lo = f * fold_size;
        let hi = ((f + 1) * fold_size).min(data.len());
        if lo >= hi {
            continue;
        }
        let test: Vec<(Triple, ClassId)> = idx[lo..hi].iter().map(|&i| data[i]).collect();
        let train_set: Vec<(Triple, ClassId)> = idx[..lo]
            .iter()
            .chain(idx[hi..].iter())
            .map(|&i| data[i])
            .collect();
        if train_set.is_empty() {
            continue;
        }
        let tree = train(&train_set, n_classes, params);
        accs.push(classifier_accuracy(&tree, &test));
    }
    let mean = crate::util::stats::mean(&accs);
    let var = accs.iter().map(|a| (a - mean).powi(2)).sum::<f64>()
        / accs.len().max(1) as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtree::MinSamples;

    fn t(m: u32, n: u32, k: u32) -> Triple {
        Triple::new(m, n, k)
    }

    fn region_data() -> Vec<(Triple, ClassId)> {
        // class = 0 for small M, 1 for large M (clean regions).
        (1..120u32)
            .map(|i| {
                let tr = t(i * 16, 64, 64);
                (tr, u32::from(tr.m >= 1000))
            })
            .collect()
    }

    #[test]
    fn majority_predicts_mode() {
        let data = vec![(t(1, 1, 1), 0), (t(2, 2, 2), 1), (t(3, 3, 3), 1)];
        let m = MajorityClass::fit(&data, 2);
        assert_eq!(m.predict(t(9, 9, 9)), 1);
        assert_eq!(m.name(), "majority");
    }

    #[test]
    fn knn_learns_regions() {
        let data = region_data();
        let knn = KNearest::fit(&data, 2, 3);
        assert_eq!(knn.predict(t(32, 64, 64)), 0);
        assert_eq!(knn.predict(t(1800, 64, 64)), 1);
        let acc = classifier_accuracy(&knn, &data);
        assert!(acc > 95.0, "knn acc {acc}");
    }

    #[test]
    fn knn_beats_majority_on_structured_data() {
        let data = region_data();
        let knn = KNearest::fit(&data, 2, 3);
        let maj = MajorityClass::fit(&data, 2);
        assert!(
            classifier_accuracy(&knn, &data) > classifier_accuracy(&maj, &data)
        );
    }

    #[test]
    fn cart_implements_classifier_trait() {
        let data = region_data();
        let tree = train(
            &data,
            2,
            TrainParams { max_depth: None, min_samples_leaf: MinSamples::Count(1) },
        );
        let c: &dyn Classifier = &tree;
        assert!(c.name().starts_with("cart:"));
        assert!(classifier_accuracy(c, &data) > 99.0);
    }

    #[test]
    fn cross_validation_high_on_separable_data() {
        let data = region_data();
        let (mean, sd) = cross_validate(
            &data,
            2,
            TrainParams { max_depth: Some(4), min_samples_leaf: MinSamples::Count(1) },
            5,
            42,
        );
        assert!(mean > 90.0, "cv mean {mean}");
        assert!(sd < 15.0, "cv sd {sd}");
    }

    #[test]
    fn cross_validation_deterministic() {
        let data = region_data();
        let p = TrainParams { max_depth: Some(2), min_samples_leaf: MinSamples::Count(1) };
        assert_eq!(
            cross_validate(&data, 2, p, 4, 7),
            cross_validate(&data, 2, p, 4, 7)
        );
    }
}
