//! Online adaptation of the trained model — the closed loop the frozen
//! paper pipeline lacks: live telemetry (served triple, measured service
//! time, optional shadow-measured alternative) is folded back into the
//! labeled dataset, a misprediction-rate trigger decides when the CART is
//! retrained, and the coordinator hot-swaps the resulting policy (see
//! `coordinator::adapt`).
//!
//! This module is pure model/dataset logic: it knows nothing about
//! threads, rings, or policies, which keeps it unit-testable without a
//! runtime and keeps the dependency direction `coordinator -> dtree`.

use crate::config::{KernelConfig, Triple};
use crate::dataset::{LabeledDataset, UpsertOutcome};

use super::train::{train_dataset, TrainParams};
use super::DecisionTree;

/// One live observation, distilled from the coordinator's telemetry tap.
#[derive(Debug, Clone, Copy)]
pub struct OnlineObservation {
    pub triple: Triple,
    /// Configuration that actually served the request.
    pub served: KernelConfig,
    /// Measured service seconds of the served configuration (pad + execute,
    /// compile excluded).
    pub served_secs: f64,
    /// Shadow-measured alternative, if the shard spent shadow budget on
    /// this request: (config, seconds) under identical operands.
    pub shadow: Option<(KernelConfig, f64)>,
}

impl OnlineObservation {
    /// The winning configuration of this observation: the shadow
    /// alternative if it beat the served config by more than `margin`
    /// (relative), otherwise the served config.  The margin absorbs
    /// single-measurement noise so near-ties never flap labels.
    pub fn winner(&self, margin: f64) -> KernelConfig {
        match self.shadow {
            Some((cfg, secs)) if secs * (1.0 + margin) < self.served_secs => cfg,
            _ => self.served,
        }
    }
}

/// What one [`OnlineTrainer::fold`] call did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FoldReport {
    /// Observations folded into the dataset.
    pub folded: usize,
    /// Entries whose stored label changed (new triples included).
    pub relabeled: usize,
    /// Observations where the current tree disagreed with the folded
    /// label — the numerator of the retrain trigger.
    pub mispredicted: usize,
}

/// Incremental dataset maintenance + retrain trigger.
///
/// The trainer owns the *living* labeled dataset and the current tree.
/// [`fold`](Self::fold) merges telemetry (relabeling a triple when a
/// shadow-measured alternative beat the served config);
/// [`should_retrain`](Self::should_retrain) fires once the observed
/// misprediction rate since the last retrain crosses the threshold;
/// [`retrain`](Self::retrain) rebuilds the CART from the merged dataset.
pub struct OnlineTrainer {
    dataset: LabeledDataset,
    tree: DecisionTree,
    params: TrainParams,
    /// Retrain once `mispredicted / seen >= threshold` (default 0.2).
    pub mispredict_threshold: f64,
    /// Relative margin a shadow measurement must win by to relabel
    /// (default 0.05 = 5%).
    pub shadow_margin: f64,
    /// Minimum observations since the last retrain before the trigger may
    /// fire (default 16) — keeps one noisy record from forcing a retrain.
    pub min_observations: usize,
    seen: usize,
    mispredicted: usize,
    retrains: usize,
}

impl std::fmt::Debug for OnlineTrainer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OnlineTrainer").finish_non_exhaustive()
    }
}

impl OnlineTrainer {
    /// Build from an initial dataset; trains the initial tree eagerly.
    /// Panics if the dataset is empty (nothing to train on).
    pub fn new(dataset: LabeledDataset, params: TrainParams) -> OnlineTrainer {
        assert!(!dataset.is_empty(), "online trainer needs a seed dataset");
        let tree = train_dataset(&dataset, params);
        OnlineTrainer {
            dataset,
            tree,
            params,
            mispredict_threshold: 0.2,
            shadow_margin: 0.05,
            min_observations: 16,
            seen: 0,
            mispredicted: 0,
            retrains: 0,
        }
    }

    pub fn tree(&self) -> &DecisionTree {
        &self.tree
    }

    pub fn dataset(&self) -> &LabeledDataset {
        &self.dataset
    }

    /// Observations folded since the last retrain.
    pub fn observed(&self) -> usize {
        self.seen
    }

    pub fn retrains(&self) -> usize {
        self.retrains
    }

    /// Misprediction rate since the last retrain (0.0 when nothing seen).
    pub fn mispredict_rate(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.mispredicted as f64 / self.seen as f64
        }
    }

    /// Fold telemetry into the dataset: each observation's winning config
    /// becomes (or confirms) the label for its triple.
    pub fn fold(&mut self, observations: &[OnlineObservation]) -> FoldReport {
        let mut report = FoldReport::default();
        for obs in observations {
            let label = self.dataset.classes.intern(obs.winner(self.shadow_margin));
            if self.tree.predict(obs.triple) != label {
                report.mispredicted += 1;
            }
            if self.dataset.upsert(obs.triple, label) != UpsertOutcome::Unchanged {
                report.relabeled += 1;
            }
            report.folded += 1;
        }
        self.seen += report.folded;
        self.mispredicted += report.mispredicted;
        report
    }

    /// Has the misprediction rate crossed the retrain threshold?
    pub fn should_retrain(&self) -> bool {
        self.seen >= self.min_observations
            && self.mispredict_rate() >= self.mispredict_threshold
    }

    /// Rebuild the tree from the merged dataset and reset the trigger
    /// window.  Returns the new tree (also readable via [`tree`](Self::tree)).
    pub fn retrain(&mut self) -> &DecisionTree {
        self.tree = train_dataset(&self.dataset, self.params);
        self.seen = 0;
        self.mispredicted = 0;
        self.retrains += 1;
        &self.tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DirectParams, XgemmParams};
    use crate::dataset::{ClassTable, DatasetKind};
    use crate::dtree::MinSamples;

    fn direct() -> KernelConfig {
        KernelConfig::Direct(DirectParams::default())
    }

    fn xgemm() -> KernelConfig {
        KernelConfig::Xgemm(XgemmParams::default())
    }

    /// Seed dataset: everything labeled `direct` — deliberately wrong for
    /// large triples, so telemetry has something to correct.
    fn seed() -> LabeledDataset {
        let mut classes = ClassTable::new();
        let c = classes.intern(direct());
        LabeledDataset {
            kind: DatasetKind::Po2,
            device: "sim".into(),
            entries: (1..=8).map(|i| (Triple::new(i * 32, 32, 32), c)).collect(),
            classes,
        }
    }

    fn obs(t: Triple, served: KernelConfig, secs: f64) -> OnlineObservation {
        OnlineObservation { triple: t, served, served_secs: secs, shadow: None }
    }

    #[test]
    fn winner_prefers_shadow_only_beyond_margin() {
        let t = Triple::new(64, 64, 64);
        let mut o = obs(t, direct(), 1.0);
        o.shadow = Some((xgemm(), 0.98)); // within 5% margin: served wins
        assert_eq!(o.winner(0.05), direct());
        o.shadow = Some((xgemm(), 0.5)); // clearly faster: shadow wins
        assert_eq!(o.winner(0.05), xgemm());
    }

    #[test]
    fn fold_counts_and_relabels() {
        let mut tr = OnlineTrainer::new(seed(), TrainParams {
            max_depth: None,
            min_samples_leaf: MinSamples::Count(1),
        });
        // Confirming observation: served config == current label.
        let confirm = obs(Triple::new(32, 32, 32), direct(), 1.0);
        // Correcting observation: big triple actually ran xgemm faster.
        let mut correct = obs(Triple::new(256, 32, 32), direct(), 1.0);
        correct.shadow = Some((xgemm(), 0.4));
        let report = tr.fold(&[confirm, correct]);
        assert_eq!(report.folded, 2);
        assert_eq!(report.relabeled, 1);
        assert_eq!(report.mispredicted, 1);
        assert!((tr.mispredict_rate() - 0.5).abs() < 1e-12);
        // The dataset now holds the corrected label.
        let c_x = tr.dataset().classes.len() - 1;
        assert!(tr
            .dataset()
            .entries
            .iter()
            .any(|&(t, c)| t == Triple::new(256, 32, 32) && c as usize == c_x));
    }

    #[test]
    fn retrain_trigger_fires_then_resets() {
        let mut tr = OnlineTrainer::new(seed(), TrainParams {
            max_depth: None,
            min_samples_leaf: MinSamples::Count(1),
        });
        tr.min_observations = 4;
        // Four corrections on large triples: 100% misprediction rate.
        let corrections: Vec<OnlineObservation> = (1..=4)
            .map(|i| {
                let mut o = obs(Triple::new(512 + i * 32, 32, 32), direct(), 1.0);
                o.shadow = Some((xgemm(), 0.2));
                o
            })
            .collect();
        tr.fold(&corrections);
        assert!(tr.should_retrain());
        let before = tr.tree().n_leaves();
        tr.retrain();
        assert_eq!(tr.retrains(), 1);
        assert_eq!(tr.observed(), 0);
        assert!(!tr.should_retrain());
        // The retrained tree now routes large triples to xgemm.
        let c_x = tr.dataset().classes.len() as u32 - 1;
        assert_eq!(tr.tree().predict(Triple::new(600, 32, 32)), c_x);
        assert!(tr.tree().n_leaves() >= before);
    }

    #[test]
    fn below_min_observations_never_retrains() {
        let mut tr = OnlineTrainer::new(seed(), TrainParams {
            max_depth: None,
            min_samples_leaf: MinSamples::Count(1),
        });
        tr.min_observations = 16;
        let mut o = obs(Triple::new(999, 32, 32), direct(), 1.0);
        o.shadow = Some((xgemm(), 0.1));
        tr.fold(&[o]);
        assert!((tr.mispredict_rate() - 1.0).abs() < 1e-12);
        assert!(!tr.should_retrain(), "one record must not force a retrain");
    }
}
