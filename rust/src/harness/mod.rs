//! Criterion-lite benchmark harness (no criterion crate in the offline
//! image): warmup, calibrated iteration counts, MAD outlier filtering and
//! a compact report.  Used by every `cargo bench` target (`harness =
//! false` in Cargo.toml).

use std::time::{Duration, Instant};

use crate::util::stats::{filter_outliers, Summary};

/// Configuration for one benchmark run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum timed samples regardless of duration budget.
    pub min_samples: usize,
    /// Maximum timed samples (caps very fast benchmarks).
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
            max_samples: 10_000,
        }
    }
}

impl BenchConfig {
    /// Fast settings for CI / smoke runs.
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_samples: 5,
            max_samples: 1_000,
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration (outlier-filtered).
    pub summary: Summary,
    pub iterations: usize,
}

impl BenchResult {
    pub fn per_iter(&self) -> Duration {
        Duration::from_secs_f64(self.summary.median)
    }

    pub fn report_line(&self) -> String {
        let med = self.summary.median;
        let (val, unit) = humanize(med);
        format!(
            "{:<44} {:>9.3} {}  (mean {:.3} ±{:.3} {u2}, n={})",
            self.name,
            val,
            unit,
            humanize(self.summary.mean).0,
            humanize(self.summary.ci95_half()).0,
            self.iterations,
            u2 = humanize(self.summary.mean).1,
        )
    }
}

fn humanize(seconds: f64) -> (f64, &'static str) {
    if seconds >= 1.0 {
        (seconds, "s ")
    } else if seconds >= 1e-3 {
        (seconds * 1e3, "ms")
    } else if seconds >= 1e-6 {
        (seconds * 1e6, "µs")
    } else {
        (seconds * 1e9, "ns")
    }
}

/// Run one benchmark: `f` is called once per sample; its return value is
/// black-boxed so the computation cannot be optimized away.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup.
    let t0 = Instant::now();
    let mut warm_iters = 0u64;
    while t0.elapsed() < cfg.warmup || warm_iters < 3 {
        black_box(f());
        warm_iters += 1;
    }
    // Measure.
    let mut samples = Vec::new();
    let t1 = Instant::now();
    while (t1.elapsed() < cfg.measure || samples.len() < cfg.min_samples)
        && samples.len() < cfg.max_samples
    {
        let s = Instant::now();
        black_box(f());
        samples.push(s.elapsed().as_secs_f64());
    }
    let filtered = filter_outliers(&samples, 8.0);
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&filtered),
        iterations: samples.len(),
    }
}

/// Opaque value barrier (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Simple suite runner for `harness = false` bench binaries: respects
/// the substring filter argv convention of `cargo bench -- <filter>` and
/// the `ADAPTLIB_BENCH_QUICK` env var.
pub struct Suite {
    cfg: BenchConfig,
    filter: Option<String>,
    pub results: Vec<BenchResult>,
}

impl std::fmt::Debug for Suite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Suite").finish_non_exhaustive()
    }
}

impl Suite {
    pub fn from_args() -> Suite {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'));
        let cfg = if std::env::var("ADAPTLIB_BENCH_QUICK").is_ok() {
            BenchConfig::quick()
        } else {
            BenchConfig::default()
        };
        Suite { cfg, filter, results: Vec::new() }
    }

    pub fn with_config(cfg: BenchConfig) -> Suite {
        Suite { cfg, filter: None, results: Vec::new() }
    }

    /// Run a benchmark if it passes the filter; prints the report line.
    pub fn bench<T>(&mut self, name: &str, f: impl FnMut() -> T) {
        if let Some(ref flt) = self.filter {
            if !name.contains(flt.as_str()) {
                return;
            }
        }
        let r = bench(name, &self.cfg, f);
        println!("{}", r.report_line());
        self.results.push(r);
    }

    /// Print a section header.
    pub fn section(&self, title: &str) {
        println!("\n== {title} ==");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig::quick();
        let r = bench("noop-sum", &cfg, || (0..100u64).sum::<u64>());
        assert!(r.summary.median > 0.0);
        assert!(r.iterations >= cfg.min_samples);
    }

    #[test]
    fn bench_ordering_sane() {
        let cfg = BenchConfig::quick();
        let fast = bench("fast", &cfg, || (0..10u64).sum::<u64>());
        let slow = bench("slow", &cfg, || {
            let mut v: Vec<u64> = (0..20_000).collect();
            v.reverse();
            v.iter().sum::<u64>()
        });
        assert!(
            slow.summary.median > fast.summary.median,
            "slow {} !> fast {}",
            slow.summary.median,
            fast.summary.median
        );
    }

    #[test]
    fn humanize_units() {
        assert_eq!(humanize(2.0).1, "s ");
        assert_eq!(humanize(2e-3).1, "ms");
        assert_eq!(humanize(2e-6).1, "µs");
        assert_eq!(humanize(2e-9).1, "ns");
    }

    #[test]
    fn report_line_contains_name() {
        let cfg = BenchConfig::quick();
        let r = bench("xyzzy", &cfg, || 1 + 1);
        assert!(r.report_line().contains("xyzzy"));
    }
}
