//! Search-space definition and enumeration — reproduces Table 1 of the
//! paper exactly: the `xgemm` grid has 14 parameters and 8748 raw points
//! (3^7 · 2^2), the `xgemm_direct` grid has 9 parameters and 3888 points
//! (3^5 · 2^4).  Structural + device legality then filters the grid, as
//! CLTune's constraint system does.

use super::{DirectParams, KernelConfig, XgemmParams};

/// One tunable parameter: name + the values the tuner may assign.
#[derive(Debug, Clone)]
pub struct ParamDef {
    pub name: &'static str,
    pub values: Vec<u32>,
}

impl ParamDef {
    fn new(name: &'static str, values: &[u32]) -> Self {
        ParamDef { name, values: values.to_vec() }
    }
}

/// A kernel's full tuning space.
#[derive(Debug, Clone)]
pub struct ConfigSpace {
    pub kernel: &'static str,
    pub params: Vec<ParamDef>,
    /// Materializes the config at a mixed-radix index of the raw grid.
    builder: fn(&[u32]) -> KernelConfig,
}

impl ConfigSpace {
    /// Raw grid size: the product of per-parameter value counts (Table 1's
    /// "Search Space Size" column).
    pub fn raw_size(&self) -> u64 {
        self.params.iter().map(|p| p.values.len() as u64).product()
    }

    pub fn num_params(&self) -> usize {
        self.params.len()
    }

    /// Materialize the configuration at raw-grid index `idx` (mixed radix).
    pub fn at(&self, idx: u64) -> KernelConfig {
        let mut assignment = Vec::with_capacity(self.params.len());
        let mut rem = idx;
        for p in &self.params {
            let radix = p.values.len() as u64;
            assignment.push(p.values[(rem % radix) as usize]);
            rem /= radix;
        }
        debug_assert_eq!(rem, 0, "index {idx} out of range");
        (self.builder)(&assignment)
    }

    /// Iterate the entire raw grid.
    pub fn iter(&self) -> impl Iterator<Item = KernelConfig> + '_ {
        (0..self.raw_size()).map(move |i| self.at(i))
    }

    /// All structurally legal configurations.
    pub fn structurally_legal(&self) -> Vec<KernelConfig> {
        self.iter().filter(|c| c.is_structurally_legal()).collect()
    }
}

/// The paper's xgemm tuning grid (Table 1 row 1: 14 params, 8748 points).
pub fn xgemm_space() -> ConfigSpace {
    ConfigSpace {
        kernel: "xgemm",
        params: vec![
            ParamDef::new("MWG", &[32, 64, 128]),
            ParamDef::new("NWG", &[32, 64, 128]),
            ParamDef::new("KWG", &[16, 32, 64]),
            ParamDef::new("MDIMC", &[8, 16, 32]),
            ParamDef::new("NDIMC", &[8, 16, 32]),
            ParamDef::new("MDIMA", &[16]),
            ParamDef::new("NDIMB", &[16]),
            ParamDef::new("KWI", &[2]),
            ParamDef::new("VWM", &[1, 2, 4]),
            ParamDef::new("VWN", &[1, 2, 4]),
            ParamDef::new("STRM", &[0]),
            ParamDef::new("STRN", &[0]),
            ParamDef::new("SA", &[0, 1]),
            ParamDef::new("SB", &[0, 1]),
        ],
        builder: |a| {
            KernelConfig::Xgemm(XgemmParams {
                mwg: a[0],
                nwg: a[1],
                kwg: a[2],
                mdimc: a[3],
                ndimc: a[4],
                mdima: a[5],
                ndimb: a[6],
                kwi: a[7],
                vwm: a[8],
                vwn: a[9],
                strm: a[10],
                strn: a[11],
                sa: a[12],
                sb: a[13],
            })
        },
    }
}

/// The paper's xgemm_direct grid (Table 1 row 2: 9 params, 3888 points).
pub fn direct_space() -> ConfigSpace {
    ConfigSpace {
        kernel: "xgemm_direct",
        params: vec![
            ParamDef::new("WGD", &[8, 16, 32]),
            ParamDef::new("MDIMCD", &[8, 16, 32]),
            ParamDef::new("NDIMCD", &[8, 16, 32]),
            ParamDef::new("MDIMAD", &[8, 16]),
            ParamDef::new("VWMD", &[1, 2, 4]),
            ParamDef::new("VWND", &[1, 2, 4]),
            ParamDef::new("KWID", &[2, 8]),
            ParamDef::new("PADA", &[0, 1]),
            ParamDef::new("PADB", &[0, 1]),
        ],
        builder: |a| {
            KernelConfig::Direct(DirectParams {
                wgd: a[0],
                mdimcd: a[1],
                ndimcd: a[2],
                mdimad: a[3],
                vwmd: a[4],
                vwnd: a[5],
                kwid: a[6],
                pada: a[7],
                padb: a[8],
            })
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn table1_raw_sizes_exact() {
        // The paper's Table 1.
        assert_eq!(xgemm_space().raw_size(), 8748);
        assert_eq!(xgemm_space().num_params(), 14);
        assert_eq!(direct_space().raw_size(), 3888);
        assert_eq!(direct_space().num_params(), 9);
    }

    #[test]
    fn enumeration_is_unique() {
        let s = xgemm_space();
        let all: HashSet<String> = s.iter().map(|c| c.name()).collect();
        // Pinned params don't appear in the name; distinct names = distinct
        // behavioural configs.
        assert_eq!(all.len() as u64, s.raw_size());
    }

    #[test]
    fn structurally_legal_subset_nonempty_and_smaller() {
        let s = xgemm_space();
        let legal = s.structurally_legal();
        assert!(!legal.is_empty());
        assert!((legal.len() as u64) < s.raw_size());
        assert!(legal.iter().all(|c| c.is_structurally_legal()));

        let d = direct_space();
        let legal_d = d.structurally_legal();
        assert!(!legal_d.is_empty());
        assert!((legal_d.len() as u64) < d.raw_size());
    }

    #[test]
    fn at_roundtrips_first_and_last() {
        let s = direct_space();
        let first = s.at(0);
        let last = s.at(s.raw_size() - 1);
        assert_ne!(first, last);
        if let KernelConfig::Direct(p) = first {
            assert_eq!(p.wgd, 8);
        } else {
            panic!("wrong kind");
        }
    }

    #[test]
    fn default_configs_inside_grid() {
        // CLBlast's defaults must be reachable points of the search space.
        let x = KernelConfig::Xgemm(XgemmParams::default());
        assert!(xgemm_space().iter().any(|c| c == x));
        let d = KernelConfig::Direct(DirectParams::default());
        assert!(direct_space().iter().any(|c| c == d));
    }
}
