//! The `xgemm_direct` kernel's 9-parameter tuning space — CLBlast's
//! generic one-pass GEMM kernel.  The grid reproduces Table 1: exactly
//! 3888 = 3^5 · 2^4 raw points over 9 parameters.

use crate::util::json::{Json, JsonError};

/// Full xgemm_direct parameter assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DirectParams {
    /// Square work-group tile (the direct kernel tiles M, N and K by WGD).
    pub wgd: u32,
    /// Threads in M.
    pub mdimcd: u32,
    /// Threads in N.
    pub ndimcd: u32,
    /// Re-shaped tile for loading A.
    pub mdimad: u32,
    /// Vector width for A.
    pub vwmd: u32,
    /// Vector width for B.
    pub vwnd: u32,
    /// K-loop unroll.
    pub kwid: u32,
    /// Pad A accesses (bounds-check strategy).
    pub pada: u32,
    /// Pad B accesses.
    pub padb: u32,
}

impl Default for DirectParams {
    /// CLBlast's shipped default (tuned for M=N=K=256).
    fn default() -> Self {
        DirectParams {
            wgd: 32,
            mdimcd: 8,
            ndimcd: 8,
            mdimad: 8,
            vwmd: 2,
            vwnd: 2,
            kwid: 2,
            pada: 1,
            padb: 1,
        }
    }
}

impl DirectParams {
    pub fn mwid(&self) -> u32 {
        self.wgd / self.mdimcd
    }

    pub fn nwid(&self) -> u32 {
        self.wgd / self.ndimcd
    }

    pub fn is_structurally_legal(&self) -> bool {
        self.wgd % self.mdimcd == 0
            && self.wgd % self.ndimcd == 0
            && self.wgd % self.kwid == 0
            && self.wgd % self.mdimad == 0
            && self.mwid() % self.vwmd == 0
            && self.nwid() % self.vwnd == 0
            && self.pada <= 1
            && self.padb <= 1
    }

    /// VMEM bytes per grid step: three WGD x WGD f32 tiles.
    pub fn scratch_bytes(&self) -> u64 {
        3 * (self.wgd as u64 * self.wgd as u64) * 4
    }

    /// Local-memory analogue (the direct kernel always stages both tiles).
    pub fn local_mem_bytes(&self) -> u64 {
        2 * (self.wgd as u64 * self.wgd as u64) * 4
    }

    pub fn name(&self) -> String {
        format!(
            "d_w{}_c{}x{}_a{}_v{}x{}_k{}_p{}{}",
            self.wgd,
            self.mdimcd,
            self.ndimcd,
            self.mdimad,
            self.vwmd,
            self.vwnd,
            self.kwid,
            self.pada,
            self.padb
        )
    }

    pub fn fingerprint(&self) -> u64 {
        let fields = [
            self.wgd, self.mdimcd, self.ndimcd, self.mdimad, self.vwmd,
            self.vwnd, self.kwid, self.pada, self.padb,
        ];
        fields
            .iter()
            .fold(0x8422_2325_cbf2_9ce4u64, |h, &f| {
                (h ^ f as u64).wrapping_mul(0x100_0000_01b3)
            })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wgd", Json::num(self.wgd)),
            ("mdimcd", Json::num(self.mdimcd)),
            ("ndimcd", Json::num(self.ndimcd)),
            ("mdimad", Json::num(self.mdimad)),
            ("vwmd", Json::num(self.vwmd)),
            ("vwnd", Json::num(self.vwnd)),
            ("kwid", Json::num(self.kwid)),
            ("pada", Json::num(self.pada)),
            ("padb", Json::num(self.padb)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        let g = |k: &str| -> Result<u32, JsonError> { v.get(k)?.as_u32() };
        Ok(DirectParams {
            wgd: g("wgd")?,
            mdimcd: g("mdimcd")?,
            ndimcd: g("ndimcd")?,
            mdimad: v.get_or("mdimad", &Json::Num(8.0)).as_u32()?,
            vwmd: g("vwmd")?,
            vwnd: g("vwnd")?,
            kwid: g("kwid")?,
            pada: g("pada")?,
            padb: g("padb")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_legal() {
        assert!(DirectParams::default().is_structurally_legal());
    }

    #[test]
    fn illegal_vector_width() {
        let p = DirectParams { wgd: 8, mdimcd: 8, vwmd: 2, ..Default::default() };
        // mwid = 1, 1 % 2 != 0
        assert!(!p.is_structurally_legal());
    }

    #[test]
    fn scratch() {
        assert_eq!(DirectParams { wgd: 16, ..Default::default() }.scratch_bytes(),
                   3 * 16 * 16 * 4);
    }

    #[test]
    fn json_roundtrip() {
        let p = DirectParams { wgd: 16, kwid: 8, pada: 0, ..Default::default() };
        assert_eq!(DirectParams::from_json(&p.to_json()).unwrap(), p);
    }
}
