//! Tuning-configuration model: the two CLBlast GEMM kernels, their full
//! parameter spaces (Table 1 of the paper: 14 parameters / 8748 points for
//! `xgemm`, 9 parameters / 3888 points for `xgemm_direct`), structural and
//! device legality, and the `(M, N, K)` input triples.

mod direct;
mod host;
mod space;
mod xgemm;

pub use direct::DirectParams;
pub use host::{host_variants, HostParams, SimdTier, MAX_TILE};
pub use space::{direct_space, xgemm_space, ConfigSpace, ParamDef};
pub use xgemm::XgemmParams;

use crate::util::json::{Json, JsonError};

/// A GEMM problem instance: the paper's input description `I`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Triple {
    pub m: u32,
    pub n: u32,
    pub k: u32,
}

impl Triple {
    pub const fn new(m: u32, n: u32, k: u32) -> Self {
        Triple { m, n, k }
    }

    /// FLOPs of the multiply-accumulate: 2·M·N·K.
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Total operand+result elements (f32 words moved at least once).
    pub fn footprint_elems(&self) -> u64 {
        self.m as u64 * self.k as u64
            + self.k as u64 * self.n as u64
            + self.m as u64 * self.n as u64
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(vec![
            Json::num(self.m),
            Json::num(self.n),
            Json::num(self.k),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        let a = v.as_arr()?;
        Ok(Triple::new(a[0].as_u32()?, a[1].as_u32()?, a[2].as_u32()?))
    }
}

impl std::fmt::Display for Triple {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{},{})", self.m, self.n, self.k)
    }
}

/// Which GEMM kernel a configuration belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// The tiled "indirect" kernel (O(n^3) fast path + O(n^2) pad helpers).
    Xgemm,
    /// The generic one-pass "direct" kernel.
    XgemmDirect,
    /// The host SIMD microkernel family (multi-versioned: instruction
    /// tier × register tile × unroll, dispatched at runtime).
    HostSimd,
}

impl KernelKind {
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Xgemm => "xgemm",
            KernelKind::XgemmDirect => "xgemm_direct",
            KernelKind::HostSimd => "host_simd",
        }
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One point in the union search space: kernel + its parameter assignment.
/// This is the paper's *class description* `C`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelConfig {
    Xgemm(XgemmParams),
    Direct(DirectParams),
    HostSimd(HostParams),
}

impl KernelConfig {
    pub fn kind(&self) -> KernelKind {
        match self {
            KernelConfig::Xgemm(_) => KernelKind::Xgemm,
            KernelConfig::Direct(_) => KernelKind::XgemmDirect,
            KernelConfig::HostSimd(_) => KernelKind::HostSimd,
        }
    }

    /// Stable unique name (doubles as the class label in datasets).
    pub fn name(&self) -> String {
        match self {
            KernelConfig::Xgemm(p) => p.name(),
            KernelConfig::Direct(p) => p.name(),
            KernelConfig::HostSimd(p) => p.name(),
        }
    }

    /// Structural legality (independent of device).
    pub fn is_structurally_legal(&self) -> bool {
        match self {
            KernelConfig::Xgemm(p) => p.is_structurally_legal(),
            KernelConfig::Direct(p) => p.is_structurally_legal(),
            KernelConfig::HostSimd(p) => p.is_structurally_legal(),
        }
    }

    /// VMEM / local-memory footprint in bytes for one work-group/grid step.
    pub fn scratch_bytes(&self) -> u64 {
        match self {
            KernelConfig::Xgemm(p) => p.scratch_bytes(),
            KernelConfig::Direct(p) => p.scratch_bytes(),
            KernelConfig::HostSimd(p) => p.scratch_bytes(),
        }
    }

    /// "Work-group size" analogue (threads per group in CLBlast terms —
    /// the microkernel tile for the host family, which has no work-groups).
    pub fn workgroup_size(&self) -> u32 {
        match self {
            KernelConfig::Xgemm(p) => p.mdimc * p.ndimc,
            KernelConfig::Direct(p) => p.mdimcd * p.ndimcd,
            KernelConfig::HostSimd(p) => p.mr * p.nr,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            KernelConfig::Xgemm(p) => Json::obj(vec![
                ("kernel", Json::str("xgemm")),
                ("params", p.to_json()),
            ]),
            KernelConfig::Direct(p) => Json::obj(vec![
                ("kernel", Json::str("xgemm_direct")),
                ("params", p.to_json()),
            ]),
            KernelConfig::HostSimd(p) => Json::obj(vec![
                ("kernel", Json::str("host_simd")),
                ("params", p.to_json()),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        let kernel = v.get("kernel")?.as_str()?;
        let params = v.get("params")?;
        match kernel {
            "xgemm" => Ok(KernelConfig::Xgemm(XgemmParams::from_json(params)?)),
            "xgemm_direct" => {
                Ok(KernelConfig::Direct(DirectParams::from_json(params)?))
            }
            "host_simd" => {
                Ok(KernelConfig::HostSimd(HostParams::from_json(params)?))
            }
            other => Err(JsonError::Type(
                "kernel name",
                Box::leak(other.to_string().into_boxed_str()),
            )),
        }
    }
}

impl std::fmt::Display for KernelConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triple_flops() {
        assert_eq!(Triple::new(2, 3, 4).flops(), 48.0);
    }

    #[test]
    fn triple_json_roundtrip() {
        let t = Triple::new(128, 64, 256);
        assert_eq!(Triple::from_json(&t.to_json()).unwrap(), t);
    }

    #[test]
    fn config_json_roundtrip() {
        let c = KernelConfig::Xgemm(XgemmParams::default());
        let back = KernelConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        let d = KernelConfig::Direct(DirectParams::default());
        assert_eq!(KernelConfig::from_json(&d.to_json()).unwrap(), d);
        for p in host_variants() {
            let h = KernelConfig::HostSimd(p);
            assert_eq!(KernelConfig::from_json(&h.to_json()).unwrap(), h);
        }
    }

    #[test]
    fn config_names_unique_across_kernels() {
        let a = KernelConfig::Xgemm(XgemmParams::default()).name();
        let b = KernelConfig::Direct(DirectParams::default()).name();
        assert_ne!(a, b);
    }
}
