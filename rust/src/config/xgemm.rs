//! The `xgemm` (indirect) kernel's 14-parameter tuning space — CLBlast's
//! tiled GEMM kernel.  The grid reproduces the paper's Table 1: exactly
//! 8748 = 3^7 · 2^2 raw points over 14 parameters (five of which are
//! pinned to a single value in the paper's CLTune setup, as here).

use crate::util::json::{Json, JsonError};

/// Full CLBlast xgemm parameter assignment.
///
/// Pallas mapping (DESIGN.md §Hardware-Adaptation): `mwg/nwg/kwg` are the
/// BlockSpec tiles, `mdimc/ndimc` the inner sub-tile decomposition,
/// `vwm/vwn` alignment legality, `sa/sb` VMEM staging.  `mdima/ndimb/kwi/
/// strm/strn` shape only the OpenCL thread layout and survive as carried
/// metadata (single-valued in this study, as in the paper's tuner setup).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct XgemmParams {
    /// Work-group tile rows of C.
    pub mwg: u32,
    /// Work-group tile cols of C.
    pub nwg: u32,
    /// K-loop tile.
    pub kwg: u32,
    /// Threads in M within a work-group (register tile MWI = MWG/MDIMC).
    pub mdimc: u32,
    /// Threads in N within a work-group (register tile NWI = NWG/NDIMC).
    pub ndimc: u32,
    /// Re-shaped tile dimension for loading A (pinned).
    pub mdima: u32,
    /// Re-shaped tile dimension for loading B (pinned).
    pub ndimb: u32,
    /// K-loop unroll factor (pinned).
    pub kwi: u32,
    /// Vector width for loading A.
    pub vwm: u32,
    /// Vector width for loading B.
    pub vwn: u32,
    /// Stride for accessing A within a thread (pinned).
    pub strm: u32,
    /// Stride for accessing B within a thread (pinned).
    pub strn: u32,
    /// Stage A tile through local memory / VMEM scratch.
    pub sa: u32,
    /// Stage B tile through local memory / VMEM scratch.
    pub sb: u32,
}

impl Default for XgemmParams {
    /// CLBlast's shipped default configuration (tuned for M=N=K=1024).
    fn default() -> Self {
        XgemmParams {
            mwg: 64,
            nwg: 64,
            kwg: 32,
            mdimc: 16,
            ndimc: 16,
            mdima: 16,
            ndimb: 16,
            kwi: 2,
            vwm: 2,
            vwn: 2,
            strm: 0,
            strn: 0,
            sa: 1,
            sb: 1,
        }
    }
}

impl XgemmParams {
    /// Inner register tile rows (CLBlast MWI).
    pub fn mwi(&self) -> u32 {
        self.mwg / self.mdimc
    }

    /// Inner register tile cols (CLBlast NWI).
    pub fn nwi(&self) -> u32 {
        self.nwg / self.ndimc
    }

    /// Structural legality — mirrors CLBlast's tuner constraints and the
    /// python-side `GemmConfig.validate`.
    pub fn is_structurally_legal(&self) -> bool {
        self.mwg % self.mdimc == 0
            && self.nwg % self.ndimc == 0
            && self.mwi() % self.vwm == 0
            && self.nwi() % self.vwn == 0
            && self.kwg % self.kwi == 0
            && self.mwg % self.mdima == 0
            && self.nwg % self.ndimb == 0
            && self.sa <= 1
            && self.sb <= 1
    }

    /// Local-memory / VMEM bytes for one work-group step (f32).
    /// A block + B block + C accumulator + staged copies.
    pub fn scratch_bytes(&self) -> u64 {
        let a = (self.mwg * self.kwg) as u64;
        let b = (self.kwg * self.nwg) as u64;
        let c = (self.mwg * self.nwg) as u64;
        let staged = self.sa as u64 * a + self.sb as u64 * b;
        (a + b + c + staged) * 4
    }

    /// CLBlast's local-memory usage (only the staged tiles count on GPU).
    pub fn local_mem_bytes(&self) -> u64 {
        (self.sa as u64 * (self.mwg * self.kwg) as u64
            + self.sb as u64 * (self.kwg * self.nwg) as u64)
            * 4
    }

    pub fn name(&self) -> String {
        format!(
            "x_m{}n{}k{}_c{}x{}_v{}x{}_s{}{}",
            self.mwg,
            self.nwg,
            self.kwg,
            self.mdimc,
            self.ndimc,
            self.vwm,
            self.vwn,
            self.sa,
            self.sb
        )
    }

    /// A compact stable u64 fingerprint (used for deterministic sim noise).
    pub fn fingerprint(&self) -> u64 {
        let fields = [
            self.mwg, self.nwg, self.kwg, self.mdimc, self.ndimc, self.mdima,
            self.ndimb, self.kwi, self.vwm, self.vwn, self.strm, self.strn,
            self.sa, self.sb,
        ];
        fields
            .iter()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, &f| {
                (h ^ f as u64).wrapping_mul(0x100_0000_01b3)
            })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mwg", Json::num(self.mwg)),
            ("nwg", Json::num(self.nwg)),
            ("kwg", Json::num(self.kwg)),
            ("mdimc", Json::num(self.mdimc)),
            ("ndimc", Json::num(self.ndimc)),
            ("mdima", Json::num(self.mdima)),
            ("ndimb", Json::num(self.ndimb)),
            ("kwi", Json::num(self.kwi)),
            ("vwm", Json::num(self.vwm)),
            ("vwn", Json::num(self.vwn)),
            ("strm", Json::num(self.strm)),
            ("strn", Json::num(self.strn)),
            ("sa", Json::num(self.sa)),
            ("sb", Json::num(self.sb)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        let g = |k: &str| -> Result<u32, JsonError> { v.get(k)?.as_u32() };
        Ok(XgemmParams {
            mwg: g("mwg")?,
            nwg: g("nwg")?,
            kwg: g("kwg")?,
            mdimc: g("mdimc")?,
            ndimc: g("ndimc")?,
            mdima: v.get_or("mdima", &Json::Num(16.0)).as_u32()?,
            ndimb: v.get_or("ndimb", &Json::Num(16.0)).as_u32()?,
            kwi: v.get_or("kwi", &Json::Num(2.0)).as_u32()?,
            vwm: g("vwm")?,
            vwn: g("vwn")?,
            strm: v.get_or("strm", &Json::Num(0.0)).as_u32()?,
            strn: v.get_or("strn", &Json::Num(0.0)).as_u32()?,
            sa: g("sa")?,
            sb: g("sb")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_legal() {
        assert!(XgemmParams::default().is_structurally_legal());
    }

    #[test]
    fn mwi_nwi() {
        let p = XgemmParams { mwg: 128, mdimc: 32, ..Default::default() };
        assert_eq!(p.mwi(), 4);
    }

    #[test]
    fn illegal_when_not_divisible() {
        let p = XgemmParams { mwg: 96, mdimc: 32, vwm: 1, ..Default::default() };
        assert!(p.is_structurally_legal());
        let p = XgemmParams { mwg: 100, mdimc: 32, ..Default::default() };
        assert!(!p.is_structurally_legal());
    }

    #[test]
    fn scratch_and_local_mem() {
        let p = XgemmParams {
            mwg: 64, nwg: 64, kwg: 32, sa: 1, sb: 0, ..Default::default()
        };
        assert_eq!(p.local_mem_bytes(), 64 * 32 * 4);
        assert_eq!(
            p.scratch_bytes(),
            ((64 * 32) + (32 * 64) + (64 * 64) + (64 * 32)) as u64 * 4
        );
    }

    #[test]
    fn json_roundtrip() {
        let p = XgemmParams { mwg: 128, vwm: 4, sa: 0, ..Default::default() };
        assert_eq!(XgemmParams::from_json(&p.to_json()).unwrap(), p);
    }

    #[test]
    fn fingerprint_sensitive_to_fields() {
        let a = XgemmParams::default();
        let b = XgemmParams { sb: 0, ..a };
        assert_ne!(a.fingerprint(), b.fingerprint());
    }
}
