//! The `host_simd` kernel's tuning space: cache-blocked SIMD microkernel
//! variants of the host GEMM inner loop, multi-versioned in the "A Few
//! Fit Most" sense — a small roster of (instruction tier, register tile,
//! unroll) points the adaptive loop selects between per shape, instead of
//! one hard-coded kernel.  Every variant is bit-identical to the scalar
//! reference (same f64 accumulation order per output element), so tier
//! selection is purely a performance decision.

use crate::util::json::{Json, JsonError};

/// Instruction-set tier a microkernel variant is compiled against.
/// Ordered by capability: a variant is *servable* on a host whose
/// detected tier is at least the variant's tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SimdTier {
    /// Portable scalar loop — always available, the reference kernel.
    Scalar,
    /// 128-bit SSE2 lanes (2 × f64 per accumulator register).
    Sse128,
    /// 256-bit AVX2 + FMA lanes (4 × f64 per accumulator register).
    Avx2Fma,
}

impl SimdTier {
    pub fn name(&self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Sse128 => "sse",
            SimdTier::Avx2Fma => "avx2",
        }
    }

    /// f64 lanes per vector register of this tier.
    pub fn lanes(&self) -> u32 {
        match self {
            SimdTier::Scalar => 1,
            SimdTier::Sse128 => 2,
            SimdTier::Avx2Fma => 4,
        }
    }

    pub fn from_name(s: &str) -> Option<SimdTier> {
        match s {
            "scalar" => Some(SimdTier::Scalar),
            "sse" => Some(SimdTier::Sse128),
            "avx2" => Some(SimdTier::Avx2Fma),
            _ => None,
        }
    }
}

impl std::fmt::Display for SimdTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One point of the host microkernel variant space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HostParams {
    pub tier: SimdTier,
    /// Microkernel register-tile rows of C.
    pub mr: u32,
    /// Microkernel register-tile cols of C.
    pub nr: u32,
    /// K-loop unroll factor.
    pub ku: u32,
    /// Packed-operand layout: A repacked into `mr x k` row panels and B
    /// into `k x nr` column panels once per dispatch, so the inner loops
    /// run unit-stride.  Costs an O(n^2) pack pass — a *layout* choice
    /// the adaptive loop learns per shape (loses for skinny k, wins for
    /// large k), not a capability tier.
    pub packed: bool,
}

/// Hard tile bound the executor's stack accumulators are sized for.
pub const MAX_TILE: u32 = 8;

impl HostParams {
    /// Structural legality: tiles fit the fixed-size stack accumulator
    /// and the unroll factor is a small power of two.
    pub fn is_structurally_legal(&self) -> bool {
        (1..=MAX_TILE).contains(&self.mr)
            && (1..=MAX_TILE).contains(&self.nr)
            && matches!(self.ku, 1 | 2 | 4 | 8)
    }

    /// Accumulator footprint of one microkernel step (f64 per element).
    pub fn scratch_bytes(&self) -> u64 {
        (self.mr * self.nr) as u64 * 8
    }

    pub fn name(&self) -> String {
        format!(
            "h_{}_t{}x{}_u{}{}",
            self.tier.name(),
            self.mr,
            self.nr,
            self.ku,
            if self.packed { "_p" } else { "" }
        )
    }

    /// A compact stable u64 fingerprint (used for deterministic sim noise).
    /// The `packed` axis folds in only when set, so every pre-existing
    /// unpacked variant keeps its fingerprint (and its sim landscape).
    pub fn fingerprint(&self) -> u64 {
        let fields = [self.tier.lanes(), self.mr, self.nr, self.ku];
        let h = fields
            .iter()
            .fold(0x9ce4_8422_cbf2_2325u64, |h, &f| {
                (h ^ f as u64).wrapping_mul(0x100_0000_01b3)
            });
        if self.packed {
            (h ^ 1).wrapping_mul(0x100_0000_01b3)
        } else {
            h
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tier", Json::str(self.tier.name())),
            ("mr", Json::num(self.mr)),
            ("nr", Json::num(self.nr)),
            ("ku", Json::num(self.ku)),
            ("packed", Json::Bool(self.packed)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        let tier_name = v.get("tier")?.as_str()?;
        let tier = SimdTier::from_name(tier_name).ok_or(JsonError::Type(
            "simd tier",
            Box::leak(tier_name.to_string().into_boxed_str()),
        ))?;
        Ok(HostParams {
            tier,
            mr: v.get("mr")?.as_u32()?,
            nr: v.get("nr")?.as_u32()?,
            ku: v.get_or("ku", &Json::Num(1.0)).as_u32()?,
            packed: v.get_or("packed", &Json::Bool(false)).as_bool()?,
        })
    }
}

/// The shipped variant roster: the multi-versioned points the manifest
/// expands every indirect padding bucket by.  Small on purpose — the "A
/// Few Fit Most" result is that a handful of variants plus a learned
/// selector covers the input space; each tier contributes tile/unroll
/// points the CART can prefer per shape.  Each unpacked point ships a
/// packed twin (appended *after* the unpacked four, so positional and
/// first-match lookups keep finding the unpacked originals) — packing
/// is a per-shape layout decision the selector learns, not a default.
pub fn host_variants() -> Vec<HostParams> {
    let unpacked = vec![
        HostParams { tier: SimdTier::Scalar, mr: 8, nr: 8, ku: 1, packed: false },
        HostParams { tier: SimdTier::Sse128, mr: 4, nr: 4, ku: 2, packed: false },
        HostParams { tier: SimdTier::Avx2Fma, mr: 8, nr: 8, ku: 4, packed: false },
        HostParams { tier: SimdTier::Avx2Fma, mr: 4, nr: 8, ku: 2, packed: false },
    ];
    let packed = unpacked.iter().map(|p| HostParams { packed: true, ..*p });
    unpacked.iter().copied().chain(packed).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_ordering_is_capability_ordering() {
        assert!(SimdTier::Scalar < SimdTier::Sse128);
        assert!(SimdTier::Sse128 < SimdTier::Avx2Fma);
    }

    #[test]
    fn tier_names_roundtrip() {
        for t in [SimdTier::Scalar, SimdTier::Sse128, SimdTier::Avx2Fma] {
            assert_eq!(SimdTier::from_name(t.name()), Some(t));
        }
        assert_eq!(SimdTier::from_name("neon"), None);
    }

    #[test]
    fn variants_are_legal_and_uniquely_named() {
        let vs = host_variants();
        assert!(vs.len() >= 3, "need at least one variant per tier");
        let mut names: Vec<String> = vs
            .iter()
            .inspect(|p| assert!(p.is_structurally_legal(), "{}", p.name()))
            .map(|p| p.name())
            .collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), vs.len());
        // Every tier is represented (the fallback chain is complete).
        for t in [SimdTier::Scalar, SimdTier::Sse128, SimdTier::Avx2Fma] {
            assert!(vs.iter().any(|p| p.tier == t), "no {t} variant");
        }
    }

    #[test]
    fn illegal_tiles_rejected() {
        let p = HostParams { tier: SimdTier::Scalar, mr: 16, nr: 4, ku: 1, packed: false };
        assert!(!p.is_structurally_legal());
        let p = HostParams { tier: SimdTier::Scalar, mr: 4, nr: 4, ku: 3, packed: false };
        assert!(!p.is_structurally_legal());
    }

    #[test]
    fn json_roundtrip() {
        for p in host_variants() {
            assert_eq!(HostParams::from_json(&p.to_json()).unwrap(), p);
        }
    }

    #[test]
    fn json_packed_defaults_false_for_legacy_entries() {
        // Manifests written before the packed axis existed omit the key.
        let p = HostParams { tier: SimdTier::Avx2Fma, mr: 8, nr: 8, ku: 4, packed: true };
        let mut legacy = p.to_json();
        if let Json::Obj(fields) = &mut legacy {
            fields.remove("packed");
        }
        let parsed = HostParams::from_json(&legacy).unwrap();
        assert!(!parsed.packed);
        assert_eq!(parsed, HostParams { packed: false, ..p });
    }

    #[test]
    fn fingerprint_sensitive_to_fields() {
        let a = HostParams { tier: SimdTier::Avx2Fma, mr: 8, nr: 8, ku: 4, packed: false };
        let b = HostParams { ku: 2, ..a };
        let c = HostParams { tier: SimdTier::Sse128, ..a };
        let d = HostParams { packed: true, ..a };
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_ne!(a.fingerprint(), d.fingerprint());
    }

    #[test]
    fn packed_twins_suffix_names_and_follow_unpacked() {
        let vs = host_variants();
        let unpacked: Vec<_> = vs.iter().filter(|p| !p.packed).collect();
        let packed: Vec<_> = vs.iter().filter(|p| p.packed).collect();
        assert_eq!(unpacked.len(), packed.len(), "every point has a packed twin");
        // The unpacked originals come first so first-match/positional
        // lookups (`find`, `[0]`) keep their pre-packing meaning.
        assert!(!vs[0].packed);
        let first_packed = vs.iter().position(|p| p.packed).unwrap();
        assert!(vs[..first_packed].iter().all(|p| !p.packed));
        assert!(vs[first_packed..].iter().all(|p| p.packed));
        for p in packed {
            assert!(p.name().ends_with("_p"), "{}", p.name());
            let twin = HostParams { packed: false, ..*p };
            assert!(unpacked.contains(&&twin), "twin missing for {}", p.name());
        }
    }
}
