//! The `host_simd` kernel's tuning space: cache-blocked SIMD microkernel
//! variants of the host GEMM inner loop, multi-versioned in the "A Few
//! Fit Most" sense — a small roster of (instruction tier, register tile,
//! unroll) points the adaptive loop selects between per shape, instead of
//! one hard-coded kernel.  Every variant is bit-identical to the scalar
//! reference (same f64 accumulation order per output element), so tier
//! selection is purely a performance decision.

use crate::util::json::{Json, JsonError};

/// Instruction-set tier a microkernel variant is compiled against.
/// Ordered by capability: a variant is *servable* on a host whose
/// detected tier is at least the variant's tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SimdTier {
    /// Portable scalar loop — always available, the reference kernel.
    Scalar,
    /// 128-bit SSE2 lanes (2 × f64 per accumulator register).
    Sse128,
    /// 256-bit AVX2 + FMA lanes (4 × f64 per accumulator register).
    Avx2Fma,
}

impl SimdTier {
    pub fn name(&self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Sse128 => "sse",
            SimdTier::Avx2Fma => "avx2",
        }
    }

    /// f64 lanes per vector register of this tier.
    pub fn lanes(&self) -> u32 {
        match self {
            SimdTier::Scalar => 1,
            SimdTier::Sse128 => 2,
            SimdTier::Avx2Fma => 4,
        }
    }

    pub fn from_name(s: &str) -> Option<SimdTier> {
        match s {
            "scalar" => Some(SimdTier::Scalar),
            "sse" => Some(SimdTier::Sse128),
            "avx2" => Some(SimdTier::Avx2Fma),
            _ => None,
        }
    }
}

impl std::fmt::Display for SimdTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One point of the host microkernel variant space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HostParams {
    pub tier: SimdTier,
    /// Microkernel register-tile rows of C.
    pub mr: u32,
    /// Microkernel register-tile cols of C.
    pub nr: u32,
    /// K-loop unroll factor.
    pub ku: u32,
}

/// Hard tile bound the executor's stack accumulators are sized for.
pub const MAX_TILE: u32 = 8;

impl HostParams {
    /// Structural legality: tiles fit the fixed-size stack accumulator
    /// and the unroll factor is a small power of two.
    pub fn is_structurally_legal(&self) -> bool {
        (1..=MAX_TILE).contains(&self.mr)
            && (1..=MAX_TILE).contains(&self.nr)
            && matches!(self.ku, 1 | 2 | 4 | 8)
    }

    /// Accumulator footprint of one microkernel step (f64 per element).
    pub fn scratch_bytes(&self) -> u64 {
        (self.mr * self.nr) as u64 * 8
    }

    pub fn name(&self) -> String {
        format!("h_{}_t{}x{}_u{}", self.tier.name(), self.mr, self.nr, self.ku)
    }

    /// A compact stable u64 fingerprint (used for deterministic sim noise).
    pub fn fingerprint(&self) -> u64 {
        let fields = [self.tier.lanes(), self.mr, self.nr, self.ku];
        fields
            .iter()
            .fold(0x9ce4_8422_cbf2_2325u64, |h, &f| {
                (h ^ f as u64).wrapping_mul(0x100_0000_01b3)
            })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tier", Json::str(self.tier.name())),
            ("mr", Json::num(self.mr)),
            ("nr", Json::num(self.nr)),
            ("ku", Json::num(self.ku)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        let tier_name = v.get("tier")?.as_str()?;
        let tier = SimdTier::from_name(tier_name).ok_or(JsonError::Type(
            "simd tier",
            Box::leak(tier_name.to_string().into_boxed_str()),
        ))?;
        Ok(HostParams {
            tier,
            mr: v.get("mr")?.as_u32()?,
            nr: v.get("nr")?.as_u32()?,
            ku: v.get_or("ku", &Json::Num(1.0)).as_u32()?,
        })
    }
}

/// The shipped variant roster: the multi-versioned points the manifest
/// expands every indirect padding bucket by.  Small on purpose — the "A
/// Few Fit Most" result is that a handful of variants plus a learned
/// selector covers the input space; each tier contributes tile/unroll
/// points the CART can prefer per shape.
pub fn host_variants() -> Vec<HostParams> {
    vec![
        HostParams { tier: SimdTier::Scalar, mr: 8, nr: 8, ku: 1 },
        HostParams { tier: SimdTier::Sse128, mr: 4, nr: 4, ku: 2 },
        HostParams { tier: SimdTier::Avx2Fma, mr: 8, nr: 8, ku: 4 },
        HostParams { tier: SimdTier::Avx2Fma, mr: 4, nr: 8, ku: 2 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_ordering_is_capability_ordering() {
        assert!(SimdTier::Scalar < SimdTier::Sse128);
        assert!(SimdTier::Sse128 < SimdTier::Avx2Fma);
    }

    #[test]
    fn tier_names_roundtrip() {
        for t in [SimdTier::Scalar, SimdTier::Sse128, SimdTier::Avx2Fma] {
            assert_eq!(SimdTier::from_name(t.name()), Some(t));
        }
        assert_eq!(SimdTier::from_name("neon"), None);
    }

    #[test]
    fn variants_are_legal_and_uniquely_named() {
        let vs = host_variants();
        assert!(vs.len() >= 3, "need at least one variant per tier");
        let mut names: Vec<String> = vs
            .iter()
            .inspect(|p| assert!(p.is_structurally_legal(), "{}", p.name()))
            .map(|p| p.name())
            .collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), vs.len());
        // Every tier is represented (the fallback chain is complete).
        for t in [SimdTier::Scalar, SimdTier::Sse128, SimdTier::Avx2Fma] {
            assert!(vs.iter().any(|p| p.tier == t), "no {t} variant");
        }
    }

    #[test]
    fn illegal_tiles_rejected() {
        let p = HostParams { tier: SimdTier::Scalar, mr: 16, nr: 4, ku: 1 };
        assert!(!p.is_structurally_legal());
        let p = HostParams { tier: SimdTier::Scalar, mr: 4, nr: 4, ku: 3 };
        assert!(!p.is_structurally_legal());
    }

    #[test]
    fn json_roundtrip() {
        for p in host_variants() {
            assert_eq!(HostParams::from_json(&p.to_json()).unwrap(), p);
        }
    }

    #[test]
    fn fingerprint_sensitive_to_fields() {
        let a = HostParams { tier: SimdTier::Avx2Fma, mr: 8, nr: 8, ku: 4 };
        let b = HostParams { ku: 2, ..a };
        let c = HostParams { tier: SimdTier::Sse128, ..a };
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
    }
}
