//! Seeded train/test splitting (paper §3: 80/20 via random sampling).

use crate::util::prng::Rng;

/// Split `n` indices into (train, test) with `test_frac` of the data in
/// the test set, shuffled deterministically by `seed`.
pub fn train_test_split(n: usize, test_frac: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
    assert!((0.0..1.0).contains(&test_frac), "bad test_frac {test_frac}");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let test = idx.split_off(n.saturating_sub(n_test));
    (idx, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn split_is_partition() {
        let (tr, te) = train_test_split(100, 0.2, 42);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        let all: HashSet<usize> = tr.iter().chain(te.iter()).copied().collect();
        assert_eq!(all.len(), 100);
    }

    #[test]
    fn split_deterministic() {
        assert_eq!(train_test_split(50, 0.2, 7), train_test_split(50, 0.2, 7));
        assert_ne!(
            train_test_split(50, 0.2, 7).1,
            train_test_split(50, 0.2, 8).1
        );
    }

    #[test]
    fn split_empty_and_tiny() {
        let (tr, te) = train_test_split(0, 0.2, 1);
        assert!(tr.is_empty() && te.is_empty());
        let (tr, te) = train_test_split(1, 0.2, 1);
        assert_eq!(tr.len() + te.len(), 1);
    }
}
