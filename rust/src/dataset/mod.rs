//! Dataset generation (paper §4.1): the two synthetic strategies (`po2`,
//! `go2`), the real-world `AntonNet` collection (GEMM triples profiled
//! from AlexNet / GoogLeNet / SqueezeNet), labeled datasets produced by
//! the tuner, and the seeded 80/20 train/test split.

pub mod antonnet;
pub mod labeled;
pub mod split;

pub use labeled::{ClassId, ClassTable, LabeledDataset, UpsertOutcome};
pub use split::train_test_split;

use crate::config::Triple;

/// The three dataset-generation strategies of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// Powers of two, 64..=2048 (6^3 = 216 triples).
    Po2,
    /// Grid of 256, 256..=3840 step 256 (15^3 = 3375 triples).
    Go2,
    /// Real-world GEMM shapes from deep networks (~460 triples).
    AntonNet,
}

impl DatasetKind {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Po2 => "po2",
            DatasetKind::Go2 => "go2",
            DatasetKind::AntonNet => "antonnet",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "po2" | "powerof2" => Some(DatasetKind::Po2),
            "go2" | "gridof2" => Some(DatasetKind::Go2),
            "antonnet" => Some(DatasetKind::AntonNet),
            _ => None,
        }
    }

    pub fn all() -> [DatasetKind; 3] {
        [DatasetKind::AntonNet, DatasetKind::Po2, DatasetKind::Go2]
    }
}

impl std::fmt::Display for DatasetKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// An unlabeled dataset: the input descriptions `I`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub triples: Vec<Triple>,
}

impl Dataset {
    pub fn generate(kind: DatasetKind) -> Dataset {
        let triples = match kind {
            DatasetKind::Po2 => po2_triples(),
            DatasetKind::Go2 => go2_triples(),
            DatasetKind::AntonNet => antonnet::triples(),
        };
        Dataset { kind, triples }
    }

    pub fn len(&self) -> usize {
        self.triples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.triples.is_empty()
    }
}

/// `po2`: all (M, N, K) with every dim a power of two in [64, 2048].
pub fn po2_triples() -> Vec<Triple> {
    let vals: Vec<u32> = (6..=11).map(|e| 1u32 << e).collect(); // 64..2048
    cube(&vals)
}

/// `go2`: all (M, N, K) with every dim in {256, 512, ..., 3840}.
pub fn go2_triples() -> Vec<Triple> {
    let vals: Vec<u32> = (1..=15).map(|i| i * 256).collect();
    cube(&vals)
}

fn cube(vals: &[u32]) -> Vec<Triple> {
    let mut out = Vec::with_capacity(vals.len().pow(3));
    for &m in vals {
        for &n in vals {
            for &k in vals {
                out.push(Triple::new(m, n, k));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn po2_matches_paper_size() {
        let t = po2_triples();
        assert_eq!(t.len(), 216); // paper Tables 3/4: 216
        assert!(t.iter().all(|t| t.m.is_power_of_two()
            && (64..=2048).contains(&t.m)));
    }

    #[test]
    fn go2_matches_paper_size() {
        let t = go2_triples();
        assert_eq!(t.len(), 3375); // paper Table 3: 3375
        assert!(t.iter().all(|t| t.m % 256 == 0 && t.m <= 3840));
    }

    #[test]
    fn triples_unique() {
        for kind in DatasetKind::all() {
            let d = Dataset::generate(kind);
            let set: HashSet<Triple> = d.triples.iter().copied().collect();
            assert_eq!(set.len(), d.len(), "{kind} has duplicate triples");
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in DatasetKind::all() {
            assert_eq!(DatasetKind::parse(kind.name()), Some(kind));
        }
    }

    #[test]
    fn go2_denser_than_po2() {
        // The paper's observation: go2 is ~8x larger than AntonNet and
        // denser than po2.
        assert!(go2_triples().len() > 8 * po2_triples().len());
    }
}
