//! The `AntonNet` real-world dataset: GEMM operand shapes profiled from
//! AlexNet, GoogLeNet and SqueezeNet inference, batch sizes 2..=128 step 2
//! (paper §4.1: ~460 unique triples, ~35% with K = 1, mostly rectangular).
//!
//! The paper gathered these by instrumenting CLBlast under the three
//! networks; we reconstruct the same population from the networks'
//! published layer shapes (DESIGN.md §Substitutions):
//!
//! * convolution via im2col: M = C_out, N = H_out * W_out, K = C_in*KH*KW
//!   (spatial N is batch-independent; CLBlast sees per-image GEMMs, the
//!   batch enters through fully-connected layers and repeated calls);
//! * fully-connected: M = features_out, N = batch, K = features_in;
//! * bias / residual rank-1 updates: M = C_out, N = spatial or batch,
//!   K = 1 — the source of the paper's 35% K=1 population.

use crate::config::Triple;

/// One conv layer: (c_out, c_in, kh, kw, h_out, w_out).
struct Conv(u32, u32, u32, u32, u32, u32);

/// One fully-connected layer: (features_out, features_in).
struct Fc(u32, u32);

/// AlexNet (Krizhevsky et al. 2012), 227x227 input.
fn alexnet() -> (Vec<Conv>, Vec<Fc>) {
    (
        vec![
            Conv(96, 3, 11, 11, 55, 55),
            Conv(256, 96, 5, 5, 27, 27),
            Conv(384, 256, 3, 3, 13, 13),
            Conv(384, 384, 3, 3, 13, 13),
            Conv(256, 384, 3, 3, 13, 13),
        ],
        vec![Fc(4096, 9216), Fc(4096, 4096), Fc(1000, 4096)],
    )
}

/// GoogLeNet (Szegedy et al. 2015) — stem + the 9 inception modules'
/// distinct GEMM shapes (1x1 / 3x3 / 5x5 branches and projections).
fn googlenet() -> (Vec<Conv>, Vec<Fc>) {
    let mut convs = vec![
        Conv(64, 3, 7, 7, 112, 112),
        Conv(64, 64, 1, 1, 56, 56),
        Conv(192, 64, 3, 3, 56, 56),
    ];
    // (in_ch, spatial, branch channel sets) per inception module.
    let modules: [(u32, u32, [u32; 6]); 9] = [
        (192, 28, [64, 96, 128, 16, 32, 32]),
        (256, 28, [128, 128, 192, 32, 96, 64]),
        (480, 14, [192, 96, 208, 16, 48, 64]),
        (512, 14, [160, 112, 224, 24, 64, 64]),
        (512, 14, [128, 128, 256, 24, 64, 64]),
        (512, 14, [112, 144, 288, 32, 64, 64]),
        (528, 14, [256, 160, 320, 32, 128, 128]),
        (832, 7, [256, 160, 320, 32, 128, 128]),
        (832, 7, [384, 192, 384, 48, 128, 128]),
    ];
    for (c_in, s, [b1, b3r, b3, b5r, b5, pp]) in modules {
        convs.push(Conv(b1, c_in, 1, 1, s, s)); // 1x1 branch
        convs.push(Conv(b3r, c_in, 1, 1, s, s)); // 3x3 reduce
        convs.push(Conv(b3, b3r, 3, 3, s, s)); // 3x3
        convs.push(Conv(b5r, c_in, 1, 1, s, s)); // 5x5 reduce
        convs.push(Conv(b5, b5r, 5, 5, s, s)); // 5x5
        convs.push(Conv(pp, c_in, 1, 1, s, s)); // pool projection
    }
    (convs, vec![Fc(1000, 1024)])
}

/// SqueezeNet 1.0 (Iandola et al. 2016): conv1 + 8 fire modules + conv10.
fn squeezenet() -> (Vec<Conv>, Vec<Fc>) {
    let mut convs = vec![Conv(96, 3, 7, 7, 111, 111)];
    // (squeeze, expand, in_ch, spatial) per fire module.
    let fires: [(u32, u32, u32, u32); 8] = [
        (16, 64, 96, 55),
        (16, 64, 128, 55),
        (32, 128, 128, 55),
        (32, 128, 256, 27),
        (48, 192, 256, 27),
        (48, 192, 384, 27),
        (64, 256, 384, 27),
        (64, 256, 512, 13),
    ];
    for (s, e, c_in, sp) in fires {
        convs.push(Conv(s, c_in, 1, 1, sp, sp)); // squeeze 1x1
        convs.push(Conv(e, s, 1, 1, sp, sp)); // expand 1x1
        convs.push(Conv(e, s, 3, 3, sp, sp)); // expand 3x3
    }
    convs.push(Conv(1000, 512, 1, 1, 13, 13)); // conv10
    (convs, vec![])
}

/// Batch sizes profiled by the paper: 2..=128 step 2.
pub fn batches() -> Vec<u32> {
    (1..=64).map(|i| i * 2).collect()
}

/// Generate the full AntonNet triple population (deduplicated, sorted).
pub fn triples() -> Vec<Triple> {
    let mut set = std::collections::BTreeSet::new();
    let nets = [alexnet(), googlenet(), squeezenet()];
    for (convs, fcs) in &nets {
        for Conv(c_out, c_in, kh, kw, h, w) in convs {
            let m = *c_out;
            let n = h * w;
            let k = c_in * kh * kw;
            // im2col GEMM (per image; CLBlast sees one call per image).
            set.insert(Triple::new(m, n, k));
            // bias broadcast as rank-1 GEMM: the K=1 population.
            set.insert(Triple::new(m, n, 1));
        }
        for Fc(f_out, f_in) in fcs {
            for b in batches() {
                set.insert(Triple::new(*f_out, b, *f_in));
                set.insert(Triple::new(*f_out, b, 1)); // bias
            }
        }
    }
    set.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn population_close_to_paper() {
        // Paper: "roughly 460 different triples".
        let t = triples();
        assert!(
            (380..=560).contains(&t.len()),
            "AntonNet population {} outside the paper's ballpark",
            t.len()
        );
    }

    #[test]
    fn k1_fraction_close_to_paper() {
        // Paper: "35% of them having K = 1".
        let t = triples();
        let k1 = t.iter().filter(|t| t.k == 1).count() as f64 / t.len() as f64;
        assert!(
            (0.20..=0.50).contains(&k1),
            "K=1 fraction {k1:.2} outside the paper's ballpark"
        );
    }

    #[test]
    fn mostly_rectangular() {
        // Paper: "the other shapes are mostly rectangular".
        let t = triples();
        let square = t
            .iter()
            .filter(|t| t.m == t.n && t.n == t.k)
            .count() as f64
            / t.len() as f64;
        assert!(square < 0.05, "square fraction {square:.2} too high");
    }

    #[test]
    fn batch_range_matches_paper() {
        let b = batches();
        assert_eq!(b.first(), Some(&2));
        assert_eq!(b.last(), Some(&128));
        assert_eq!(b.len(), 64);
    }

    #[test]
    fn contains_known_alexnet_fc_shape() {
        // FC6 at batch 128: (4096, 128, 9216).
        assert!(triples().contains(&Triple::new(4096, 128, 9216)));
    }
}
