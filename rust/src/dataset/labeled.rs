//! Labeled datasets: the paper's `D = {(I, C)}` — triples paired with the
//! best kernel configuration the tuner found, interned through a class
//! table so the decision tree trains on compact integer labels.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::{KernelConfig, KernelKind, Triple};
use crate::util::json::Json;

use super::DatasetKind;

/// Compact class label (index into the `ClassTable`).
pub type ClassId = u32;

/// Interns kernel configurations as dense class ids.
#[derive(Debug, Clone, Default)]
pub struct ClassTable {
    configs: Vec<KernelConfig>,
    index: HashMap<KernelConfig, ClassId>,
}

impl ClassTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn intern(&mut self, cfg: KernelConfig) -> ClassId {
        if let Some(&id) = self.index.get(&cfg) {
            return id;
        }
        let id = self.configs.len() as ClassId;
        self.configs.push(cfg);
        self.index.insert(cfg, id);
        id
    }

    pub fn get(&self, id: ClassId) -> Option<&KernelConfig> {
        self.configs.get(id as usize)
    }

    pub fn config(&self, id: ClassId) -> &KernelConfig {
        &self.configs[id as usize]
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (ClassId, &KernelConfig)> {
        self.configs.iter().enumerate().map(|(i, c)| (i as ClassId, c))
    }

    /// Count of distinct configs per kernel (Tables 3/4 columns 3-4).
    pub fn unique_per_kernel(&self) -> (usize, usize) {
        let x = self
            .configs
            .iter()
            .filter(|c| c.kind() == KernelKind::Xgemm)
            .count();
        (x, self.configs.len() - x)
    }
}

/// What [`LabeledDataset::upsert`] did with the observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpsertOutcome {
    /// New triple appended.
    Inserted,
    /// Known triple, label changed.
    Relabeled,
    /// Known triple, label already matched.
    Unchanged,
}

/// A labeled dataset ready for training.
#[derive(Debug, Clone)]
pub struct LabeledDataset {
    pub kind: DatasetKind,
    pub device: String,
    pub entries: Vec<(Triple, ClassId)>,
    pub classes: ClassTable,
}

impl LabeledDataset {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Subset by index list (train/test split views).
    pub fn subset(&self, idx: &[usize]) -> Vec<(Triple, ClassId)> {
        idx.iter().map(|&i| self.entries[i]).collect()
    }

    // ------------------------------------------------- online maintenance

    /// Insert or relabel one entry — the telemetry fold-in primitive of
    /// the online adaptation loop.  A triple appears at most once; folding
    /// a fresher observation for a known triple *replaces* its label.
    /// Linear scan: labeled datasets are small (hundreds of triples), and
    /// this runs on the background trainer thread, never the hot path.
    ///
    /// Panics if `class` is not interned in `self.classes`.
    pub fn upsert(&mut self, t: Triple, class: ClassId) -> UpsertOutcome {
        assert!(
            (class as usize) < self.classes.len(),
            "upsert with un-interned class {class}"
        );
        for e in &mut self.entries {
            if e.0 == t {
                if e.1 == class {
                    return UpsertOutcome::Unchanged;
                }
                e.1 = class;
                return UpsertOutcome::Relabeled;
            }
        }
        self.entries.push((t, class));
        UpsertOutcome::Inserted
    }

    /// Merge another labeled dataset into this one, re-interning its
    /// classes (the two tables need not agree on ids).  Entries from
    /// `other` win on triple collisions — "other" is the fresher data.
    /// Returns how many entries were inserted or relabeled.
    pub fn merge_from(&mut self, other: &LabeledDataset) -> usize {
        let mut changed = 0;
        for &(t, c) in &other.entries {
            let class = self.classes.intern(*other.classes.config(c));
            if self.upsert(t, class) != UpsertOutcome::Unchanged {
                changed += 1;
            }
        }
        changed
    }

    // ------------------------------------------------------- persistence

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind.name())),
            ("device", Json::str(self.device.clone())),
            (
                "classes",
                Json::Arr(self.classes.configs.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "entries",
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|(t, c)| {
                            Json::Arr(vec![
                                Json::num(t.m),
                                Json::num(t.n),
                                Json::num(t.k),
                                Json::num(*c),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let kind = DatasetKind::parse(v.get("kind")?.as_str()?)
            .context("unknown dataset kind")?;
        let device = v.get("device")?.as_str()?.to_string();
        let mut classes = ClassTable::new();
        for cj in v.get("classes")?.as_arr()? {
            classes.intern(KernelConfig::from_json(cj)?);
        }
        let mut entries = Vec::new();
        for ej in v.get("entries")?.as_arr()? {
            let a = ej.as_arr()?;
            let triple = Triple::new(a[0].as_u32()?, a[1].as_u32()?, a[2].as_u32()?);
            let class = a[3].as_u32()?;
            anyhow::ensure!(
                (class as usize) < classes.len(),
                "class id {class} out of range"
            );
            entries.push((triple, class));
        }
        Ok(LabeledDataset { kind, device, entries, classes })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DirectParams, XgemmParams};

    fn sample() -> LabeledDataset {
        let mut classes = ClassTable::new();
        let a = classes.intern(KernelConfig::Xgemm(XgemmParams::default()));
        let b = classes.intern(KernelConfig::Direct(DirectParams::default()));
        LabeledDataset {
            kind: DatasetKind::Po2,
            device: "nvidia-p100".into(),
            entries: vec![
                (Triple::new(64, 64, 64), b),
                (Triple::new(1024, 1024, 1024), a),
            ],
            classes,
        }
    }

    #[test]
    fn intern_dedups() {
        let mut t = ClassTable::new();
        let a = t.intern(KernelConfig::Xgemm(XgemmParams::default()));
        let b = t.intern(KernelConfig::Xgemm(XgemmParams::default()));
        assert_eq!(a, b);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn unique_per_kernel_counts() {
        let d = sample();
        assert_eq!(d.classes.unique_per_kernel(), (1, 1));
    }

    #[test]
    fn json_roundtrip() {
        let d = sample();
        let back = LabeledDataset::from_json(&d.to_json()).unwrap();
        assert_eq!(back.entries, d.entries);
        assert_eq!(back.classes.len(), d.classes.len());
        assert_eq!(back.device, d.device);
    }

    #[test]
    fn save_load(){
        let d = sample();
        let dir = std::env::temp_dir().join("adaptlib-test-ds");
        let path = dir.join("ds.json");
        d.save(&path).unwrap();
        let back = LabeledDataset::load(&path).unwrap();
        assert_eq!(back.entries, d.entries);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn upsert_inserts_relabels_and_dedups() {
        let mut d = sample();
        let n0 = d.len();
        let direct = d
            .classes
            .iter()
            .find(|(_, c)| c.kind() == KernelKind::XgemmDirect)
            .map(|(id, _)| id)
            .unwrap();
        let xgemm = d
            .classes
            .iter()
            .find(|(_, c)| c.kind() == KernelKind::Xgemm)
            .map(|(id, _)| id)
            .unwrap();
        // New triple.
        let t = Triple::new(7, 7, 7);
        assert_eq!(d.upsert(t, direct), UpsertOutcome::Inserted);
        assert_eq!(d.len(), n0 + 1);
        // Same label again: no change.
        assert_eq!(d.upsert(t, direct), UpsertOutcome::Unchanged);
        assert_eq!(d.len(), n0 + 1);
        // Fresher observation flips the label in place.
        assert_eq!(d.upsert(t, xgemm), UpsertOutcome::Relabeled);
        assert_eq!(d.len(), n0 + 1);
        assert!(d.entries.iter().any(|&(tt, c)| tt == t && c == xgemm));
    }

    #[test]
    #[should_panic(expected = "un-interned class")]
    fn upsert_rejects_unknown_class() {
        let mut d = sample();
        d.upsert(Triple::new(1, 1, 1), 99);
    }

    #[test]
    fn merge_from_reinterns_classes() {
        let mut a = sample();
        // `b` uses its own class table with ids in the opposite order.
        let mut classes = ClassTable::new();
        let d = classes.intern(KernelConfig::Direct(DirectParams::default()));
        let x = classes.intern(KernelConfig::Xgemm(XgemmParams {
            mwg: 128,
            ..Default::default()
        }));
        let b = LabeledDataset {
            kind: DatasetKind::Po2,
            device: "nvidia-p100".into(),
            entries: vec![
                // Collides with a's (64,64,64) entry, same config family.
                (Triple::new(64, 64, 64), d),
                // New triple with a config unknown to a.
                (Triple::new(512, 512, 512), x),
            ],
            classes,
        };
        let n_classes_before = a.classes.len();
        let changed = a.merge_from(&b);
        assert_eq!(changed, 1, "only the new triple changes anything");
        assert_eq!(a.classes.len(), n_classes_before + 1);
        // The merged entry's class resolves to the same config.
        let (_, c) = *a
            .entries
            .iter()
            .find(|(t, _)| *t == Triple::new(512, 512, 512))
            .unwrap();
        assert_eq!(
            *a.classes.config(c),
            KernelConfig::Xgemm(XgemmParams { mwg: 128, ..Default::default() })
        );
    }

    #[test]
    fn from_json_rejects_bad_class_id() {
        let mut j = sample().to_json();
        if let Json::Obj(ref mut m) = j {
            m.insert(
                "entries".into(),
                Json::Arr(vec![Json::Arr(vec![
                    Json::num(1),
                    Json::num(1),
                    Json::num(1),
                    Json::num(99),
                ])]),
            );
        }
        assert!(LabeledDataset::from_json(&j).is_err());
    }
}
