//! Network front door: framed serving over TCP in front of the
//! in-process fleet.
//!
//! - [`wire`] — the length-prefixed binary protocol (v1): frame layout,
//!   zero-copy decoding into borrowed views, typed protocol errors.
//! - [`server`] — the thread-per-connection acceptor: per-connection
//!   in-flight caps, typed status frames for every refusal, deadline
//!   stamping from the budget header, graceful drain.
//! - [`client`] — a minimal blocking loopback client used by the
//!   integration tests and the overload experiment's network arm.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{ClientReply, NetClient, NetReceiver, NetSender};
pub use server::{NetConfig, NetServer, NetStats};
pub use wire::{Frame, NetError, ProtocolError, WireStatus};
