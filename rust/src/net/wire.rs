//! Wire protocol v1: compact length-prefixed binary framing for the
//! network front door, with zero-copy request decoding.
//!
//! Every frame on the wire is a little-endian `u32` byte length followed
//! by that many body bytes.  The length prefix is capped at
//! [`MAX_FRAME_BYTES`] *before* any buffering, so a lying prefix can
//! never make a connection allocate unbounded memory.  Bodies share a
//! 16-byte common header and then branch on the frame kind:
//!
//! ```text
//! common header (16 bytes)
//!   0  [u8; 4]  magic  b"ADPT"
//!   4  u16      version (1)
//!   6  u16      kind: 1 = request, 2 = response, 3 = status
//!   8  u64      request id (echoed verbatim in the reply)
//!
//! request body (kind 1), after the common header
//!  16  u64      deadline budget in microseconds (0 = no deadline)
//!  24  u32      m          28  u32 n          32  u32 k
//!  36  f32      alpha      40  f32 beta
//!  44  u16      artifact-hint byte length (UTF-8; 0 = none)
//!  46  u16      reserved (0 on encode, ignored on decode)
//!  48  ..       hint bytes, then operands a (m*k), b (k*n), c (m*n),
//!               each element a little-endian f32 — the body length must
//!               equal the computed size *exactly*
//!
//! response body (kind 2) — a successfully served result
//!  16  u32      element count (= m*n of the request)
//!  20  ..       out payload, little-endian f32s
//!
//! status body (kind 3) — every non-payload answer, typed
//!  16  u16      status code (see `WireStatus`)
//!  18  u16      message byte length
//!  20  ..       message bytes (UTF-8)
//! ```
//!
//! Decoding is *zero-copy*: [`decode`] offset-scans the body slice and
//! returns borrowed views — the artifact hint as a `&str` into the
//! frame, each operand as a [`PayloadView`] wrapping its byte range.
//! Nothing is parsed into an owned tree (the mik-sdk ADR lesson: lazy
//! byte-scanning extraction beats eager full-tree parsing by an order
//! of magnitude on hot paths); the only copy on the request path is the
//! single borrowed-bytes → owned-operand conversion the fleet's
//! `GemmRequest` API requires, via [`PayloadView::copy_into`] on a
//! pooled destination buffer.  Every decode failure is a typed
//! [`ProtocolError`] — a malformed, truncated or lying frame can never
//! panic, hang, or read out of bounds (all offset arithmetic is
//! checked, element counts go through u64 `checked_{add,mul}`).

use std::fmt;
use std::io;
use std::time::{Duration, Instant};

use crate::coordinator::GemmRequest;

/// Frame magic: the first four body bytes of every well-formed frame.
pub const MAGIC: [u8; 4] = *b"ADPT";
/// The only protocol version this build speaks.
pub const VERSION: u16 = 1;
/// Hard cap on the body length a peer may announce (64 MiB).  Enforced
/// on the prefix *before* buffering: the bounded-memory guarantee of
/// the front door starts here.
pub const MAX_FRAME_BYTES: u32 = 64 * 1024 * 1024;

/// Byte length of the common header shared by every frame kind.
pub const COMMON_HEADER_BYTES: usize = 16;
/// Byte length of the fixed request header (common header included).
pub const REQUEST_HEADER_BYTES: usize = 48;
/// Byte length of the fixed response header (common header included).
pub const RESPONSE_HEADER_BYTES: usize = 20;
/// Byte length of the fixed status header (common header included).
pub const STATUS_HEADER_BYTES: usize = 20;

const KIND_REQUEST: u16 = 1;
const KIND_RESPONSE: u16 = 2;
const KIND_STATUS: u16 = 3;

/// Typed status codes a server answers with when there is no result
/// payload — the wire-level mirror of the coordinator's `Admission`
/// refusals and unhappy `RequestOutcome`s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireStatus {
    /// Every candidate class was at its queue bound (`Admission::Shed`).
    Shed,
    /// Every candidate class's breaker was open
    /// (`Admission::Quarantined` or a quarantined outcome).
    Quarantined,
    /// The request was semantically invalid — dimension overflow or
    /// operand length mismatch (`Admission::Rejected`).
    Rejected,
    /// The deadline budget elapsed before the request was served
    /// (`RequestOutcome::Expired`).
    Expired,
    /// The server drained during graceful shutdown before serving
    /// (`RequestOutcome::Drained`).
    Drained,
    /// The per-connection in-flight cap refused the frame before it
    /// reached the fleet — socket-level backpressure, not fleet load.
    Busy,
    /// The request executed but failed (`RequestOutcome::Error`).
    Error,
    /// The frame itself failed to decode; the message carries the
    /// rendered [`ProtocolError`].
    Malformed,
}

impl WireStatus {
    /// The u16 code this status travels as.
    pub fn code(self) -> u16 {
        match self {
            WireStatus::Shed => 1,
            WireStatus::Quarantined => 2,
            WireStatus::Rejected => 3,
            WireStatus::Expired => 4,
            WireStatus::Drained => 5,
            WireStatus::Busy => 6,
            WireStatus::Error => 7,
            WireStatus::Malformed => 8,
        }
    }

    /// The status a code denotes; `None` for unassigned codes.
    pub fn from_code(code: u16) -> Option<WireStatus> {
        match code {
            1 => Some(WireStatus::Shed),
            2 => Some(WireStatus::Quarantined),
            3 => Some(WireStatus::Rejected),
            4 => Some(WireStatus::Expired),
            5 => Some(WireStatus::Drained),
            6 => Some(WireStatus::Busy),
            7 => Some(WireStatus::Error),
            8 => Some(WireStatus::Malformed),
            // The code domain is u16; unassigned values are the
            // caller's BadStatusCode, not a variant.
            _ => None, // LINT: allow(wildcard)
        }
    }

    /// Human-readable tag used in renders and experiment accounting.
    pub fn name(self) -> &'static str {
        match self {
            WireStatus::Shed => "shed",
            WireStatus::Quarantined => "quarantined",
            WireStatus::Rejected => "rejected",
            WireStatus::Expired => "expired",
            WireStatus::Drained => "drained",
            WireStatus::Busy => "busy",
            WireStatus::Error => "error",
            WireStatus::Malformed => "malformed",
        }
    }
}

impl fmt::Display for WireStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A typed decode/encode failure.  Every malformed input maps to one of
/// these — the fuzz suite (`tests/wire_protocol.rs`) pins that no
/// mutation of a valid frame can produce anything else (no panic, no
/// hang, no over-read).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtocolError {
    /// The body ended before a required field: `need` bytes were
    /// required, `have` were present.
    Truncated { need: usize, have: usize },
    /// The first four body bytes were not [`MAGIC`].
    BadMagic { got: [u8; 4] },
    /// The peer speaks a different protocol version.
    VersionSkew { got: u16, want: u16 },
    /// The frame kind is not request/response/status.
    BadKind { got: u16 },
    /// The length prefix announced a body beyond [`MAX_FRAME_BYTES`].
    Oversized { len: u32, max: u32 },
    /// The triple's operand element counts overflow a u64 byte size —
    /// a pathological header that could never describe a real payload.
    OperandOverflow { m: u32, n: u32, k: u32 },
    /// The body length does not match the size the header fields imply
    /// exactly (a lying length field, a truncated or padded payload).
    LengthMismatch { want: u64, got: u64 },
    /// A status frame carried an unassigned status code.
    BadStatusCode { got: u16 },
    /// A text field (artifact hint, status message) was not UTF-8.
    BadUtf8 { field: &'static str },
    /// An encoder input could not be framed (hint longer than a u16
    /// length field can carry).
    HintTooLong { len: usize },
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            ProtocolError::BadMagic { got } => {
                write!(f, "bad magic {got:02x?} (want {:02x?})", MAGIC)
            }
            ProtocolError::VersionSkew { got, want } => {
                write!(f, "protocol version skew: got v{got}, this build speaks v{want}")
            }
            ProtocolError::BadKind { got } => write!(f, "unknown frame kind {got}"),
            ProtocolError::Oversized { len, max } => {
                write!(f, "frame length {len} exceeds the {max}-byte cap")
            }
            ProtocolError::OperandOverflow { m, n, k } => {
                write!(f, "operand sizes for ({m}, {n}, {k}) overflow the frame format")
            }
            ProtocolError::LengthMismatch { want, got } => {
                write!(f, "body length mismatch: header implies {want} bytes, frame has {got}")
            }
            ProtocolError::BadStatusCode { got } => {
                write!(f, "unassigned status code {got}")
            }
            ProtocolError::BadUtf8 { field } => write!(f, "{field} is not valid UTF-8"),
            ProtocolError::HintTooLong { len } => {
                write!(f, "artifact hint of {len} bytes exceeds the u16 length field")
            }
        }
    }
}

impl std::error::Error for ProtocolError {}

/// A framing-layer failure: either a typed protocol violation or the
/// underlying socket error.  Truncated streams surface as
/// `Io(UnexpectedEof)` — typed, never a hang.
#[derive(Debug)]
pub enum NetError {
    Protocol(ProtocolError),
    Io(io::Error),
}

impl fmt::Display for NetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetError::Protocol(e) => write!(f, "protocol error: {e}"),
            NetError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for NetError {}

impl From<ProtocolError> for NetError {
    fn from(e: ProtocolError) -> NetError {
        NetError::Protocol(e)
    }
}

impl From<io::Error> for NetError {
    fn from(e: io::Error) -> NetError {
        NetError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// Checked little-endian field readers.  Every decode goes through these:
// a short body yields a typed `Truncated`, never a slice panic.
// ---------------------------------------------------------------------------

fn bytes_at<const N: usize>(b: &[u8], off: usize) -> Result<[u8; N], ProtocolError> {
    let end = off.checked_add(N).ok_or(ProtocolError::Truncated { need: usize::MAX, have: b.len() })?;
    match b.get(off..end) {
        Some(s) => {
            let mut out = [0u8; N];
            out.copy_from_slice(s);
            Ok(out)
        }
        None => Err(ProtocolError::Truncated { need: end, have: b.len() }),
    }
}

fn u16_at(b: &[u8], off: usize) -> Result<u16, ProtocolError> {
    Ok(u16::from_le_bytes(bytes_at::<2>(b, off)?))
}

fn u32_at(b: &[u8], off: usize) -> Result<u32, ProtocolError> {
    Ok(u32::from_le_bytes(bytes_at::<4>(b, off)?))
}

fn u64_at(b: &[u8], off: usize) -> Result<u64, ProtocolError> {
    Ok(u64::from_le_bytes(bytes_at::<8>(b, off)?))
}

fn f32_at(b: &[u8], off: usize) -> Result<f32, ProtocolError> {
    Ok(f32::from_le_bytes(bytes_at::<4>(b, off)?))
}

/// Best-effort request-id extraction from a body that may be malformed.
/// Used to address a `Malformed` status frame at the offending request
/// when the header got far enough to carry an id; 0 otherwise.
pub fn request_id_hint(body: &[u8]) -> u64 {
    u64_at(body, 8).unwrap_or(0)
}

/// A borrowed view over one operand's raw little-endian f32 bytes.
/// Length is always a multiple of 4 (the decoder checked the exact
/// body size against the triple before constructing the view).
#[derive(Debug, Clone, Copy)]
pub struct PayloadView<'a> {
    bytes: &'a [u8],
}

impl<'a> PayloadView<'a> {
    /// Number of f32 elements in the view.
    pub fn len(&self) -> usize {
        self.bytes.len() / 4
    }

    /// True when the operand carries no elements.
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// The raw borrowed bytes (little-endian f32s).
    pub fn bytes(&self) -> &'a [u8] {
        self.bytes
    }

    // LINT: hot-path
    /// Decode the borrowed bytes into a caller-pooled buffer.  `out` is
    /// cleared and refilled in place: once its capacity has plateaued
    /// this performs zero allocations — the property the hotpath bench
    /// gates as `allocs_per_request.net_decode`.
    pub fn copy_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend(
            self.bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
    }

    /// Decode into a fresh `Vec` (cold paths and tests).
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len());
        self.copy_into(&mut out);
        out
    }
}

/// A decoded request frame: borrowed hint and operand views into the
/// body slice, plus the fixed header fields by value.
#[derive(Debug, Clone, Copy)]
pub struct RequestFrame<'a> {
    pub request_id: u64,
    /// Deadline budget in microseconds from frame receipt; 0 = none.
    pub deadline_micros: u64,
    pub m: u32,
    pub n: u32,
    pub k: u32,
    pub alpha: f32,
    pub beta: f32,
    /// Artifact hint (may be empty), borrowed from the frame.
    pub hint: &'a str,
    pub a: PayloadView<'a>,
    pub b: PayloadView<'a>,
    pub c: PayloadView<'a>,
}

impl RequestFrame<'_> {
    /// Materialise the one owned copy the fleet API requires: a
    /// `GemmRequest` with owned operand vectors decoded from the
    /// borrowed payload views.
    pub fn to_request(&self) -> GemmRequest {
        GemmRequest {
            m: self.m as usize,
            n: self.n as usize,
            k: self.k as usize,
            a: self.a.to_vec(),
            b: self.b.to_vec(),
            c: self.c.to_vec(),
            alpha: self.alpha,
            beta: self.beta,
        }
    }

    /// Absolute deadline implied by the budget header, anchored at
    /// `now` (the moment the frame was read off the socket).  `None`
    /// when the request carries no budget.
    pub fn deadline_from(&self, now: Instant) -> Option<Instant> {
        if self.deadline_micros == 0 {
            None
        } else {
            Some(now + Duration::from_micros(self.deadline_micros))
        }
    }
}

/// A decoded response frame: the served payload as a borrowed view.
#[derive(Debug, Clone, Copy)]
pub struct ResponseFrame<'a> {
    pub request_id: u64,
    pub out: PayloadView<'a>,
}

/// A decoded status frame: a typed code plus borrowed message text.
#[derive(Debug, Clone, Copy)]
pub struct StatusFrame<'a> {
    pub request_id: u64,
    pub status: WireStatus,
    pub message: &'a str,
}

/// One decoded frame, borrowing from the body it was scanned over.
#[derive(Debug, Clone, Copy)]
pub enum Frame<'a> {
    Request(RequestFrame<'a>),
    Response(ResponseFrame<'a>),
    Status(StatusFrame<'a>),
}

/// Exact body size (bytes) a request with this header must have, or
/// `None` on u64 overflow.
fn request_body_len(m: u32, n: u32, k: u32, hint_len: u16) -> Option<u64> {
    let (m, n, k) = (m as u64, n as u64, k as u64);
    let elems = m
        .checked_mul(k)?
        .checked_add(k.checked_mul(n)?)?
        .checked_add(m.checked_mul(n)?)?;
    elems
        .checked_mul(4)?
        .checked_add(REQUEST_HEADER_BYTES as u64)?
        .checked_add(hint_len as u64)
}

// LINT: hot-path
/// Decode one frame body by offset-scanning into borrowed slices.
/// Performs no allocation and no copying; all failures are typed.
pub fn decode(body: &[u8]) -> Result<Frame<'_>, ProtocolError> {
    let magic = bytes_at::<4>(body, 0)?;
    if magic != MAGIC {
        return Err(ProtocolError::BadMagic { got: magic });
    }
    let version = u16_at(body, 4)?;
    if version != VERSION {
        return Err(ProtocolError::VersionSkew { got: version, want: VERSION });
    }
    let kind = u16_at(body, 6)?;
    let request_id = u64_at(body, 8)?;
    match kind {
        KIND_REQUEST => {
            let deadline_micros = u64_at(body, 16)?;
            let m = u32_at(body, 24)?;
            let n = u32_at(body, 28)?;
            let k = u32_at(body, 32)?;
            let alpha = f32_at(body, 36)?;
            let beta = f32_at(body, 40)?;
            let hint_len = u16_at(body, 44)?;
            // offset 46: reserved u16, ignored on decode.
            let want = request_body_len(m, n, k, hint_len)
                .ok_or(ProtocolError::OperandOverflow { m, n, k })?;
            if want != body.len() as u64 {
                return Err(ProtocolError::LengthMismatch { want, got: body.len() as u64 });
            }
            // `want` fits the actual body, so every offset below is in
            // bounds; index arithmetic stays in usize range.
            let hint_start = REQUEST_HEADER_BYTES;
            let hint_end = hint_start + hint_len as usize;
            let hint = std::str::from_utf8(&body[hint_start..hint_end])
                .map_err(|_| ProtocolError::BadUtf8 { field: "artifact hint" })?;
            let a_end = hint_end + (m as usize) * (k as usize) * 4;
            let b_end = a_end + (k as usize) * (n as usize) * 4;
            let c_end = b_end + (m as usize) * (n as usize) * 4;
            Ok(Frame::Request(RequestFrame {
                request_id,
                deadline_micros,
                m,
                n,
                k,
                alpha,
                beta,
                hint,
                a: PayloadView { bytes: &body[hint_end..a_end] },
                b: PayloadView { bytes: &body[a_end..b_end] },
                c: PayloadView { bytes: &body[b_end..c_end] },
            }))
        }
        KIND_RESPONSE => {
            let elems = u32_at(body, 16)?;
            let want = (elems as u64)
                .checked_mul(4)
                .and_then(|b| b.checked_add(RESPONSE_HEADER_BYTES as u64))
                .ok_or(ProtocolError::OperandOverflow { m: elems, n: 1, k: 0 })?;
            if want != body.len() as u64 {
                return Err(ProtocolError::LengthMismatch { want, got: body.len() as u64 });
            }
            Ok(Frame::Response(ResponseFrame {
                request_id,
                out: PayloadView { bytes: &body[RESPONSE_HEADER_BYTES..] },
            }))
        }
        KIND_STATUS => {
            let code = u16_at(body, 16)?;
            let status =
                WireStatus::from_code(code).ok_or(ProtocolError::BadStatusCode { got: code })?;
            let msg_len = u16_at(body, 18)?;
            let want = STATUS_HEADER_BYTES as u64 + msg_len as u64;
            if want != body.len() as u64 {
                return Err(ProtocolError::LengthMismatch { want, got: body.len() as u64 });
            }
            let message = std::str::from_utf8(&body[STATUS_HEADER_BYTES..])
                .map_err(|_| ProtocolError::BadUtf8 { field: "status message" })?;
            Ok(Frame::Status(StatusFrame { request_id, status, message }))
        }
        other => Err(ProtocolError::BadKind { got: other }),
    }
}

// ---------------------------------------------------------------------------
// Encoders.  Each writes a full wire frame (length prefix + body) into a
// caller-owned buffer that is cleared and refilled in place, so a
// connection's encode buffer reaches steady state with zero allocations.
// ---------------------------------------------------------------------------

fn put_common_header(buf: &mut Vec<u8>, kind: u16, request_id: u64) {
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&kind.to_le_bytes());
    buf.extend_from_slice(&request_id.to_le_bytes());
}

/// Patch the 4-byte length prefix once the body is fully written, and
/// enforce the frame cap on our own output.
fn seal(buf: &mut [u8]) -> Result<(), ProtocolError> {
    let body_len = buf.len() - 4;
    if body_len as u64 > MAX_FRAME_BYTES as u64 {
        return Err(ProtocolError::Oversized { len: body_len as u32, max: MAX_FRAME_BYTES });
    }
    buf[..4].copy_from_slice(&(body_len as u32).to_le_bytes());
    Ok(())
}

// LINT: hot-path
/// Encode a request frame into `buf` (cleared first).  Validates that
/// the operand vector lengths match the triple and that the hint fits
/// the u16 length field; dimension/size lies are impossible by
/// construction on the encode side.
pub fn encode_request_into(
    buf: &mut Vec<u8>,
    request_id: u64,
    deadline_micros: u64,
    hint: &str,
    req: &GemmRequest,
) -> Result<(), ProtocolError> {
    if hint.len() > u16::MAX as usize {
        return Err(ProtocolError::HintTooLong { len: hint.len() });
    }
    let (m, n, k) = (req.m as u64, req.n as u64, req.k as u64);
    if m > u32::MAX as u64 || n > u32::MAX as u64 || k > u32::MAX as u64 {
        return Err(ProtocolError::OperandOverflow {
            m: req.m.min(u32::MAX as usize) as u32,
            n: req.n.min(u32::MAX as usize) as u32,
            k: req.k.min(u32::MAX as usize) as u32,
        });
    }
    let (m32, n32, k32) = (req.m as u32, req.n as u32, req.k as u32);
    if req.a.len() as u64 != m * k || req.b.len() as u64 != k * n || req.c.len() as u64 != m * n {
        let want = request_body_len(m32, n32, k32, hint.len() as u16)
            .ok_or(ProtocolError::OperandOverflow { m: m32, n: n32, k: k32 })?;
        let got = REQUEST_HEADER_BYTES as u64
            + hint.len() as u64
            + 4 * (req.a.len() as u64 + req.b.len() as u64 + req.c.len() as u64);
        return Err(ProtocolError::LengthMismatch { want, got });
    }
    let body = request_body_len(m32, n32, k32, hint.len() as u16)
        .ok_or(ProtocolError::OperandOverflow { m: m32, n: n32, k: k32 })?;
    if body > MAX_FRAME_BYTES as u64 {
        return Err(ProtocolError::Oversized { len: u32::MAX, max: MAX_FRAME_BYTES });
    }
    buf.clear();
    buf.extend_from_slice(&[0u8; 4]); // length prefix, patched by seal()
    put_common_header(buf, KIND_REQUEST, request_id);
    buf.extend_from_slice(&deadline_micros.to_le_bytes());
    buf.extend_from_slice(&m32.to_le_bytes());
    buf.extend_from_slice(&n32.to_le_bytes());
    buf.extend_from_slice(&k32.to_le_bytes());
    buf.extend_from_slice(&req.alpha.to_le_bytes());
    buf.extend_from_slice(&req.beta.to_le_bytes());
    buf.extend_from_slice(&(hint.len() as u16).to_le_bytes());
    buf.extend_from_slice(&0u16.to_le_bytes()); // reserved
    buf.extend_from_slice(hint.as_bytes());
    for operand in [&req.a, &req.b, &req.c] {
        for v in operand {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    seal(buf)
}

// LINT: hot-path
/// Encode a response frame carrying a served payload into `buf`.
pub fn encode_response_into(
    buf: &mut Vec<u8>,
    request_id: u64,
    out: &[f32],
) -> Result<(), ProtocolError> {
    if out.len() as u64 * 4 + RESPONSE_HEADER_BYTES as u64 > MAX_FRAME_BYTES as u64 {
        return Err(ProtocolError::Oversized { len: u32::MAX, max: MAX_FRAME_BYTES });
    }
    buf.clear();
    buf.extend_from_slice(&[0u8; 4]);
    put_common_header(buf, KIND_RESPONSE, request_id);
    buf.extend_from_slice(&(out.len() as u32).to_le_bytes());
    for v in out {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    seal(buf)
}

/// Encode a typed status frame into `buf`.  Messages longer than the
/// u16 length field are truncated at a char boundary, never rejected —
/// a status must always be deliverable.
pub fn encode_status_into(
    buf: &mut Vec<u8>,
    request_id: u64,
    status: WireStatus,
    message: &str,
) -> Result<(), ProtocolError> {
    let mut end = message.len().min(u16::MAX as usize);
    while end > 0 && !message.is_char_boundary(end) {
        end -= 1;
    }
    let msg = &message[..end];
    buf.clear();
    buf.extend_from_slice(&[0u8; 4]);
    put_common_header(buf, KIND_STATUS, request_id);
    buf.extend_from_slice(&status.code().to_le_bytes());
    buf.extend_from_slice(&(msg.len() as u16).to_le_bytes());
    buf.extend_from_slice(msg.as_bytes());
    seal(buf)
}

/// Read one length-prefixed frame body from `r` into `buf` (resized in
/// place; zero allocations once its capacity plateaus) and return the
/// body slice.  Returns `Ok(None)` on a clean EOF at a frame boundary.
/// A stream that dies mid-prefix or mid-body yields a typed
/// `Io(UnexpectedEof)`; a prefix beyond [`MAX_FRAME_BYTES`] yields
/// `Protocol(Oversized)` before a single body byte is buffered — both
/// are connection-fatal, neither can hang or over-allocate.
pub fn read_frame<'a>(
    r: &mut impl io::Read,
    buf: &'a mut Vec<u8>,
) -> Result<Option<&'a [u8]>, NetError> {
    let mut prefix = [0u8; 4];
    // First byte by hand so a clean close between frames is Ok(None)
    // while a mid-prefix close is a typed UnexpectedEof.
    let mut got = 0usize;
    while got == 0 {
        match r.read(&mut prefix[..1]) {
            Ok(0) => return Ok(None),
            Ok(n) => got = n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(NetError::Io(e)),
        }
    }
    r.read_exact(&mut prefix[1..])?;
    let len = u32::from_le_bytes(prefix);
    if len > MAX_FRAME_BYTES {
        return Err(NetError::Protocol(ProtocolError::Oversized { len, max: MAX_FRAME_BYTES }));
    }
    buf.resize(len as usize, 0);
    r.read_exact(buf)?;
    Ok(Some(&buf[..]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request() -> GemmRequest {
        let (m, n, k) = (2usize, 3usize, 4usize);
        GemmRequest {
            m,
            n,
            k,
            a: (0..m * k).map(|i| i as f32 * 0.5).collect(),
            b: (0..k * n).map(|i| 1.0 - i as f32).collect(),
            c: (0..m * n).map(|i| i as f32).collect(),
            alpha: 1.5,
            beta: -0.25,
        }
    }

    fn body(frame: &[u8]) -> &[u8] {
        &frame[4..]
    }

    #[test]
    fn request_round_trips() {
        let req = sample_request();
        let mut buf = Vec::new();
        encode_request_into(&mut buf, 42, 7_000, "xgemm_128", &req).unwrap();
        let prefix = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
        assert_eq!(prefix as usize, buf.len() - 4);
        match decode(body(&buf)).unwrap() {
            Frame::Request(rf) => {
                assert_eq!(rf.request_id, 42);
                assert_eq!(rf.deadline_micros, 7_000);
                assert_eq!((rf.m, rf.n, rf.k), (2, 3, 4));
                assert_eq!(rf.alpha, 1.5);
                assert_eq!(rf.beta, -0.25);
                assert_eq!(rf.hint, "xgemm_128");
                assert_eq!(rf.a.to_vec(), req.a);
                assert_eq!(rf.b.to_vec(), req.b);
                assert_eq!(rf.c.to_vec(), req.c);
                let owned = rf.to_request();
                assert_eq!(owned.m, req.m);
                assert_eq!(owned.c, req.c);
            }
            other => panic!("expected request frame, got {other:?}"),
        }
    }

    #[test]
    fn response_and_status_round_trip() {
        let mut buf = Vec::new();
        let out = [1.0f32, -2.5, 3.25];
        encode_response_into(&mut buf, 9, &out).unwrap();
        match decode(body(&buf)).unwrap() {
            Frame::Response(rf) => {
                assert_eq!(rf.request_id, 9);
                assert_eq!(rf.out.to_vec(), out);
            }
            other => panic!("expected response frame, got {other:?}"),
        }
        encode_status_into(&mut buf, 11, WireStatus::Shed, "queue full").unwrap();
        match decode(body(&buf)).unwrap() {
            Frame::Status(sf) => {
                assert_eq!(sf.request_id, 11);
                assert_eq!(sf.status, WireStatus::Shed);
                assert_eq!(sf.message, "queue full");
            }
            other => panic!("expected status frame, got {other:?}"),
        }
    }

    #[test]
    fn copy_into_reuses_capacity() {
        let mut buf = Vec::new();
        encode_request_into(&mut buf, 1, 0, "", &sample_request()).unwrap();
        let Frame::Request(rf) = decode(body(&buf)).unwrap() else {
            panic!("expected request frame");
        };
        let mut pool = Vec::with_capacity(rf.a.len());
        let cap = pool.capacity();
        rf.a.copy_into(&mut pool);
        assert_eq!(pool.len(), rf.a.len());
        assert_eq!(pool.capacity(), cap);
    }

    #[test]
    fn status_codes_round_trip() {
        for status in [
            WireStatus::Shed,
            WireStatus::Quarantined,
            WireStatus::Rejected,
            WireStatus::Expired,
            WireStatus::Drained,
            WireStatus::Busy,
            WireStatus::Error,
            WireStatus::Malformed,
        ] {
            assert_eq!(WireStatus::from_code(status.code()), Some(status));
        }
        assert_eq!(WireStatus::from_code(0), None);
        assert_eq!(WireStatus::from_code(999), None);
    }

    #[test]
    fn typed_errors_for_malformed_bodies() {
        let mut buf = Vec::new();
        encode_request_into(&mut buf, 5, 0, "hint", &sample_request()).unwrap();
        let good = body(&buf).to_vec();

        // Empty and short bodies: Truncated.
        assert!(matches!(decode(&[]), Err(ProtocolError::Truncated { .. })));
        assert!(matches!(decode(&good[..3]), Err(ProtocolError::Truncated { .. })));

        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode(&bad), Err(ProtocolError::BadMagic { .. })));

        // Version skew.
        let mut bad = good.clone();
        bad[4] = 2;
        assert_eq!(
            decode(&bad),
            Err(ProtocolError::VersionSkew { got: 2, want: VERSION })
        );

        // Unknown kind.
        let mut bad = good.clone();
        bad[6] = 9;
        assert_eq!(decode(&bad), Err(ProtocolError::BadKind { got: 9 }));

        // Truncated payload: LengthMismatch, not a slice panic.
        let short = &good[..good.len() - 1];
        assert!(matches!(decode(short), Err(ProtocolError::LengthMismatch { .. })));

        // Lying dimension field: LengthMismatch.
        let mut bad = good.clone();
        bad[24] = bad[24].wrapping_add(1);
        assert!(matches!(decode(&bad), Err(ProtocolError::LengthMismatch { .. })));

        // Pathological triple: OperandOverflow, no attempt to size it.
        let mut bad = good.clone();
        for off in [24, 28, 32] {
            bad[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        }
        assert!(matches!(decode(&bad), Err(ProtocolError::OperandOverflow { .. })));

        // Non-UTF-8 hint bytes.
        let mut bad = good.clone();
        bad[REQUEST_HEADER_BYTES] = 0xFF;
        bad[REQUEST_HEADER_BYTES + 1] = 0xFE;
        assert!(matches!(decode(&bad), Err(ProtocolError::BadUtf8 { .. })));

        // Unassigned status code.
        encode_status_into(&mut buf, 1, WireStatus::Busy, "x").unwrap();
        let mut bad = body(&buf).to_vec();
        bad[16..18].copy_from_slice(&77u16.to_le_bytes());
        assert_eq!(decode(&bad), Err(ProtocolError::BadStatusCode { got: 77 }));
    }

    #[test]
    fn encode_rejects_inconsistent_requests() {
        let mut req = sample_request();
        req.a.pop();
        let mut buf = Vec::new();
        assert!(matches!(
            encode_request_into(&mut buf, 1, 0, "", &req),
            Err(ProtocolError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn status_message_truncates_at_char_boundary() {
        let long = "é".repeat(40_000); // 80k bytes, > u16::MAX
        let mut buf = Vec::new();
        encode_status_into(&mut buf, 3, WireStatus::Error, &long).unwrap();
        let Frame::Status(sf) = decode(body(&buf)).unwrap() else {
            panic!("expected status frame");
        };
        assert!(sf.message.len() <= u16::MAX as usize);
        assert!(sf.message.chars().all(|ch| ch == 'é'));
    }

    #[test]
    fn read_frame_eof_and_truncation() {
        let req = sample_request();
        let mut wire = Vec::new();
        encode_request_into(&mut wire, 8, 0, "", &req).unwrap();

        // Whole frame then clean EOF.
        let mut cursor = io::Cursor::new(wire.clone());
        let mut buf = Vec::new();
        let got = read_frame(&mut cursor, &mut buf).unwrap().unwrap();
        assert!(matches!(decode(got), Ok(Frame::Request(_))));
        assert!(read_frame(&mut cursor, &mut buf).unwrap().is_none());

        // Stream dies mid-prefix: typed io error, not a hang or Ok(None).
        let mut cursor = io::Cursor::new(wire[..2].to_vec());
        match read_frame(&mut cursor, &mut buf) {
            Err(NetError::Io(e)) => assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof),
            other => panic!("expected io error, got {other:?}"),
        }

        // Stream dies mid-body: same.
        let mut cursor = io::Cursor::new(wire[..wire.len() - 3].to_vec());
        match read_frame(&mut cursor, &mut buf) {
            Err(NetError::Io(e)) => assert_eq!(e.kind(), io::ErrorKind::UnexpectedEof),
            other => panic!("expected io error, got {other:?}"),
        }

        // Oversized prefix: typed protocol error before buffering.
        let mut huge = ((MAX_FRAME_BYTES as u64 + 1) as u32).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0u8; 8]);
        let mut cursor = io::Cursor::new(huge);
        match read_frame(&mut cursor, &mut buf) {
            Err(NetError::Protocol(ProtocolError::Oversized { .. })) => {}
            other => panic!("expected oversized error, got {other:?}"),
        }
    }

    #[test]
    fn request_id_hint_is_best_effort() {
        let mut buf = Vec::new();
        encode_request_into(&mut buf, 0xDEAD_BEEF, 0, "", &sample_request()).unwrap();
        assert_eq!(request_id_hint(body(&buf)), 0xDEAD_BEEF);
        assert_eq!(request_id_hint(&[1, 2, 3]), 0);
    }
}
