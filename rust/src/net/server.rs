//! The network front door: a dependency-free thread-per-connection
//! acceptor that speaks the wire protocol in `net::wire` and feeds the
//! in-process fleet through [`ServerHandle`].
//!
//! ## Threading model
//!
//! One acceptor thread polls a non-blocking listener.  Each accepted
//! connection gets a **reader** thread (owns the socket's read half,
//! decodes frames, submits to the fleet) and a **responder** thread
//! (owns the write half, answers in request order).  The two halves are
//! joined by an in-order channel of [`Reply`] values, so responses are
//! written back in the order requests arrived on that connection —
//! request ids are echoed verbatim for clients that pipeline.
//!
//! ## Backpressure — never buffer, always answer
//!
//! Two bounds stand between a socket flood and memory growth:
//!
//! 1. **Per-connection in-flight cap** ([`NetConfig::max_inflight`],
//!    enforced by an [`AdmissionGauge`]): a connection with that many
//!    unanswered requests gets an immediate typed `Busy` status frame —
//!    the frame is dropped, nothing queues.
//! 2. **Fleet admission**: admitted frames go through
//!    `ServerHandle::try_submit{_with_deadline}` and a refusal maps
//!    1:1 onto a typed status frame — `Admission::Shed` → `Shed`,
//!    `Admission::Quarantined` → `Quarantined`, `Admission::Rejected`
//!    → `Rejected`.  The server never buffers on behalf of a full
//!    class.
//!
//! Deadline budgets stamped in the request header become absolute
//! `Instant` deadlines at frame-read time, so queue-expiry,
//! pressure-pick and retry semantics all work end to end over the wire
//! exactly as they do in-process.
//!
//! ## Graceful drain
//!
//! [`NetServer::shutdown`] stops the acceptor, then shuts down the
//! *read* half of every live connection: readers stop admitting new
//! frames while responders keep draining — every in-flight request is
//! answered (with its result, or a typed `Drained` status if the fleet
//! shut down underneath it) before the connection closes.

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::coordinator::{Admission, GemmResponse, RequestOutcome, ServerHandle};
use crate::util::sync::{AdmissionGauge, AtomicBool, AtomicU64, Ordering};

use super::wire::{
    self, encode_response_into, encode_status_into, request_id_hint, Frame, NetError, WireStatus,
};

/// Front-door tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    /// Max unanswered requests a single connection may have in flight
    /// before new frames are refused with a typed `Busy` status.
    pub max_inflight: usize,
    /// Acceptor poll interval while the listener has no pending
    /// connection (the listener runs non-blocking so shutdown is
    /// bounded by one poll).
    pub accept_poll: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { max_inflight: 32, accept_poll: Duration::from_millis(10) }
    }
}

/// Monotonic front-door counters, shared across connection threads.
#[derive(Debug, Default)]
struct NetCounters {
    accepted: AtomicU64,
    served: AtomicU64,
    shed: AtomicU64,
    quarantined: AtomicU64,
    rejected: AtomicU64,
    busy: AtomicU64,
    expired: AtomicU64,
    drained: AtomicU64,
    errors: AtomicU64,
    malformed: AtomicU64,
}

impl NetCounters {
    // RELAXED: monotonic stats counters bumped from connection threads
    // and read only for reporting/reconciliation after joins — no
    // ordering-dependent reader.
    fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> NetStats {
        // RELAXED: see `bump` — reconciliation reads happen after the
        // connection threads are joined.
        NetStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            busy: self.busy.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            drained: self.drained.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            malformed: self.malformed.load(Ordering::Relaxed),
        }
    }
}

/// A snapshot of the front door's counters — the wire-side ledger the
/// overload experiment reconciles against the fleet's `ServeStats`
/// (every shed status frame on the wire must have a fleet-side shed
/// behind it, and vice versa).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted.
    pub accepted: u64,
    /// Requests answered with a response payload.
    pub served: u64,
    /// Requests answered with a `Shed` status frame.
    pub shed: u64,
    /// Requests answered with a `Quarantined` status frame.
    pub quarantined: u64,
    /// Requests answered with a `Rejected` status frame.
    pub rejected: u64,
    /// Frames refused by the per-connection in-flight cap.
    pub busy: u64,
    /// Requests answered with an `Expired` status frame.
    pub expired: u64,
    /// Requests answered with a `Drained` status frame.
    pub drained: u64,
    /// Requests answered with an `Error` status frame.
    pub errors: u64,
    /// Frames answered with a `Malformed` status frame.
    pub malformed: u64,
}

impl NetStats {
    /// Every request-level answer the front door sent (excludes
    /// `accepted`, which counts connections).
    pub fn answered(&self) -> u64 {
        self.served
            + self.shed
            + self.quarantined
            + self.rejected
            + self.busy
            + self.expired
            + self.drained
            + self.errors
            + self.malformed
    }
}

/// One in-order unit of work for a connection's responder thread.
enum Reply {
    /// An admitted request: the fleet will answer on `rx`.
    Pending { id: u64, rx: mpsc::Receiver<GemmResponse> },
    /// An immediate typed refusal (busy/shed/quarantined/rejected/
    /// malformed) — encoded and written as-is.
    Status { id: u64, status: WireStatus, message: String },
}

/// The listening front door.  Dropping the handle without calling
/// [`NetServer::shutdown`] aborts the acceptor but does not join
/// connections; call `shutdown` for a graceful drain.
#[derive(Debug)]
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
    accept_thread: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    streams: Arc<Mutex<Vec<TcpStream>>>,
}

impl NetServer {
    /// Bind the front door and start accepting.  `addr` may carry port
    /// 0 for an OS-assigned port; the resolved address is available via
    /// [`NetServer::local_addr`].
    pub fn bind(
        addr: SocketAddr,
        handle: ServerHandle,
        cfg: NetConfig,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(NetCounters::default());
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let streams: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));

        let accept_thread = {
            let stop = Arc::clone(&stop);
            let counters = Arc::clone(&counters);
            let conns = Arc::clone(&conns);
            let streams = Arc::clone(&streams);
            thread::spawn(move || {
                // RELAXED: shutdown flag polled once per accept loop;
                // a one-poll-late observation only delays shutdown by
                // `accept_poll`.
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            NetCounters::bump(&counters.accepted);
                            if stream.set_nonblocking(false).is_err()
                                || stream.set_nodelay(true).is_err()
                            {
                                continue;
                            }
                            let Ok(read_half) = stream.try_clone() else { continue };
                            // Registry clone shares the socket: drain-time
                            // Shutdown::Read lands on every half at once.
                            streams.lock().unwrap().push(stream);
                            let handle = handle.clone();
                            let counters = Arc::clone(&counters);
                            let worker = thread::spawn(move || {
                                serve_connection(read_half, handle, cfg, counters);
                            });
                            conns.lock().unwrap().push(worker);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(cfg.accept_poll);
                        }
                        Err(_) => thread::sleep(cfg.accept_poll),
                    }
                }
            })
        };

        Ok(NetServer {
            local_addr,
            stop,
            counters,
            accept_thread: Some(accept_thread),
            conns,
            streams,
        })
    }

    /// The address the front door is actually listening on.
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Snapshot the wire-side counters.
    pub fn stats(&self) -> NetStats {
        self.counters.snapshot()
    }

    /// Graceful drain: stop accepting, stop reading new frames on every
    /// live connection, and join the connection threads — responders
    /// answer every in-flight request before their connection closes.
    /// The fleet (`GemmServer`) is the caller's to shut down afterwards.
    pub fn shutdown(mut self) -> NetStats {
        // RELAXED: paired with the acceptor's poll; see bind().
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Unblock every reader: no new frames are admitted, but the
        // write halves stay open for the responders to drain.
        for stream in self.streams.lock().unwrap().drain(..) {
            let _ = stream.shutdown(Shutdown::Read);
        }
        let workers: Vec<JoinHandle<()>> = self.conns.lock().unwrap().drain(..).collect();
        for w in workers {
            let _ = w.join();
        }
        self.counters.snapshot()
    }
}

/// Map an unhappy fleet outcome onto its wire status.
fn status_for_outcome(outcome: RequestOutcome) -> WireStatus {
    match outcome {
        RequestOutcome::Ok => WireStatus::Error, // unreachable by construction; callers gate on Ok
        RequestOutcome::Error => WireStatus::Error,
        RequestOutcome::Expired => WireStatus::Expired,
        RequestOutcome::Drained => WireStatus::Drained,
        RequestOutcome::Quarantined => WireStatus::Quarantined,
    }
}

/// Reader half of one connection: decode frames, submit to the fleet,
/// hand replies (in arrival order) to the responder thread.
fn serve_connection(
    stream: TcpStream,
    handle: ServerHandle,
    cfg: NetConfig,
    counters: Arc<NetCounters>,
) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let inflight = Arc::new(AdmissionGauge::new(cfg.max_inflight));

    let responder = {
        let inflight = Arc::clone(&inflight);
        let counters = Arc::clone(&counters);
        thread::Builder::new()
            .name("net-responder".into())
            .spawn(move || respond_loop(write_half, &reply_rx, &inflight, &counters))
    };
    let Ok(responder) = responder else { return };

    let mut read = stream;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let body = match wire::read_frame(&mut read, &mut buf) {
            Ok(Some(body)) => body,
            // Clean EOF (or drain-time Shutdown::Read): stop reading.
            Ok(None) => break,
            Err(NetError::Io(_)) => break,
            Err(NetError::Protocol(e)) => {
                // A lying length prefix poisons the stream framing:
                // answer once, then close.
                NetCounters::bump(&counters.malformed);
                let _ = reply_tx.send(Reply::Status {
                    id: 0,
                    status: WireStatus::Malformed,
                    message: e.to_string(),
                });
                break;
            }
        };
        let frame = match wire::decode(body) {
            Ok(f) => f,
            Err(e) => {
                // The body was length-complete, so framing is intact:
                // answer the offending frame and keep the connection.
                NetCounters::bump(&counters.malformed);
                let _ = reply_tx.send(Reply::Status {
                    id: request_id_hint(body),
                    status: WireStatus::Malformed,
                    message: e.to_string(),
                });
                continue;
            }
        };
        match frame {
            Frame::Request(rf) => {
                let id = rf.request_id;
                if inflight.try_reserve().is_none() {
                    // Socket-level backpressure: refuse instead of
                    // buffering; the client sees a typed Busy.
                    NetCounters::bump(&counters.busy);
                    let _ = reply_tx.send(Reply::Status {
                        id,
                        status: WireStatus::Busy,
                        message: format!(
                            "connection at its in-flight cap ({})",
                            inflight.capacity()
                        ),
                    });
                    continue;
                }
                let now = Instant::now();
                let req = rf.to_request();
                let admission = match rf.deadline_from(now) {
                    Some(deadline) => handle.try_submit_with_deadline(req, deadline),
                    None => handle.try_submit(req),
                };
                // Only an admitted request holds its in-flight slot;
                // refusals release immediately — the responder releases
                // the Pending slot once the answer is written.
                let reply = match admission {
                    Admission::Enqueued(rx) => Reply::Pending { id, rx },
                    Admission::Shed { device, outstanding, capacity, .. } => {
                        inflight.release();
                        NetCounters::bump(&counters.shed);
                        Reply::Status {
                            id,
                            status: WireStatus::Shed,
                            message: format!(
                                "all classes at queue bound (least-loaded {device:?}: \
                                 {outstanding}/{capacity})"
                            ),
                        }
                    }
                    Admission::Quarantined { device, .. } => {
                        inflight.release();
                        NetCounters::bump(&counters.quarantined);
                        Reply::Status {
                            id,
                            status: WireStatus::Quarantined,
                            message: format!("fleet quarantined (retry probes {device:?})"),
                        }
                    }
                    Admission::Rejected { reason } => {
                        inflight.release();
                        NetCounters::bump(&counters.rejected);
                        Reply::Status { id, status: WireStatus::Rejected, message: reason }
                    }
                };
                if reply_tx.send(reply).is_err() {
                    break;
                }
            }
            Frame::Response(rf) => {
                NetCounters::bump(&counters.malformed);
                let send = reply_tx.send(Reply::Status {
                    id: rf.request_id,
                    status: WireStatus::Malformed,
                    message: "unexpected response frame from client".into(),
                });
                if send.is_err() {
                    break;
                }
            }
            Frame::Status(sf) => {
                NetCounters::bump(&counters.malformed);
                let send = reply_tx.send(Reply::Status {
                    id: sf.request_id,
                    status: WireStatus::Malformed,
                    message: "unexpected status frame from client".into(),
                });
                if send.is_err() {
                    break;
                }
            }
        }
    }
    // Dropping the sender lets the responder drain every queued reply
    // and exit — the graceful-drain guarantee.
    drop(reply_tx);
    let _ = responder.join();
}

/// Responder half: answer every reply in order, counting terminal
/// outcomes and releasing the in-flight gauge as each admitted request
/// is answered.
fn respond_loop(
    mut stream: TcpStream,
    replies: &mpsc::Receiver<Reply>,
    inflight: &AdmissionGauge,
    counters: &NetCounters,
) {
    let mut buf: Vec<u8> = Vec::new();
    let mut write_ok = true;
    for reply in replies.iter() {
        let encoded = match reply {
            Reply::Status { id, status, message } => {
                encode_status_into(&mut buf, id, status, &message)
            }
            Reply::Pending { id, rx } => {
                let encoded = match rx.recv() {
                    Ok(resp) => match (&resp.out, resp.outcome) {
                        (Ok(out), RequestOutcome::Ok) => {
                            NetCounters::bump(&counters.served);
                            encode_response_into(&mut buf, id, out)
                        }
                        (_, outcome) => {
                            let status = status_for_outcome(outcome);
                            NetCounters::bump(match status {
                                WireStatus::Expired => &counters.expired,
                                WireStatus::Drained => &counters.drained,
                                WireStatus::Quarantined => &counters.quarantined,
                                WireStatus::Shed => &counters.shed,
                                WireStatus::Rejected => &counters.rejected,
                                WireStatus::Busy => &counters.busy,
                                WireStatus::Malformed => &counters.malformed,
                                WireStatus::Error => &counters.errors,
                            });
                            let message = match &resp.out {
                                Ok(_) => status.name().to_string(),
                                Err(e) => e.to_string(),
                            };
                            encode_status_into(&mut buf, id, status, &message)
                        }
                    },
                    // The fleet dropped the sender (hard shutdown): the
                    // request can never be answered with a result, but
                    // the connection still gets a typed status.
                    Err(_) => {
                        NetCounters::bump(&counters.drained);
                        encode_status_into(
                            &mut buf,
                            id,
                            WireStatus::Drained,
                            "server shut down before answering",
                        )
                    }
                };
                inflight.release();
                encoded
            }
        };
        if write_ok {
            write_ok = encoded.is_ok()
                && stream.write_all(&buf).is_ok()
                && stream.flush().is_ok();
        }
        // After a write failure keep draining replies (still releasing
        // the gauge) so the reader never wedges, but stop touching the
        // dead socket.
    }
    let _ = stream.shutdown(Shutdown::Write);
}
