//! A minimal blocking client for the wire protocol — the loopback
//! counterpart the integration tests and the overload experiment's
//! network arm drive the front door with.
//!
//! [`NetClient`] is a simple call-style client (send, then recv).  For
//! open-loop sweeps where the sender must keep pacing while replies
//! stream back, [`NetClient::split`] clones the socket into an
//! independently-owned [`NetSender`] / [`NetReceiver`] pair.

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpStream};

use crate::coordinator::GemmRequest;

use super::wire::{self, encode_request_into, Frame, NetError, WireStatus};

/// One decoded answer from the server, owned.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientReply {
    /// The request was served: the result payload, row-major `m*n`.
    Served { id: u64, out: Vec<f32> },
    /// A typed non-payload answer (shed, expired, busy, malformed, …).
    Status { id: u64, status: WireStatus, message: String },
}

impl ClientReply {
    /// The echoed request id, whichever variant arrived.
    pub fn id(&self) -> u64 {
        match self {
            ClientReply::Served { id, .. } => *id,
            ClientReply::Status { id, .. } => *id,
        }
    }
}

fn decode_reply(body: &[u8]) -> Result<ClientReply, NetError> {
    match wire::decode(body)? {
        Frame::Response(rf) => {
            Ok(ClientReply::Served { id: rf.request_id, out: rf.out.to_vec() })
        }
        Frame::Status(sf) => Ok(ClientReply::Status {
            id: sf.request_id,
            status: sf.status,
            message: sf.message.to_string(),
        }),
        // A server must never send a request frame; surface it as a
        // kind violation (1 is the request kind on the wire).
        Frame::Request(_) => {
            Err(NetError::Protocol(wire::ProtocolError::BadKind { got: 1 }))
        }
    }
}

/// Write-half of a split connection.
#[derive(Debug)]
pub struct NetSender {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl NetSender {
    /// Frame and send one request.  The encode buffer is reused across
    /// calls, so a steady-shape workload sends with zero allocations.
    pub fn send(
        &mut self,
        id: u64,
        deadline_micros: u64,
        hint: &str,
        req: &GemmRequest,
    ) -> Result<(), NetError> {
        encode_request_into(&mut self.buf, id, deadline_micros, hint, req)?;
        self.stream.write_all(&self.buf)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Close the write half so the server sees a clean EOF and drains.
    pub fn finish(self) -> Result<(), NetError> {
        self.stream.shutdown(Shutdown::Write)?;
        Ok(())
    }
}

/// Read-half of a split connection.
#[derive(Debug)]
pub struct NetReceiver {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl NetReceiver {
    /// Block for the next reply; `Ok(None)` once the server closes the
    /// connection cleanly.
    pub fn recv(&mut self) -> Result<Option<ClientReply>, NetError> {
        match wire::read_frame(&mut self.stream, &mut self.buf)? {
            Some(body) => Ok(Some(decode_reply(body)?)),
            None => Ok(None),
        }
    }
}

/// A blocking loopback client: one socket, framed requests out,
/// decoded replies back.
#[derive(Debug)]
pub struct NetClient {
    stream: TcpStream,
    write_buf: Vec<u8>,
    read_buf: Vec<u8>,
}

impl NetClient {
    /// Connect to a front door.
    pub fn connect(addr: SocketAddr) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient { stream, write_buf: Vec::new(), read_buf: Vec::new() })
    }

    /// Frame and send one request (replies arrive via [`NetClient::recv`]
    /// in request order).
    pub fn send(
        &mut self,
        id: u64,
        deadline_micros: u64,
        hint: &str,
        req: &GemmRequest,
    ) -> Result<(), NetError> {
        encode_request_into(&mut self.write_buf, id, deadline_micros, hint, req)?;
        self.stream.write_all(&self.write_buf)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Block for the next reply; `Ok(None)` once the server closes the
    /// connection cleanly (graceful drain completed).
    pub fn recv(&mut self) -> Result<Option<ClientReply>, NetError> {
        match wire::read_frame(&mut self.stream, &mut self.read_buf)? {
            Some(body) => Ok(Some(decode_reply(body)?)),
            None => Ok(None),
        }
    }

    /// Send one request and block for its answer — the wire analogue of
    /// `ServerHandle::call`.
    pub fn call(
        &mut self,
        id: u64,
        deadline_micros: u64,
        hint: &str,
        req: &GemmRequest,
    ) -> Result<Option<ClientReply>, NetError> {
        self.send(id, deadline_micros, hint, req)?;
        self.recv()
    }

    /// Split into independently-owned sender/receiver halves (shared
    /// underlying socket) for open-loop send-while-receiving sweeps.
    pub fn split(self) -> std::io::Result<(NetSender, NetReceiver)> {
        let read = self.stream.try_clone()?;
        Ok((
            NetSender { stream: self.stream, buf: self.write_buf },
            NetReceiver { stream: read, buf: self.read_buf },
        ))
    }

    /// Close the write half; the server answers what is in flight and
    /// then closes, so `recv` drains to `Ok(None)`.
    pub fn finish_sending(&mut self) -> Result<(), NetError> {
        self.stream.shutdown(Shutdown::Write)?;
        Ok(())
    }
}
