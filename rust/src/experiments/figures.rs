//! Regenerate the paper's figures (as CSV series + ASCII charts).
//!
//! * Figure 3 — accuracy of every (H, L) model per dataset, both devices
//! * Figure 4 — DTPR / DTTR per model, Nvidia P100
//! * Figure 5 — DTPR / DTTR per model, ARM Mali-T860
//! * Figure 6 — per-triple GFLOPS: model vs default vs peak, P100
//! * Figure 7 — per-triple GFLOPS: model vs default vs peak, Mali

use crate::dataset::DatasetKind;
use crate::device::DeviceId;
use crate::util::csv::CsvWriter;
use crate::util::table;

use super::context::Context;
use super::tables::Rendered;

fn datasets_for(device: DeviceId) -> Vec<DatasetKind> {
    match device {
        DeviceId::MaliT860 => vec![DatasetKind::Po2, DatasetKind::AntonNet],
        _ => vec![DatasetKind::Go2, DatasetKind::Po2, DatasetKind::AntonNet],
    }
}

/// Figure 3: model accuracy across the sweep, one series per dataset.
pub fn fig3(ctx: &mut Context, device: DeviceId) -> Rendered {
    let id = match device {
        DeviceId::NvidiaP100 => "fig3a_p100",
        _ => "fig3b_mali",
    };
    let mut csv = CsvWriter::new(&["dataset", "model", "accuracy_pct"]);
    let mut ascii = String::new();
    for kind in datasets_for(device) {
        let sweep = ctx.sweep(device, kind);
        let series: Vec<(String, f64)> = sweep
            .models
            .iter()
            .map(|m| (m.scores.model.clone(), m.scores.accuracy))
            .collect();
        for (model, acc) in &series {
            csv.row(&[kind.name().into(), model.clone(), table::f(*acc, 1)]);
        }
        ascii.push_str(&table::bar_chart(
            &format!("Figure 3 ({device}): accuracy — dataset {kind}"),
            &series,
            50,
        ));
        ascii.push('\n');
    }
    Rendered { id, ascii, csv }
}

/// Figures 4/5: DTPR and DTTR across the sweep per dataset.
pub fn fig45(ctx: &mut Context, device: DeviceId) -> Rendered {
    let id = match device {
        DeviceId::NvidiaP100 => "fig4_p100",
        _ => "fig5_mali",
    };
    let mut csv = CsvWriter::new(&["dataset", "model", "dtpr", "dttr"]);
    let mut ascii = String::new();
    for kind in datasets_for(device) {
        let sweep = ctx.sweep(device, kind);
        for metric in ["DTPR", "DTTR"] {
            let series: Vec<(String, f64)> = sweep
                .models
                .iter()
                .map(|m| {
                    let v = if metric == "DTPR" { m.scores.dtpr } else { m.scores.dttr };
                    (m.scores.model.clone(), v)
                })
                .collect();
            ascii.push_str(&table::bar_chart(
                &format!("Figure 4/5 ({device}): {metric} — dataset {kind}"),
                &series,
                50,
            ));
            ascii.push('\n');
        }
        for m in &sweep.models {
            csv.row(&[
                kind.name().into(),
                m.scores.model.clone(),
                table::f(m.scores.dtpr, 3),
                table::f(m.scores.dttr, 3),
            ]);
        }
    }
    Rendered { id, ascii, csv }
}

/// Figures 6/7: per-triple GFLOPS of the best model vs default vs peak.
/// One section per dataset the paper plots for that device.
pub fn fig67(ctx: &mut Context, device: DeviceId) -> Rendered {
    let (id, kinds) = match device {
        DeviceId::NvidiaP100 => (
            "fig6_p100",
            vec![DatasetKind::Go2, DatasetKind::Po2, DatasetKind::AntonNet],
        ),
        _ => ("fig7_mali", vec![DatasetKind::Po2, DatasetKind::AntonNet]),
    };
    let mut csv = CsvWriter::new(&[
        "dataset", "m", "n", "k", "gflops_model", "gflops_default",
        "gflops_peak", "speedup_vs_default",
    ]);
    let mut ascii = String::new();
    for &kind in &kinds {
        let sweep = ctx.sweep(device, kind);
        let best = sweep.best_model();
        let mut records = best.records.clone();
        records.sort_by_key(|r| (r.triple.m, r.triple.n, r.triple.k));
        for r in &records {
            csv.row(&[
                kind.name().into(),
                r.triple.m.to_string(),
                r.triple.n.to_string(),
                r.triple.k.to_string(),
                table::f(r.gflops_model, 2),
                table::f(r.gflops_default, 2),
                table::f(r.gflops_peak, 2),
                table::f(r.gflops_model / r.gflops_default.max(1e-12), 3),
            ]);
        }
        // ASCII: subsample ~16 triples for readability.
        let step = (records.len() / 16).max(1);
        let sampled: Vec<_> = records.iter().step_by(step).collect();
        let labels: Vec<String> =
            sampled.iter().map(|r| r.triple.to_string()).collect();
        let series = [
            ("model", sampled.iter().map(|r| r.gflops_model).collect::<Vec<_>>()),
            ("default", sampled.iter().map(|r| r.gflops_default).collect()),
            ("peak", sampled.iter().map(|r| r.gflops_peak).collect()),
        ];
        ascii.push_str(&table::grouped_chart(
            &format!(
                "Figure 6/7 ({device}): GFLOPS over test triples — {} (best model {})",
                kind, best.scores.model
            ),
            &labels,
            &[
                (series[0].0, series[0].1.clone()),
                (series[1].0, series[1].1.clone()),
                (series[2].0, series[2].1.clone()),
            ],
            40,
        ));
        // Headline numbers the paper quotes.
        let max_speedup = records
            .iter()
            .map(|r| r.gflops_model / r.gflops_default.max(1e-12))
            .fold(f64::MIN, f64::max);
        ascii.push_str(&format!(
            "max speedup vs default: {max_speedup:.2}x | DTTR (avg): {:.3}\n\n",
            best.scores.dttr
        ));
    }
    Rendered { id, ascii, csv }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_has_series_per_dataset() {
        let mut ctx = Context::new();
        ctx.model_limit = Some(2);
        let r = fig3(&mut ctx, DeviceId::MaliT860);
        // 2 datasets x 2 models
        assert_eq!(r.csv.len(), 4);
        assert!(r.ascii.contains("accuracy"));
    }

    #[test]
    fn fig67_reports_speedups() {
        let mut ctx = Context::new();
        ctx.model_limit = Some(2);
        let r = fig67(&mut ctx, DeviceId::MaliT860);
        assert!(r.ascii.contains("max speedup vs default"));
        assert!(r.csv.len() > 10);
    }
}
