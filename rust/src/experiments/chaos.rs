//! Chaos experiment: the fleet under *injected faults* — circuit
//! breakers, deadline-aware retry/failover, and recovery, measured
//! end-to-end through the public serving API.
//!
//! Three scenarios run against a two-class simulated fleet (the victim
//! device carries a seeded [`FaultPlan`]; its sibling serves faithfully),
//! all with the sensitive breaker preset and the default retry budget:
//!
//! * **transient** — every victim dispatch fails independently at a
//!   seeded rate.  Failed dispatches retry individually (fused members)
//!   and fail over to the healthy sibling, so offered traffic still
//!   answers `Ok` — availability stays 1.0 and every result is
//!   bit-identical to the `fill * k` oracle.
//! * **sticky** — the victim dies mid-run (`FaultPlan::kill_now`).  The
//!   scenario measures *time-to-quarantine* (kill → breaker `Open`),
//!   serves free waves through the dead phase (routed around the open
//!   class), revives the device and measures *time-to-recovery*
//!   (revive → `HalfOpen` probes → `Closed`), then asserts a zero
//!   post-recovery error rate with the victim serving again.
//! * **latency** — dispatches slow down but never fail: the breaker must
//!   stay `Closed` (latency is not an error) and availability 1.0.
//!
//! Every response is collected with a bounded `recv_timeout` — a hung
//! request (dropped reply channel, lost envelope) is counted and fails
//! the gate, never deadlocks the run.  `BENCH_chaos.json` carries the
//! machine-readable summary; CI gates `chaos_availability_min`,
//! `chaos_post_recovery_error_rate == 0`, `chaos_quarantined`,
//! `chaos_recovered`, `chaos_bit_identical` and `chaos_hung == 0` via
//! `adaptd bench-compare`.

use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::config::Triple;
use crate::coordinator::{
    Admission, BreakerConfig, BreakerState, CircuitBreaker, DeviceClass, GemmServer,
    GemmResponse, RequestOutcome, ServerConfig, ServerHandle,
};
use crate::device::DeviceId;
use crate::engine::{FaultKind, FaultPlan};
use crate::runtime::Manifest;
use crate::testing::fill_request;
use crate::util::json::Json;

use super::hetero::{device_policy, hetero_mix};

/// Knobs of the chaos run.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Free-routed requests per wave.
    pub requests_per_wave: usize,
    /// Waves per scenario phase.
    pub waves: usize,
    /// Dispatcher shards per device class.
    pub shards_per_class: usize,
    /// Fleet device classes; the first is the failover sibling pool.
    pub devices: Vec<DeviceId>,
    /// The device class carrying the fault plan.
    pub victim: DeviceId,
    /// Fault-plan seed (same seed → same fault schedule).
    pub seed: u64,
    /// Transient scenario: per-dispatch failure probability.
    pub transient_rate: f64,
    /// Latency scenario: extra per-dispatch latency.
    pub latency_spike: Duration,
    /// Per-request deadline stamped at submit time.
    pub deadline: Duration,
    /// Response-collection bound: a reply slower than this counts as
    /// *hung* (and fails the gate) instead of deadlocking the run.
    pub recv_timeout: Duration,
    /// How long the sticky scenario waits for the breaker to trip/close
    /// before giving up (a miss fails the quarantine/recovery gate).
    pub breaker_patience: Duration,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            requests_per_wave: 24,
            waves: 2,
            shards_per_class: 1,
            // Simulated devices only: deterministic service, no PJRT
            // measurement noise — the chaos gates test *plumbing*, not
            // kernel speed.  The host class joins via --devices.
            devices: vec![DeviceId::NvidiaP100, DeviceId::MaliT860],
            victim: DeviceId::NvidiaP100,
            seed: 0xC4A0_5EED,
            transient_rate: 0.25,
            latency_spike: Duration::from_millis(2),
            deadline: Duration::from_secs(2),
            recv_timeout: Duration::from_secs(10),
            breaker_patience: Duration::from_secs(5),
        }
    }
}

/// Outcome tally of one scenario.
#[derive(Debug, Clone, Default)]
pub struct ScenarioResult {
    pub name: &'static str,
    /// Requests submitted (free + pinned diagnostic traffic).
    pub offered: usize,
    pub ok: usize,
    pub errors: usize,
    pub expired: usize,
    /// Typed capacity refusals at admission.
    pub shed: usize,
    /// Typed breaker refusals at admission.
    pub quarantined: usize,
    /// Replies that missed the `recv_timeout` bound — envelopes the
    /// server lost.  Must be zero.
    pub hung: usize,
    /// Ok responses that consumed at least one retry.
    pub retried: usize,
    /// Ok responses served by a failover sibling.
    pub failovers: usize,
    /// Ok responses whose payload deviated from the `fill * k` oracle.
    pub mismatches: usize,
    pub breaker_opens: u64,
    pub breaker_closes: u64,
    /// Sticky only: kill → breaker `Open` (None = never tripped).
    pub time_to_quarantine: Option<Duration>,
    /// Sticky only: revive → breaker `Closed` (None = never recovered).
    pub time_to_recovery: Option<Duration>,
    /// Sticky only: offered/error tally of the post-recovery phase.
    pub post_recovery_offered: usize,
    pub post_recovery_errors: usize,
    /// Sticky only: requests the revived victim served post-recovery.
    pub victim_served_after_recovery: usize,
}

impl ScenarioResult {
    /// Fraction of offered requests that got a *timely, typed* answer a
    /// client can act on: `Ok`, a capacity shed, or a quarantine
    /// refusal.  Errors, expiries and hung replies count against it.
    pub fn availability(&self) -> f64 {
        if self.offered == 0 {
            return 1.0;
        }
        (self.ok + self.shed + self.quarantined) as f64 / self.offered as f64
    }

    fn to_json(&self) -> Json {
        let ms = |d: Option<Duration>| match d {
            Some(d) => Json::num(d.as_secs_f64() * 1e3),
            None => Json::Null,
        };
        Json::obj(vec![
            ("name", Json::str(self.name)),
            ("offered", Json::num(self.offered as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("expired", Json::num(self.expired as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("quarantined", Json::num(self.quarantined as f64)),
            ("hung", Json::num(self.hung as f64)),
            ("retried", Json::num(self.retried as f64)),
            ("failovers", Json::num(self.failovers as f64)),
            ("mismatches", Json::num(self.mismatches as f64)),
            ("availability", Json::num(self.availability())),
            ("breaker_opens", Json::num(self.breaker_opens as f64)),
            ("breaker_closes", Json::num(self.breaker_closes as f64)),
            ("time_to_quarantine_ms", ms(self.time_to_quarantine)),
            ("time_to_recovery_ms", ms(self.time_to_recovery)),
            (
                "post_recovery_offered",
                Json::num(self.post_recovery_offered as f64),
            ),
            (
                "post_recovery_errors",
                Json::num(self.post_recovery_errors as f64),
            ),
            (
                "victim_served_after_recovery",
                Json::num(self.victim_served_after_recovery as f64),
            ),
        ])
    }
}

/// The full chaos run.
pub struct ChaosReport {
    pub cfg: ChaosConfig,
    pub mix: Vec<Triple>,
    pub scenarios: Vec<ScenarioResult>,
    pub wall: Duration,
}

impl std::fmt::Debug for ChaosReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChaosReport").finish_non_exhaustive()
    }
}

impl ChaosReport {
    fn sticky(&self) -> Option<&ScenarioResult> {
        self.scenarios.iter().find(|s| s.name == "sticky")
    }

    /// Worst per-scenario availability — the headline gate.
    pub fn availability_min(&self) -> f64 {
        self.scenarios
            .iter()
            .map(|s| s.availability())
            .fold(1.0, f64::min)
    }

    /// Did the sticky scenario's breaker trip within patience?
    pub fn quarantined(&self) -> bool {
        self.sticky().is_some_and(|s| s.time_to_quarantine.is_some())
    }

    /// Did the revived victim close its breaker *and* serve again?
    pub fn recovered(&self) -> bool {
        self.sticky().is_some_and(|s| {
            s.time_to_recovery.is_some() && s.victim_served_after_recovery > 0
        })
    }

    /// Error rate of the post-recovery phase (0.0 when it never ran —
    /// the `recovered` gate catches that case).
    pub fn post_recovery_error_rate(&self) -> f64 {
        match self.sticky() {
            Some(s) if s.post_recovery_offered > 0 => {
                s.post_recovery_errors as f64 / s.post_recovery_offered as f64
            }
            _ => 0.0,
        }
    }

    /// Every Ok payload across every scenario matched the `fill * k`
    /// oracle (vacuously false when nothing was served).
    pub fn bit_identical(&self) -> bool {
        self.scenarios.iter().all(|s| s.mismatches == 0)
            && self.scenarios.iter().map(|s| s.ok).sum::<usize>() > 0
    }

    /// Replies that missed the collection bound, across every scenario.
    pub fn hung(&self) -> usize {
        self.scenarios.iter().map(|s| s.hung).sum()
    }

    pub fn to_json(&self) -> Json {
        let ms = |d: Option<Duration>| match d {
            Some(d) => Json::num(d.as_secs_f64() * 1e3),
            None => Json::Null,
        };
        let sticky = self.sticky();
        Json::obj(vec![
            ("bench", Json::str("chaos")),
            ("requests_per_wave", Json::num(self.cfg.requests_per_wave as f64)),
            ("waves", Json::num(self.cfg.waves as f64)),
            ("shards_per_class", Json::num(self.cfg.shards_per_class as f64)),
            ("victim", Json::str(self.cfg.victim.name())),
            ("transient_rate", Json::num(self.cfg.transient_rate)),
            (
                "mix",
                Json::Arr(self.mix.iter().map(|t| t.to_json()).collect()),
            ),
            (
                "scenarios",
                Json::Arr(self.scenarios.iter().map(|s| s.to_json()).collect()),
            ),
            ("chaos_availability_min", Json::num(self.availability_min())),
            (
                "chaos_post_recovery_error_rate",
                Json::num(self.post_recovery_error_rate()),
            ),
            ("chaos_quarantined", Json::Bool(self.quarantined())),
            ("chaos_recovered", Json::Bool(self.recovered())),
            ("chaos_bit_identical", Json::Bool(self.bit_identical())),
            ("chaos_hung", Json::num(self.hung() as f64)),
            (
                "time_to_quarantine_ms",
                ms(sticky.and_then(|s| s.time_to_quarantine)),
            ),
            (
                "time_to_recovery_ms",
                ms(sticky.and_then(|s| s.time_to_recovery)),
            ),
        ])
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "=== Chaos: victim {} of {:?}, {} waves x {} requests, \
             transient rate {:.2} ===\n",
            self.cfg.victim.name(),
            self.cfg
                .devices
                .iter()
                .map(|d| d.name())
                .collect::<Vec<_>>(),
            self.cfg.waves,
            self.cfg.requests_per_wave,
            self.cfg.transient_rate,
        );
        for r in &self.scenarios {
            s.push_str(&format!(
                "{:<10} offered {:4}  ok {:4}  err {:3}  shed {:3}  \
                 quarantined {:3}  hung {}  retried {:3}  failovers {:3}  \
                 availability {:.4}\n",
                r.name,
                r.offered,
                r.ok,
                r.errors,
                r.shed,
                r.quarantined,
                r.hung,
                r.retried,
                r.failovers,
                r.availability(),
            ));
            if r.name == "sticky" {
                let ms = |d: Option<Duration>| match d {
                    Some(d) => format!("{:.0}ms", d.as_secs_f64() * 1e3),
                    None => "NEVER".into(),
                };
                s.push_str(&format!(
                    "           quarantine in {}  recovery in {}  \
                     post-recovery errors {}/{} (victim served {})\n",
                    ms(r.time_to_quarantine),
                    ms(r.time_to_recovery),
                    r.post_recovery_errors,
                    r.post_recovery_offered,
                    r.victim_served_after_recovery,
                ));
            }
        }
        s.push_str(&format!(
            "availability min {:.4}  bit-identical {}  quarantined {}  \
             recovered {}  hung {}\n",
            self.availability_min(),
            self.bit_identical(),
            self.quarantined(),
            self.recovered(),
            self.hung(),
        ));
        s
    }

    /// Write the machine-readable summary (the CI gate input).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// One in-flight request: its oracle fill value plus the reply channel.
type Pending = (f32, mpsc::Receiver<GemmResponse>);

/// Deterministic fill for the `i`-th request of a scenario — exact in
/// f32 for every mix `k`, so served payloads can be checked bit-for-bit.
fn fill_of(i: usize) -> f32 {
    [1.0f32, 0.5, 2.0, 1.5][i % 4]
}

/// Collect one reply under the timeout bound and tally it.  `expect` is
/// the oracle element value: callers submit `fill_request(m, n, k, fill)`
/// so every output element must equal `fill * k` exactly (bit-identity
/// across retries and sibling failovers).
fn collect(
    res: &mut ScenarioResult,
    expect: f32,
    rx: &mpsc::Receiver<GemmResponse>,
    timeout: Duration,
) -> Option<GemmResponse> {
    let Ok(resp) = rx.recv_timeout(timeout) else {
        res.hung += 1;
        return None;
    };
    match resp.outcome {
        RequestOutcome::Ok => {
            res.ok += 1;
            if resp.retries > 0 {
                res.retried += 1;
            }
            if resp.failover {
                res.failovers += 1;
            }
            if let Ok(out) = &resp.out {
                if out.iter().any(|&x| x != expect) {
                    res.mismatches += 1;
                }
            }
        }
        RequestOutcome::Error => res.errors += 1,
        RequestOutcome::Expired => res.expired += 1,
        RequestOutcome::Quarantined => res.quarantined += 1,
        RequestOutcome::Drained => res.errors += 1,
    }
    Some(resp)
}

/// Submit one free-routed wave and collect every reply.  `expect` is the
/// per-request expected element value (`fill * k`).
fn free_wave(
    handle: &ServerHandle,
    mix: &[Triple],
    n: usize,
    cfg: &ChaosConfig,
    res: &mut ScenarioResult,
) -> Result<Vec<GemmResponse>> {
    let mut pending: Vec<Pending> = Vec::with_capacity(n);
    for i in 0..n {
        let t = mix[i % mix.len()];
        let fill = fill_of(i);
        let req = fill_request(t.m as usize, t.n as usize, t.k as usize, fill);
        let expect = fill * t.k as f32;
        res.offered += 1;
        match handle.try_submit_with_deadline(req, Instant::now() + cfg.deadline) {
            Admission::Enqueued(rx) => pending.push((expect, rx)),
            Admission::Shed { .. } => res.shed += 1,
            Admission::Quarantined { .. } => res.quarantined += 1,
            Admission::Rejected { reason } => {
                anyhow::bail!("invalid chaos request: {reason}")
            }
        }
    }
    let mut replies = Vec::with_capacity(pending.len());
    for (expect, rx) in &pending {
        if let Some(resp) = collect(res, *expect, rx, cfg.recv_timeout) {
            replies.push(resp);
        }
    }
    Ok(replies)
}

/// Submit one burst pinned to `device` (diagnostic traffic: forces
/// coverage through the faulty engine) and collect every reply.
fn pinned_burst(
    handle: &ServerHandle,
    device: DeviceId,
    mix: &[Triple],
    n: usize,
    cfg: &ChaosConfig,
    res: &mut ScenarioResult,
) -> Result<Vec<GemmResponse>> {
    let mut pending: Vec<Pending> = Vec::with_capacity(n);
    for i in 0..n {
        let t = mix[i % mix.len()];
        let fill = fill_of(i);
        let req = fill_request(t.m as usize, t.n as usize, t.k as usize, fill);
        let expect = fill * t.k as f32;
        res.offered += 1;
        match handle
            .try_submit_to(device, req)
            .context("chaos victim not in the fleet")?
        {
            Admission::Enqueued(rx) => pending.push((expect, rx)),
            Admission::Shed { .. } => res.shed += 1,
            Admission::Quarantined { .. } => res.quarantined += 1,
            Admission::Rejected { reason } => {
                anyhow::bail!("invalid chaos request: {reason}")
            }
        }
    }
    let mut replies = Vec::with_capacity(pending.len());
    for (expect, rx) in &pending {
        if let Some(resp) = collect(res, *expect, rx, cfg.recv_timeout) {
            replies.push(resp);
        }
    }
    Ok(replies)
}

/// Poll the victim's breaker until `want` (or patience runs out).
fn await_state(
    breaker: &CircuitBreaker,
    want: BreakerState,
    patience: Duration,
) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < patience {
        if breaker.state() == want {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    breaker.state() == want
}

/// Start a fresh fleet whose victim class carries `plan`.
fn start_fleet(
    artifacts: &Path,
    manifest: &Manifest,
    cfg: &ChaosConfig,
    plan: &FaultPlan,
) -> Result<GemmServer> {
    let mut classes = Vec::new();
    for &d in &cfg.devices {
        let mut class =
            DeviceClass::new(d, cfg.shards_per_class, device_policy(manifest, d)?);
        if d == cfg.victim {
            class = class.with_fault_plan(plan.clone());
        }
        classes.push(class);
    }
    let scfg = ServerConfig {
        shards: cfg.shards_per_class,
        breaker: BreakerConfig::sensitive(),
        // Small fuse keeps the individual-retry path exercised without
        // making batch wall time dominate the scenario clock.
        max_fuse: 8,
        ..ServerConfig::default()
    };
    GemmServer::start_fleet(artifacts, classes, scfg)
}

/// Transient scenario: seeded per-dispatch failures on the victim; free
/// waves plus pinned-victim bursts.  Everything must still answer Ok
/// (retry/failover), bit-identically.
fn run_transient(
    artifacts: &Path,
    manifest: &Manifest,
    mix: &[Triple],
    cfg: &ChaosConfig,
) -> Result<ScenarioResult> {
    let plan = FaultPlan::new(cfg.seed)
        .with_fault(None, FaultKind::Transient { rate: cfg.transient_rate });
    let server = start_fleet(artifacts, manifest, cfg, &plan)?;
    let handle = server.handle();
    let mut res = ScenarioResult { name: "transient", ..Default::default() };
    for _ in 0..cfg.waves.max(1) {
        free_wave(&handle, mix, cfg.requests_per_wave, cfg, &mut res)?;
        // Pinned coverage: the router would otherwise learn to avoid the
        // flaky class and the fault path would go untested.
        pinned_burst(&handle, cfg.victim, mix, mix.len(), cfg, &mut res)?;
    }
    if let Some(b) = server.breaker_for(cfg.victim) {
        res.breaker_opens = b.opens();
        res.breaker_closes = b.closes();
    }
    drop(handle);
    let _ = server.shutdown_now();
    Ok(res)
}

/// Latency scenario: dispatches slow down but never fail — the breaker
/// must stay Closed and availability 1.0.
fn run_latency(
    artifacts: &Path,
    manifest: &Manifest,
    mix: &[Triple],
    cfg: &ChaosConfig,
) -> Result<ScenarioResult> {
    let plan = FaultPlan::new(cfg.seed).with_fault(
        None,
        FaultKind::LatencySpike { rate: 0.5, extra: cfg.latency_spike },
    );
    let server = start_fleet(artifacts, manifest, cfg, &plan)?;
    let handle = server.handle();
    let mut res = ScenarioResult { name: "latency", ..Default::default() };
    for _ in 0..cfg.waves.max(1) {
        free_wave(&handle, mix, cfg.requests_per_wave, cfg, &mut res)?;
        pinned_burst(&handle, cfg.victim, mix, mix.len(), cfg, &mut res)?;
    }
    if let Some(b) = server.breaker_for(cfg.victim) {
        res.breaker_opens = b.opens();
        res.breaker_closes = b.closes();
        anyhow::ensure!(
            b.state() == BreakerState::Closed,
            "latency alone must not trip the breaker"
        );
    }
    drop(handle);
    let _ = server.shutdown_now();
    Ok(res)
}

/// Sticky scenario: healthy phase → mid-run device death → quarantine →
/// dead-phase serving around the open class → revive → probe recovery →
/// post-recovery verification.
fn run_sticky(
    artifacts: &Path,
    manifest: &Manifest,
    mix: &[Triple],
    cfg: &ChaosConfig,
) -> Result<ScenarioResult> {
    let plan = FaultPlan::new(cfg.seed);
    let server = start_fleet(artifacts, manifest, cfg, &plan)?;
    let handle = server.handle();
    let breaker = server
        .breaker_for(cfg.victim)
        .context("victim class has no breaker")?;
    let mut res = ScenarioResult { name: "sticky", ..Default::default() };

    // Phase A: healthy baseline.
    free_wave(&handle, mix, cfg.requests_per_wave, cfg, &mut res)?;

    // Phase B: kill the device, then drive pinned bursts through it
    // until the breaker trips.  Each burst request fails its dispatch
    // (feeding the breaker) and fails over to the sibling — the client
    // still sees Ok.
    let killed_at = Instant::now();
    plan.kill_now();
    while killed_at.elapsed() < cfg.breaker_patience
        && breaker.state() != BreakerState::Open
    {
        pinned_burst(&handle, cfg.victim, mix, 4, cfg, &mut res)?;
    }
    if breaker.state() == BreakerState::Open {
        res.time_to_quarantine = Some(killed_at.elapsed());
    }

    // Phase C: dead phase — free traffic routes around the open class.
    for _ in 0..cfg.waves.max(1) {
        free_wave(&handle, mix, cfg.requests_per_wave, cfg, &mut res)?;
    }

    // Phase D: revive and probe until the breaker closes.  After the
    // cooldown the first pinned submits are admitted as HalfOpen probes;
    // their successes close the breaker.
    plan.revive();
    let revived_at = Instant::now();
    while revived_at.elapsed() < cfg.breaker_patience
        && breaker.state() != BreakerState::Closed
    {
        pinned_burst(&handle, cfg.victim, mix, 2, cfg, &mut res)?;
        std::thread::sleep(Duration::from_millis(5));
    }
    if await_state(&breaker, BreakerState::Closed, cfg.breaker_patience) {
        res.time_to_recovery = Some(revived_at.elapsed());
    }

    // Phase E: post-recovery — free waves plus pinned-victim coverage;
    // the error rate here must be exactly zero and the victim must serve.
    let before = (res.offered, res.errors, res.expired, res.hung);
    for _ in 0..cfg.waves.max(1) {
        free_wave(&handle, mix, cfg.requests_per_wave, cfg, &mut res)?;
        let replies =
            pinned_burst(&handle, cfg.victim, mix, mix.len(), cfg, &mut res)?;
        res.victim_served_after_recovery += replies
            .iter()
            .filter(|r| {
                r.outcome == RequestOutcome::Ok && r.device == cfg.victim
            })
            .count();
    }
    res.post_recovery_offered = res.offered - before.0;
    res.post_recovery_errors =
        (res.errors - before.1) + (res.expired - before.2) + (res.hung - before.3);

    res.breaker_opens = breaker.opens();
    res.breaker_closes = breaker.closes();
    drop(handle);
    let _ = server.shutdown_now();
    Ok(res)
}

/// Run the full chaos experiment: three scenarios, fresh fleet each.
pub fn run(artifacts: &Path, cfg: ChaosConfig) -> Result<ChaosReport> {
    anyhow::ensure!(
        cfg.devices.len() >= 2,
        "chaos needs at least two device classes (victim + failover sibling)"
    );
    anyhow::ensure!(
        cfg.devices.contains(&cfg.victim),
        "victim {} is not in the fleet",
        cfg.victim
    );
    let manifest = Manifest::load(artifacts)?;
    let mix = hetero_mix(&manifest, &cfg.devices);
    anyhow::ensure!(!mix.is_empty(), "no mix triple is servable on every device");
    let t0 = Instant::now();
    let scenarios = vec![
        run_transient(artifacts, &manifest, &mix, &cfg)?,
        run_sticky(artifacts, &manifest, &mix, &cfg)?,
        run_latency(artifacts, &manifest, &mix, &cfg)?,
    ];
    Ok(ChaosReport { cfg, mix, scenarios, wall: t0.elapsed() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(name: &'static str) -> ScenarioResult {
        ScenarioResult { name, ..Default::default() }
    }

    fn report(scenarios: Vec<ScenarioResult>) -> ChaosReport {
        ChaosReport {
            cfg: ChaosConfig::default(),
            mix: vec![Triple::new(64, 64, 64)],
            scenarios,
            wall: Duration::from_secs(1),
        }
    }

    #[test]
    fn availability_counts_typed_refusals_not_errors() {
        let mut s = result("transient");
        s.offered = 100;
        s.ok = 96;
        s.shed = 2;
        s.quarantined = 1;
        s.errors = 1;
        assert!((s.availability() - 0.99).abs() < 1e-12);
        // Empty scenario is vacuously available (gated elsewhere by ok>0
        // through bit_identical).
        assert_eq!(result("x").availability(), 1.0);
    }

    #[test]
    fn gates_require_quarantine_recovery_and_served_payloads() {
        let mut sticky = result("sticky");
        sticky.offered = 10;
        sticky.ok = 10;
        sticky.time_to_quarantine = Some(Duration::from_millis(80));
        sticky.time_to_recovery = Some(Duration::from_millis(120));
        sticky.victim_served_after_recovery = 3;
        sticky.post_recovery_offered = 8;
        let r = report(vec![sticky]);
        assert!(r.quarantined());
        assert!(r.recovered());
        assert!(r.bit_identical());
        assert_eq!(r.post_recovery_error_rate(), 0.0);
        assert_eq!(r.hung(), 0);
        // A breaker that never closed (or a victim that never served
        // again) is not a recovery.
        let mut unrecovered = result("sticky");
        unrecovered.ok = 1;
        unrecovered.time_to_quarantine = Some(Duration::from_millis(80));
        let r = report(vec![unrecovered]);
        assert!(r.quarantined());
        assert!(!r.recovered());
        // Nothing served at all → bit-identity is not vacuously true.
        let r = report(vec![result("transient")]);
        assert!(!r.bit_identical());
    }

    #[test]
    fn json_carries_the_gate_keys() {
        let mut sticky = result("sticky");
        sticky.offered = 4;
        sticky.ok = 4;
        sticky.time_to_quarantine = Some(Duration::from_millis(50));
        sticky.time_to_recovery = Some(Duration::from_millis(70));
        sticky.victim_served_after_recovery = 1;
        let r = report(vec![sticky]);
        let json = r.to_json();
        assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "chaos");
        assert!(json.get("chaos_availability_min").unwrap().as_f64().unwrap() > 0.99);
        assert!(json.get("chaos_quarantined").unwrap().as_bool().unwrap());
        assert!(json.get("chaos_recovered").unwrap().as_bool().unwrap());
        assert!(json.get("chaos_bit_identical").unwrap().as_bool().unwrap());
        assert_eq!(json.get("chaos_hung").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(
            json.get("chaos_post_recovery_error_rate").unwrap().as_f64().unwrap(),
            0.0
        );
        // The render includes the sticky timing line.
        let text = r.render();
        assert!(text.contains("quarantine in 50ms"), "{text}");
        assert!(text.contains("recovery in 70ms"), "{text}");
    }
}
