//! Drift experiment: does the online adaptation loop recover from a
//! workload shift the frozen model never saw?
//!
//! Phase 0 (offline): measure *every* candidate configuration of both
//! workload mixes on the real PJRT backend — the resulting per-(triple,
//! config) performance map is the oracle that selections are scored
//! against.  The initial model is trained on the **base mix only**,
//! simulating a deployment whose traffic later shifts.
//!
//! Phase 1 (frozen baseline): serve the shifted mix under the frozen
//! initial model; every selection is scored against the oracle.
//!
//! Phase 2 (adaptive): serve the shifted mix in waves through a server
//! with the telemetry tap and shadow budget enabled, running one
//! deterministic [`adapt_step`] between waves.  The misprediction trigger
//! retrains the CART on the folded telemetry and hot-swaps the policy;
//! later waves are served by the adapted model.
//!
//! Scoring is performance-aware (the paper's DTPR idea, §5.2): a served
//! config's *quality* is its measured GFLOP/s over the triple's peak, and
//! the selection accuracy is the fraction of requests served within 10%
//! of peak — robust to near-tie configs that plain label-matching would
//! score as coin flips.
//!
//! The run is summarized in `BENCH_drift.json` (machine-readable, the
//! CI bench-regression gate input) with `recovered` = the adapted model
//! beat the frozen baseline on the shifted workload.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::config::{KernelConfig, Triple};
use crate::coordinator::{
    adapt_step, GemmRequest, GemmServer, ModelPolicy, ServerConfig, ServerHandle,
    TelemetryRing,
};
use crate::dataset::{DatasetKind, LabeledDataset};
use crate::dtree::{MinSamples, OnlineTrainer, TrainParams};
use crate::metrics::accuracy;
use crate::runtime::{Manifest, PjrtBackend};
use crate::tuner::Backend;
use crate::util::json::Json;

use super::e2e::request_stream_from;

/// A selection within this factor of peak counts as "good".
const GOOD_QUALITY: f64 = 0.9;

/// The "deployment era" mix: small shapes, all served exactly by direct
/// artifacts — the distribution the initial model is trained on.
pub fn base_mix() -> Vec<Triple> {
    vec![
        Triple::new(64, 64, 64),
        Triple::new(31, 31, 31),
        Triple::new(100, 100, 1),
        Triple::new(200, 50, 100),
        Triple::new(50, 200, 75),
    ]
}

/// The post-shift mix: large bucketed shapes the initial model never saw
/// — best served by configs its class table cannot even name.
pub fn shifted_mix() -> Vec<Triple> {
    vec![
        Triple::new(250, 250, 250),
        Triple::new(200, 200, 200),
        Triple::new(256, 256, 256),
        Triple::new(128, 250, 128),
        Triple::new(220, 180, 200),
        Triple::new(256, 128, 256),
    ]
}

/// Knobs of the drift run.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// Requests per wave (one adaptation step runs between waves).
    pub requests_per_wave: usize,
    /// Waves served on the shifted mix.
    pub waves: usize,
    /// Measurement repetitions for the ground-truth oracle.
    pub reps: usize,
    pub shards: usize,
    /// Telemetry sampling fraction during the adaptive phase.
    pub telemetry_fraction: f64,
    /// Shadow-execution budget (fraction of sampled requests).
    pub shadow_fraction: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            requests_per_wave: 32,
            waves: 3,
            reps: 1,
            shards: 1,
            telemetry_fraction: 1.0,
            shadow_fraction: 1.0,
        }
    }
}

/// Ground truth: measured GFLOP/s of every candidate config per triple.
struct Oracle {
    perf: HashMap<(Triple, KernelConfig), f64>,
    peak: HashMap<Triple, (KernelConfig, f64)>,
}

impl Oracle {
    fn measure_mix(&mut self, backend: &mut PjrtBackend, mix: &[Triple]) -> Result<()> {
        for &t in mix {
            for cfg in backend.candidates(t) {
                let Some(g) = backend.measure(&cfg, t) else { continue };
                self.perf.insert((t, cfg), g);
                if self.peak.get(&t).is_none_or(|(_, bg)| g > *bg) {
                    self.peak.insert(t, (cfg, g));
                }
            }
            anyhow::ensure!(self.peak.contains_key(&t), "no artifact serves {t}");
        }
        Ok(())
    }

    /// Quality of serving `t` with `cfg`: measured GFLOP/s over peak
    /// (0.0 for a config the oracle never saw run).
    fn quality(&self, t: Triple, cfg: KernelConfig) -> f64 {
        let peak = self.peak.get(&t).map(|(_, g)| *g).unwrap_or(f64::INFINITY);
        self.perf.get(&(t, cfg)).map(|g| g / peak).unwrap_or(0.0)
    }
}

/// Serving statistics of one phase or wave, scored against the oracle.
#[derive(Debug, Clone)]
pub struct PhaseStats {
    pub n: usize,
    /// Fraction of requests served within [`GOOD_QUALITY`] of peak — the
    /// drift run's selection accuracy.
    pub accuracy: f64,
    /// Mean quality (served GFLOP/s / peak GFLOP/s): the DTPR analogue.
    pub dtpr: f64,
    pub gflops: f64,
    pub rps: f64,
    /// Highest policy epoch observed in the responses.
    pub epoch_max: u64,
}

/// One adaptive wave: serving stats plus what the adaptation step did.
#[derive(Debug, Clone)]
pub struct WaveStats {
    pub serve: PhaseStats,
    pub mispredict_rate: f64,
    pub relabeled: usize,
    pub swapped_epoch: Option<u64>,
}

/// The full drift run.
pub struct DriftReport {
    pub cfg: DriftConfig,
    /// Training accuracy of the initial (base-mix-only) model, as a 0-1
    /// fraction like every other accuracy in this report.
    pub initial_train_accuracy: f64,
    pub frozen: PhaseStats,
    pub waves: Vec<WaveStats>,
    pub swaps: u64,
}

impl std::fmt::Debug for DriftReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DriftReport").finish_non_exhaustive()
    }
}

impl DriftReport {
    /// The post-swap phase: the last wave (served by the adapted model
    /// once any swap happened).
    pub fn adapted(&self) -> &PhaseStats {
        &self.waves.last().expect("at least one wave").serve
    }

    /// Did adaptation beat the frozen baseline on the shifted workload?
    /// Requires an actual hot-swap plus a strictly better selection
    /// accuracy (mean quality breaks ties).
    pub fn recovered(&self) -> bool {
        let (a, f) = (self.adapted(), &self.frozen);
        self.swaps > 0
            && (a.accuracy > f.accuracy
                || (a.accuracy == f.accuracy && a.dtpr > f.dtpr))
    }

    pub fn to_json(&self) -> Json {
        let mix = |ts: &[Triple]| {
            Json::Arr(
                ts.iter()
                    .map(|t| {
                        Json::Arr(vec![Json::num(t.m), Json::num(t.n), Json::num(t.k)])
                    })
                    .collect(),
            )
        };
        let phase = |p: &PhaseStats| {
            Json::obj(vec![
                ("n", Json::num(p.n as f64)),
                ("accuracy", Json::num(p.accuracy)),
                ("dtpr", Json::num(p.dtpr)),
                ("gflops", Json::num(p.gflops)),
                ("rps", Json::num(p.rps)),
                ("epoch_max", Json::num(p.epoch_max as f64)),
            ])
        };
        Json::obj(vec![
            ("bench", Json::str("drift")),
            ("requests_per_wave", Json::num(self.cfg.requests_per_wave as f64)),
            ("waves", Json::num(self.cfg.waves as f64)),
            ("shards", Json::num(self.cfg.shards as f64)),
            ("base_mix", mix(&base_mix())),
            ("shifted_mix", mix(&shifted_mix())),
            ("initial_train_accuracy", Json::num(self.initial_train_accuracy)),
            ("frozen", phase(&self.frozen)),
            (
                "adapted_waves",
                Json::Arr(
                    self.waves
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("serve", phase(&w.serve)),
                                ("mispredict_rate", Json::num(w.mispredict_rate)),
                                ("relabeled", Json::num(w.relabeled as f64)),
                                (
                                    "swapped_epoch",
                                    match w.swapped_epoch {
                                        Some(e) => Json::num(e as f64),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("adapted", phase(self.adapted())),
            ("swaps", Json::num(self.swaps as f64)),
            ("recovered", Json::Bool(self.recovered())),
        ])
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "=== Drift experiment: live-telemetry adaptation vs frozen model ===\n\
             initial model: trained on base mix only, train accuracy {:.0}%\n\
             shifted mix, frozen policy:  accuracy {:5.1}%  quality {:.3}  {:.2} GFLOP/s\n",
            100.0 * self.initial_train_accuracy,
            100.0 * self.frozen.accuracy,
            self.frozen.dtpr,
            self.frozen.gflops,
        );
        for (i, w) in self.waves.iter().enumerate() {
            s.push_str(&format!(
                "wave {i}: accuracy {:5.1}%  quality {:.3}  {:.2} GFLOP/s  \
                 epoch<={}  mispredict {:.0}%  relabeled {}{}\n",
                100.0 * w.serve.accuracy,
                w.serve.dtpr,
                w.serve.gflops,
                w.serve.epoch_max,
                100.0 * w.mispredict_rate,
                w.relabeled,
                match w.swapped_epoch {
                    Some(e) => format!("  -> HOT-SWAP (epoch {e})"),
                    None => String::new(),
                },
            ));
        }
        s.push_str(&format!(
            "adapted (last wave) vs frozen: accuracy {:5.1}% vs {:5.1}%, \
             quality {:.3} vs {:.3} — {}\n",
            100.0 * self.adapted().accuracy,
            100.0 * self.frozen.accuracy,
            self.adapted().dtpr,
            self.frozen.dtpr,
            if self.recovered() { "RECOVERED" } else { "NOT RECOVERED" },
        ));
        s
    }

    /// Write the machine-readable summary (the CI gate input).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// Submit a warm request for every mix triple on every shard so compile
/// time never pollutes a measured wave.
fn warm(handle: &ServerHandle, mix: &[Triple], shards: usize) {
    let mut pending = Vec::new();
    for &t in mix {
        for _ in 0..shards.max(1) {
            let (m, n, k) = (t.m as usize, t.n as usize, t.k as usize);
            pending.push(handle.submit(GemmRequest {
                m,
                n,
                k,
                a: vec![0.5; m * k],
                b: vec![0.5; k * n],
                c: vec![0.0; m * n],
                alpha: 1.0,
                beta: 0.0,
            }));
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
}

/// Shards push telemetry *after* replying (and after any shadow GEMM),
/// so the tap lags the last response.  Wait for it so every adapt step
/// folds the complete wave — `expected` is exact when the sampling
/// fraction is 1.0; otherwise fall back to waiting for the tap to go
/// quiet.  One ring here; the hetero experiment passes one per device.
fn await_tap(telemetry: &TelemetryRing, expected: Option<u64>) {
    crate::coordinator::await_taps(&[telemetry], expected);
}

/// Expected pushed() total after `n` more sampled requests, exact only
/// at full sampling.
fn expected_after(telemetry: &TelemetryRing, fraction: f64, n: usize) -> Option<u64> {
    (fraction >= 1.0).then(|| telemetry.pushed() + n as u64)
}

/// Serve one wave and score every response against the oracle.
fn serve_wave(
    handle: &ServerHandle,
    manifest: &Manifest,
    oracle: &Oracle,
    requests: Vec<GemmRequest>,
) -> Result<PhaseStats> {
    let n = requests.len();
    let total_flops: f64 = requests.iter().map(|r| r.triple().flops()).sum();
    let t0 = Instant::now();
    let pending: Vec<_> = requests
        .into_iter()
        .map(|r| {
            let t = r.triple();
            (t, handle.submit(r))
        })
        .collect();
    let mut good = 0usize;
    let mut quality_sum = 0.0;
    let mut epoch_max = 0u64;
    for (t, rx) in pending {
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("server dropped"))?;
        resp.out.context("request failed")?;
        epoch_max = epoch_max.max(resp.epoch);
        let served = manifest
            .find(&resp.artifact)
            .map(|a| a.config)
            .context("response names unknown artifact")?;
        let q = oracle.quality(t, served);
        quality_sum += q;
        if q >= GOOD_QUALITY {
            good += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(PhaseStats {
        n,
        accuracy: if n == 0 { 0.0 } else { good as f64 / n as f64 },
        dtpr: if n == 0 { 0.0 } else { quality_sum / n as f64 },
        gflops: total_flops / wall / 1e9,
        rps: n as f64 / wall,
        epoch_max,
    })
}

/// Run the full drift experiment.  Returns the report; the caller decides
/// where to persist it.
pub fn run(artifacts: &Path, cfg: DriftConfig) -> Result<DriftReport> {
    // ------------------------------------------------ phase 0: offline
    let mut backend = PjrtBackend::open(artifacts)?;
    backend.reps = cfg.reps.max(1);
    let mut oracle = Oracle { perf: HashMap::new(), peak: HashMap::new() };
    oracle.measure_mix(&mut backend, &base_mix())?;
    // The shifted mix is measured into the oracle for scoring only — the
    // initial model and its dataset never see it.
    oracle.measure_mix(&mut backend, &shifted_mix())?;
    drop(backend);

    let mut initial = LabeledDataset {
        kind: DatasetKind::Po2,
        device: "host-cpu".into(),
        entries: Vec::new(),
        classes: Default::default(),
    };
    for t in base_mix() {
        let (best, _) = oracle.peak[&t];
        let class = initial.classes.intern(best);
        initial.entries.push((t, class));
    }
    let params =
        TrainParams { max_depth: None, min_samples_leaf: MinSamples::Count(1) };
    let mut trainer = OnlineTrainer::new(initial, params);
    trainer.min_observations = (cfg.requests_per_wave / 2).clamp(4, 64);
    // As a 0-1 fraction, like every other accuracy in the drift report
    // (metrics::accuracy reports percent).
    let initial_train_accuracy =
        accuracy(trainer.tree(), &trainer.dataset().entries) / 100.0;

    let manifest = Manifest::load(artifacts)?;
    let shifted = shifted_mix();

    // ------------------------------------------- phase 1: frozen model
    let frozen = {
        let server = GemmServer::start(
            artifacts,
            Box::new(ModelPolicy::new(trainer.tree(), &trainer.dataset().classes)),
            ServerConfig::with_shards(cfg.shards),
        )?;
        let handle = server.handle();
        warm(&handle, &shifted, cfg.shards);
        let n = cfg.requests_per_wave * cfg.waves.max(1);
        let stats = serve_wave(
            &handle,
            &manifest,
            &oracle,
            request_stream_from(&shifted, n, 0xD21F7),
        )?;
        drop(handle);
        let _ = server.shutdown();
        stats
    };

    // ---------------------------------------- phase 2: adaptation loop
    let server = GemmServer::start(
        artifacts,
        Box::new(ModelPolicy::new(trainer.tree(), &trainer.dataset().classes)),
        ServerConfig::adaptive(cfg.shards, cfg.telemetry_fraction, cfg.shadow_fraction),
    )?;
    let handle = server.handle();
    let policy_handle = server.policy_handle();
    let telemetry = server.telemetry();
    let warm_expected =
        expected_after(&telemetry, cfg.telemetry_fraction, shifted.len() * cfg.shards.max(1));
    warm(&handle, &shifted, cfg.shards);
    // Warm-up traffic is not training signal: wait for its tail pushes,
    // then drop everything it sampled.
    await_tap(&telemetry, warm_expected);
    let _ = telemetry.drain();

    let mut waves = Vec::with_capacity(cfg.waves);
    let mut swaps = 0u64;
    for wave in 0..cfg.waves.max(1) {
        let requests =
            request_stream_from(&shifted, cfg.requests_per_wave, 0xADA7 + wave as u64);
        let expected =
            expected_after(&telemetry, cfg.telemetry_fraction, cfg.requests_per_wave);
        let serve = serve_wave(&handle, &manifest, &oracle, requests)?;
        // Deterministic adaptation step between waves (the background
        // AdaptationLoop drives the same function on a timer in a
        // long-running deployment).  Wait for the wave's trailing
        // telemetry pushes first so the fold sees the complete wave.
        await_tap(&telemetry, expected);
        let outcome = adapt_step(&mut trainer, &telemetry, &policy_handle);
        if outcome.swapped_epoch.is_some() {
            swaps += 1;
        }
        waves.push(WaveStats {
            serve,
            mispredict_rate: outcome.mispredict_rate,
            relabeled: outcome.relabeled,
            swapped_epoch: outcome.swapped_epoch,
        });
    }
    drop(handle);
    let _ = server.shutdown();

    Ok(DriftReport { cfg, initial_train_accuracy, frozen, waves, swaps })
}
