//! Shared experiment pipeline: for a (device, dataset) pair, run the full
//! off-line phase — generate triples, tune exhaustively (simulated
//! device), split 80/20, train the paper's 40-model (H, L) sweep, and
//! evaluate accuracy / DTPR / DTTR for each model.  Results are cached
//! per pair so every table/figure can share one computation.

use std::collections::HashMap;

use crate::config::KernelKind;
use crate::dataset::{train_test_split, ClassTable, Dataset, DatasetKind, LabeledDataset};
use crate::device::{DeviceId, DeviceProfile};
use crate::dtree::{train, DecisionTree, TrainParams};
use crate::metrics::{evaluate, ModelScores, TripleRecord};
use crate::tuner::{Backend, SimBackend, TunedDefault, Tuner, TuningDb};

/// Split fraction and seed used across all experiments (paper: 80/20).
pub const TEST_FRAC: f64 = 0.2;
pub const SPLIT_SEED: u64 = 0x5EED_2018;

/// Structural statistics of a trained tree (Tables 5/6 columns).
#[derive(Debug, Clone)]
pub struct TreeStats {
    pub n_leaves: usize,
    pub height: u32,
    pub unique_configs_xgemm: usize,
    pub unique_configs_direct: usize,
    pub leaves_xgemm: usize,
    pub leaves_direct: usize,
    /// Host SIMD microkernel variants the tree learned to pick.
    pub unique_configs_host: usize,
    pub leaves_host: usize,
}

pub fn tree_stats(tree: &DecisionTree, classes: &ClassTable) -> TreeStats {
    let leaf_classes = tree.leaf_classes();
    let mut uniq_x = std::collections::HashSet::new();
    let mut uniq_d = std::collections::HashSet::new();
    let mut uniq_h = std::collections::HashSet::new();
    let mut leaves_x = 0;
    let mut leaves_d = 0;
    let mut leaves_h = 0;
    for c in &leaf_classes {
        match classes.config(*c).kind() {
            KernelKind::Xgemm => {
                uniq_x.insert(*c);
                leaves_x += 1;
            }
            KernelKind::XgemmDirect => {
                uniq_d.insert(*c);
                leaves_d += 1;
            }
            KernelKind::HostSimd => {
                uniq_h.insert(*c);
                leaves_h += 1;
            }
        }
    }
    TreeStats {
        n_leaves: leaf_classes.len(),
        height: tree.depth(),
        unique_configs_xgemm: uniq_x.len(),
        unique_configs_direct: uniq_d.len(),
        leaves_xgemm: leaves_x,
        leaves_direct: leaves_d,
        unique_configs_host: uniq_h.len(),
        leaves_host: leaves_h,
    }
}

/// One trained + evaluated model of the sweep.
pub struct ModelRow {
    pub params: TrainParams,
    pub tree: DecisionTree,
    pub scores: ModelScores,
    pub stats: TreeStats,
    pub records: Vec<TripleRecord>,
}

impl std::fmt::Debug for ModelRow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelRow").finish_non_exhaustive()
    }
}

/// The full off-line result for one (device, dataset) pair.
pub struct SweepResult {
    pub device: DeviceId,
    pub kind: DatasetKind,
    /// The per-device CLBlast-style default (tuned at 1024^3 / 256^3).
    pub default: TunedDefault,
    pub labeled: LabeledDataset,
    pub db: TuningDb,
    pub train_idx: Vec<usize>,
    pub test_idx: Vec<usize>,
    pub models: Vec<ModelRow>,
}

impl std::fmt::Debug for SweepResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SweepResult").finish_non_exhaustive()
    }
}

impl SweepResult {
    /// The paper's "Best Decision Tree": highest DTPR.
    pub fn best_model(&self) -> &ModelRow {
        self.models
            .iter()
            .max_by(|a, b| a.scores.dtpr.partial_cmp(&b.scores.dtpr).unwrap())
            .expect("sweep has models")
    }

    pub fn model(&self, name: &str) -> Option<&ModelRow> {
        self.models.iter().find(|m| m.scores.model == name)
    }
}

/// Experiment context: caches sweeps, controls sweep size.
pub struct Context {
    cache: HashMap<(DeviceId, DatasetKind), SweepResult>,
    /// When set, only this many models are trained (test speed-up).
    pub model_limit: Option<usize>,
    pub verbose: bool,
}

impl std::fmt::Debug for Context {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Context").finish_non_exhaustive()
    }
}

impl Default for Context {
    fn default() -> Self {
        Self::new()
    }
}

impl Context {
    pub fn new() -> Context {
        Context { cache: HashMap::new(), model_limit: None, verbose: false }
    }

    /// The paper's (device, dataset) grid: go2 was not generated on the
    /// Mali ("due to the limited amount of hours available", §5.1).
    pub fn paper_grid() -> Vec<(DeviceId, DatasetKind)> {
        vec![
            (DeviceId::NvidiaP100, DatasetKind::AntonNet),
            (DeviceId::NvidiaP100, DatasetKind::Po2),
            (DeviceId::NvidiaP100, DatasetKind::Go2),
            (DeviceId::MaliT860, DatasetKind::AntonNet),
            (DeviceId::MaliT860, DatasetKind::Po2),
        ]
    }

    pub fn sweep(&mut self, device: DeviceId, kind: DatasetKind) -> &SweepResult {
        if !self.cache.contains_key(&(device, kind)) {
            let r = self.run_sweep(device, kind);
            self.cache.insert((device, kind), r);
        }
        &self.cache[&(device, kind)]
    }

    fn run_sweep(&self, device: DeviceId, kind: DatasetKind) -> SweepResult {
        let t0 = std::time::Instant::now();
        let mut backend = SimBackend::new(DeviceProfile::get(device));
        let dataset = Dataset::generate(kind);
        let mut db = TuningDb::new(backend.device_name());
        let labeled = Tuner::default().label_dataset(&mut backend, &dataset, &mut db);
        if self.verbose {
            eprintln!(
                "[sweep] tuned {} {} triples on {} ({} classes) in {:.1}s",
                labeled.len(),
                kind,
                device,
                labeled.classes.len(),
                t0.elapsed().as_secs_f64()
            );
        }
        let default = TunedDefault::tune(&mut backend);
        let (train_idx, test_idx) =
            train_test_split(labeled.len(), TEST_FRAC, SPLIT_SEED);
        let train_set = labeled.subset(&train_idx);
        let test_set = labeled.subset(&test_idx);

        let mut params = TrainParams::paper_sweep();
        if let Some(limit) = self.model_limit {
            params.truncate(limit);
        }
        let models = params
            .into_iter()
            .map(|p| {
                let tree = train(&train_set, labeled.classes.len(), p);
                let (scores, records) =
                    evaluate(&tree, &test_set, &labeled.classes, &mut backend, &db, &default);
                let stats = tree_stats(&tree, &labeled.classes);
                ModelRow { params: p, tree, scores, stats, records }
            })
            .collect();
        if self.verbose {
            eprintln!(
                "[sweep] {}/{} done in {:.1}s",
                device,
                kind,
                t0.elapsed().as_secs_f64()
            );
        }
        SweepResult {
            device,
            kind,
            default,
            labeled,
            db,
            train_idx,
            test_idx,
            models,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_po2_p100_pipeline() {
        let mut ctx = Context::new();
        ctx.model_limit = Some(4);
        let r = ctx.sweep(DeviceId::NvidiaP100, DatasetKind::Po2);
        assert_eq!(r.labeled.len(), 216);
        assert_eq!(r.models.len(), 4);
        assert_eq!(r.train_idx.len() + r.test_idx.len(), 216);
        for m in &r.models {
            assert!(m.scores.dtpr > 0.0 && m.scores.dtpr <= 1.0 + 1e-9);
            assert!(m.stats.n_leaves >= 1);
            assert_eq!(
                m.stats.leaves_xgemm + m.stats.leaves_direct,
                m.stats.n_leaves
            );
        }
        // Cache hit: same pointer-equal result object.
        let len_before = r.models.len();
        let r2 = ctx.sweep(DeviceId::NvidiaP100, DatasetKind::Po2);
        assert_eq!(r2.models.len(), len_before);
    }

    #[test]
    fn paper_grid_is_five_pairs() {
        assert_eq!(Context::paper_grid().len(), 5);
    }
}
