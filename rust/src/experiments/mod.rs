//! Experiment drivers: one per paper table/figure (DESIGN.md §4), shared
//! by the `adaptd exp ...` CLI and the `cargo bench` targets.

pub mod ablation;
pub mod chaos;
pub mod context;
pub mod drift;
pub mod e2e;
pub mod figures;
pub mod hetero;
pub mod microbench;
pub mod overload;
pub mod tables;

pub use context::{tree_stats, Context, ModelRow, SweepResult, TreeStats};
pub use tables::Rendered;

use anyhow::Result;
use std::path::Path;

use crate::device::DeviceId;

/// Run every table/figure experiment and save outputs under `out`.
/// Returns the rendered artifacts in order.
pub fn run_all(ctx: &mut Context, out: &Path) -> Result<Vec<Rendered>> {
    let renders = vec![
        tables::table1(),
        tables::table2(),
        tables::table3(ctx),
        tables::table4(ctx),
        tables::table5(ctx),
        tables::table6(ctx),
        figures::fig3(ctx, DeviceId::NvidiaP100),
        figures::fig3(ctx, DeviceId::MaliT860),
        figures::fig45(ctx, DeviceId::NvidiaP100),
        figures::fig45(ctx, DeviceId::MaliT860),
        figures::fig67(ctx, DeviceId::NvidiaP100),
        figures::fig67(ctx, DeviceId::MaliT860),
        microbench::selector_overhead(ctx),
        ablation::tuner_budget(DeviceId::NvidiaP100),
        ablation::classifiers(ctx, DeviceId::NvidiaP100, crate::dataset::DatasetKind::Po2),
    ];
    for r in &renders {
        r.save(out)?;
    }
    Ok(renders)
}
