//! Heterogeneous-fleet experiment: the paper's central claim, served.
//!
//! The same adaptive library must select *different* kernels on different
//! architectures (3x on Pascal, 2.5x on Mali — §1).  This experiment
//! serves one mixed AntonNet workload through a fleet of {host-cpu,
//! nvidia-p100, mali-t860} device classes: the host CPU runs the real
//! PJRT runtime, the two GPUs run analytical engines charging the
//! device-model wall-time (`engine::SimEngine`).  Each class starts from
//! its own default policy and adapts independently — per-device telemetry
//! rings, per-device trainers, per-device hot-swaps — while the
//! device-aware router spreads traffic by predicted service time and
//! queue depth.
//!
//! Scoring is per device, against that device's own oracle (measured on
//! the real backend for the host, the analytical model for the GPUs):
//! a request served on device D with config c scores c's GFLOP/s over
//! D's per-triple peak, and the *selection accuracy* is the fraction of
//! requests served within 10% of peak (the drift experiment's
//! performance-aware metric).  Each wave combines the router's free
//! burst (whose split is reported as traffic share) with a *pinned
//! coverage sweep* — one request per (device, mix triple), bypassing
//! the router — so every device's accuracy is measurable even when the
//! router concentrates free traffic on the predicted-fastest class.
//! The machine-readable summary lands in `BENCH_hetero.json`; CI gates
//! per-device accuracy against the committed baseline.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::config::{KernelConfig, Triple};
use crate::coordinator::{
    adapt_step, await_taps, DeviceClass, GemmServer, PolicyHandle, SelectPolicy,
    ServerConfig, TelemetryRing,
};
use crate::dataset::{antonnet, DatasetKind, LabeledDataset};
use crate::device::{sim, DeviceId, DeviceProfile};
use crate::dtree::{MinSamples, OnlineTrainer, TrainParams};
use crate::runtime::{Manifest, PjrtBackend};
use crate::tuner::Backend;
use crate::util::json::Json;

use super::e2e::request_stream_from;

/// A selection within this factor of its device's peak counts as "good".
const GOOD_QUALITY: f64 = 0.9;

/// Knobs of the hetero run.
#[derive(Debug, Clone)]
pub struct HeteroConfig {
    /// Requests per wave (per-device adaptation steps run between waves).
    pub requests_per_wave: usize,
    pub waves: usize,
    /// Dispatcher shards per device class.
    pub shards_per_class: usize,
    /// Measurement repetitions for the host-CPU oracle.
    pub reps: usize,
    pub telemetry_fraction: f64,
    pub shadow_fraction: f64,
    /// Device classes of the fleet.
    pub devices: Vec<DeviceId>,
}

impl Default for HeteroConfig {
    fn default() -> Self {
        HeteroConfig {
            requests_per_wave: 48,
            waves: 2,
            shards_per_class: 1,
            reps: 1,
            telemetry_fraction: 1.0,
            shadow_fraction: 1.0,
            devices: DeviceId::all().to_vec(),
        }
    }
}

/// Distinct roster configurations legal on a device.
pub fn legal_roster(manifest: &Manifest, device: DeviceId) -> Vec<KernelConfig> {
    let profile = DeviceProfile::get(device);
    let mut v: Vec<KernelConfig> = manifest
        .artifacts
        .iter()
        .map(|a| a.config)
        .filter(|c| profile.is_legal(c))
        .collect();
    v.sort_by_key(|c| c.name());
    v.dedup();
    v
}

/// The initial per-device policy: CLBlast-style defaults restricted to
/// the device-legal roster subset.  A device whose legal subset lacks one
/// kernel kind degenerates to a single-config policy.
pub fn device_policy(
    manifest: &Manifest,
    device: DeviceId,
) -> Result<Box<dyn SelectPolicy>> {
    use crate::coordinator::DefaultPolicy;
    let roster = legal_roster(manifest, device);
    anyhow::ensure!(!roster.is_empty(), "no roster config is legal on {device}");
    Ok(match DefaultPolicy::from_roster(&roster) {
        Some(p) => Box::new(p),
        None => {
            let only = roster[0];
            Box::new(DefaultPolicy { direct: only, xgemm: only, threshold_geo: 384.0 })
        }
    })
}

/// The mixed AntonNet workload: real-network GEMM shapes every fleet
/// device can serve (shape-eligible in the roster *and* at least one
/// device-legal artifact per device), spread deterministically across the
/// population and capped.  Falls back to the e2e workload triples when
/// the roster is too small for any AntonNet shape.
pub fn hetero_mix(manifest: &Manifest, devices: &[DeviceId]) -> Vec<Triple> {
    const CAP: usize = 12;
    let servable_everywhere = |t: Triple| {
        devices.iter().all(|&d| {
            let profile = DeviceProfile::get(d);
            manifest
                .artifacts
                .iter()
                .any(|a| a.accepts(t) && profile.is_legal(&a.config))
        })
    };
    let pool: Vec<Triple> = antonnet::triples()
        .into_iter()
        .filter(|&t| servable_everywhere(t))
        .collect();
    let mut mix: Vec<Triple> = if pool.is_empty() {
        super::e2e::workload_triples()
            .into_iter()
            .filter(|&t| servable_everywhere(t))
            .collect()
    } else {
        let stride = (pool.len() / CAP).max(1);
        pool.into_iter().step_by(stride).take(CAP).collect()
    };
    mix.dedup();
    mix
}

/// Ground truth for one device: GFLOP/s of every candidate config per
/// mix triple, from the device's *own* measurement source.
struct DeviceOracle {
    perf: HashMap<(Triple, KernelConfig), f64>,
    peak: HashMap<Triple, f64>,
}

impl DeviceOracle {
    fn insert(&mut self, t: Triple, cfg: KernelConfig, g: f64) {
        self.perf.insert((t, cfg), g);
        let peak = self.peak.entry(t).or_insert(g);
        if g > *peak {
            *peak = g;
        }
    }

    /// Served quality: GFLOP/s over the triple's peak on this device
    /// (0.0 for a config this oracle never saw run).
    fn quality(&self, t: Triple, cfg: KernelConfig) -> f64 {
        match (self.perf.get(&(t, cfg)), self.peak.get(&t)) {
            (Some(g), Some(peak)) if *peak > 0.0 => g / peak,
            _ => 0.0,
        }
    }
}

/// Build a device's oracle over the mix: real measurements for the host
/// CPU, the analytical model for the simulated GPUs — each device is
/// scored against what *it* would actually observe.
fn build_oracle(
    artifacts: &Path,
    manifest: &Manifest,
    device: DeviceId,
    mix: &[Triple],
    reps: usize,
) -> Result<DeviceOracle> {
    let mut oracle = DeviceOracle { perf: HashMap::new(), peak: HashMap::new() };
    match device {
        DeviceId::HostCpu => {
            let mut backend = PjrtBackend::open(artifacts)?;
            backend.reps = reps.max(1);
            for &t in mix {
                for cfg in backend.candidates(t) {
                    if let Some(g) = backend.measure(&cfg, t) {
                        oracle.insert(t, cfg, g);
                    }
                }
            }
        }
        sim_dev => {
            let profile = DeviceProfile::get(sim_dev);
            let roster = legal_roster(manifest, sim_dev);
            for &t in mix {
                for &cfg in &roster {
                    let has_artifact = manifest
                        .artifacts
                        .iter()
                        .any(|a| a.config == cfg && a.accepts(t));
                    if !has_artifact {
                        continue;
                    }
                    if let Some(g) = sim::measure_gflops(&profile, &cfg, t) {
                        oracle.insert(t, cfg, g);
                    }
                }
            }
        }
    }
    for &t in mix {
        anyhow::ensure!(
            oracle.peak.contains_key(&t),
            "no measurable config for {t} on {device}"
        );
    }
    Ok(oracle)
}

/// Cumulative per-device scorecard of the run.
#[derive(Debug, Clone)]
pub struct DeviceScore {
    pub device: DeviceId,
    /// Scored requests served on this device across all waves — the
    /// router's free traffic plus the pinned coverage sweeps (one per
    /// mix triple per wave), so every device's selection accuracy is
    /// measurable even when the router rarely picks it.
    pub served: usize,
    /// Free-burst requests the router chose this device for (the
    /// traffic-share numerator; pinned coverage excluded).
    pub routed: usize,
    good: usize,
    quality_sum: f64,
    /// Requests served on this device in the final (post-adaptation) wave.
    pub served_final: usize,
    good_final: usize,
    quality_final: f64,
    pub epoch_max: u64,
    /// Policy hot-swaps this device's adaptation performed.
    pub swaps: u64,
}

impl DeviceScore {
    fn new(device: DeviceId) -> DeviceScore {
        DeviceScore {
            device,
            served: 0,
            routed: 0,
            good: 0,
            quality_sum: 0.0,
            served_final: 0,
            good_final: 0,
            quality_final: 0.0,
            epoch_max: 0,
            swaps: 0,
        }
    }

    /// Selection accuracy over the whole run (None if never served).
    pub fn accuracy(&self) -> Option<f64> {
        (self.served > 0).then(|| self.good as f64 / self.served as f64)
    }

    /// Mean served quality over the whole run (DTPR analogue).
    pub fn dtpr(&self) -> Option<f64> {
        (self.served > 0).then(|| self.quality_sum / self.served as f64)
    }

    /// Selection accuracy of the final wave only.
    pub fn accuracy_final(&self) -> Option<f64> {
        (self.served_final > 0)
            .then(|| self.good_final as f64 / self.served_final as f64)
    }

    /// Mean served quality of the final wave only.
    pub fn dtpr_final(&self) -> Option<f64> {
        (self.served_final > 0)
            .then(|| self.quality_final / self.served_final as f64)
    }
}

/// The full hetero run.
pub struct HeteroReport {
    pub cfg: HeteroConfig,
    pub mix: Vec<Triple>,
    pub devices: Vec<DeviceScore>,
    /// Total scored requests (all waves, all devices, free + pinned).
    pub requests: usize,
    /// Router-routed (free-burst) requests — the traffic-share
    /// denominator.
    pub free_requests: usize,
    pub wall: Duration,
    total_flops: f64,
    overall_good: usize,
    overall_quality: f64,
}

impl std::fmt::Debug for HeteroReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HeteroReport").finish_non_exhaustive()
    }
}

impl HeteroReport {
    pub fn overall_accuracy(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.overall_good as f64 / self.requests as f64
        }
    }

    pub fn overall_dtpr(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.overall_quality / self.requests as f64
        }
    }

    pub fn rps(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64()
    }

    pub fn gflops(&self) -> f64 {
        self.total_flops / self.wall.as_secs_f64() / 1e9
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("bench", Json::str("hetero")),
            ("requests_per_wave", Json::num(self.cfg.requests_per_wave as f64)),
            ("waves", Json::num(self.cfg.waves as f64)),
            ("shards_per_class", Json::num(self.cfg.shards_per_class as f64)),
            (
                "mix",
                Json::Arr(
                    self.mix
                        .iter()
                        .map(|t| {
                            Json::Arr(vec![Json::num(t.m), Json::num(t.n), Json::num(t.k)])
                        })
                        .collect(),
                ),
            ),
            (
                "devices",
                Json::Arr(
                    self.devices
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("device", Json::str(d.device.name())),
                                ("served", Json::num(d.served as f64)),
                                ("routed", Json::num(d.routed as f64)),
                                (
                                    "share",
                                    Json::num(if self.free_requests == 0 {
                                        0.0
                                    } else {
                                        d.routed as f64 / self.free_requests as f64
                                    }),
                                ),
                                ("accuracy", opt(d.accuracy())),
                                ("dtpr", opt(d.dtpr())),
                                ("accuracy_final", opt(d.accuracy_final())),
                                ("dtpr_final", opt(d.dtpr_final())),
                                ("swaps", Json::num(d.swaps as f64)),
                                ("epoch_max", Json::num(d.epoch_max as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("overall_accuracy", Json::num(self.overall_accuracy())),
            ("overall_dtpr", Json::num(self.overall_dtpr())),
            ("rps", Json::num(self.rps())),
            ("gflops", Json::num(self.gflops())),
        ])
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "=== Hetero fleet: {} devices, {} waves x {} requests, mix of {} \
             AntonNet shapes ===\n",
            self.devices.len(),
            self.cfg.waves,
            self.cfg.requests_per_wave,
            self.mix.len(),
        );
        for d in &self.devices {
            let pct = |v: Option<f64>| match v {
                Some(v) => format!("{:5.1}%", 100.0 * v),
                None => "    —".to_string(),
            };
            s.push_str(&format!(
                "{:<12} served {:4} (routed share {:4.0}%)  accuracy {}  quality {}  \
                 final {}  swaps {} (epoch {})\n",
                d.device.name(),
                d.served,
                if self.free_requests == 0 {
                    0.0
                } else {
                    100.0 * d.routed as f64 / self.free_requests as f64
                },
                pct(d.accuracy()),
                match d.dtpr() {
                    Some(v) => format!("{v:.3}"),
                    None => "—".to_string(),
                },
                pct(d.accuracy_final()),
                d.swaps,
                d.epoch_max,
            ));
        }
        s.push_str(&format!(
            "overall: accuracy {:5.1}%  quality {:.3}  {:.1} req/s  {:.2} GFLOP/s\n",
            100.0 * self.overall_accuracy(),
            self.overall_dtpr(),
            self.rps(),
            self.gflops(),
        ));
        s
    }

    /// Write the machine-readable summary (the CI gate input).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// Run the full hetero experiment.
pub fn run(artifacts: &Path, cfg: HeteroConfig) -> Result<HeteroReport> {
    anyhow::ensure!(!cfg.devices.is_empty(), "hetero fleet needs devices");
    let manifest = Manifest::load(artifacts)?;
    let mix = hetero_mix(&manifest, &cfg.devices);
    anyhow::ensure!(!mix.is_empty(), "no mix triple is servable on every device");

    // ---------------------------------------- phase 0: per-device oracles
    let mut oracles: HashMap<DeviceId, DeviceOracle> = HashMap::new();
    for &d in &cfg.devices {
        oracles.insert(d, build_oracle(artifacts, &manifest, d, &mix, cfg.reps)?);
    }

    // Per-device initial policies + trainers seeded with the initial
    // policy's own labels (so the first mispredictions are honest).
    let mut classes = Vec::new();
    let mut trainers: HashMap<DeviceId, OnlineTrainer> = HashMap::new();
    for &d in &cfg.devices {
        let policy = device_policy(&manifest, d)?;
        let mut seed = LabeledDataset {
            kind: DatasetKind::AntonNet,
            device: d.name().into(),
            entries: Vec::new(),
            classes: Default::default(),
        };
        for &t in &mix {
            let label = seed.classes.intern(policy.select(t));
            seed.entries.push((t, label));
        }
        let params =
            TrainParams { max_depth: None, min_samples_leaf: MinSamples::Count(1) };
        let mut trainer = OnlineTrainer::new(seed, params);
        trainer.min_observations = (cfg.requests_per_wave / 8).clamp(4, 32);
        trainers.insert(d, trainer);
        classes.push(DeviceClass::new(d, cfg.shards_per_class, policy));
    }

    // ------------------------------------------------ serve the fleet
    let server = GemmServer::start_fleet(
        artifacts,
        classes,
        ServerConfig::adaptive(
            cfg.shards_per_class,
            cfg.telemetry_fraction,
            cfg.shadow_fraction,
        ),
    )?;
    let handle = server.handle();
    let rings: Vec<std::sync::Arc<TelemetryRing>> = cfg
        .devices
        .iter()
        .map(|&d| server.telemetry_for(d).expect("fleet device"))
        .collect();
    let handles: Vec<std::sync::Arc<PolicyHandle>> = cfg
        .devices
        .iter()
        .map(|&d| server.policy_handle_for(d).expect("fleet device"))
        .collect();

    let mut scores: Vec<DeviceScore> =
        cfg.devices.iter().map(|&d| DeviceScore::new(d)).collect();
    let mut requests_total = 0usize;
    let mut free_requests = 0usize;
    let mut total_flops = 0.0f64;
    let mut overall_good = 0usize;
    let mut overall_quality = 0.0f64;
    let mut wall = Duration::ZERO;
    let mut sampled_total = 0u64;

    for wave in 0..cfg.waves.max(1) {
        let final_wave = wave + 1 == cfg.waves.max(1);
        let requests =
            request_stream_from(&mix, cfg.requests_per_wave, 0x4E7E20 + wave as u64);
        total_flops += requests.iter().map(|r| r.triple().flops()).sum::<f64>();
        let t0 = Instant::now();
        // Free burst: the router sees real queue depth, so the fleet
        // spreads by predicted-service-time x backlog.  Pinned coverage
        // sweep on top: one request per (device, mix triple), bypassing
        // the router — every device's selection accuracy is measured on
        // identical traffic (and every device's adaptation loop gets
        // telemetry) even when the router would rarely pick it.
        let mut pending: Vec<(Triple, Option<DeviceId>, _)> = requests
            .into_iter()
            .map(|r| {
                let t = r.triple();
                (t, None, handle.submit(r))
            })
            .collect();
        for &d in &cfg.devices {
            for (i, &t) in mix.iter().enumerate() {
                let seed = 0xC07E4 + wave as u64 * 1000 + i as u64;
                let req = request_stream_from(&[t], 1, seed).pop().expect("one request");
                total_flops += t.flops();
                let rx = handle.submit_to(d, req).context("fleet device missing")?;
                pending.push((t, Some(d), rx));
            }
        }
        for (t, pinned, rx) in pending {
            let resp = rx.recv().map_err(|_| anyhow::anyhow!("server dropped"))?;
            resp.out.with_context(|| format!("request {t} failed"))?;
            if let Some(d) = pinned {
                anyhow::ensure!(
                    resp.device == d,
                    "pinned request for {d} served by {}",
                    resp.device
                );
            }
            let served = manifest
                .find(&resp.artifact)
                .map(|a| a.config)
                .context("response names unknown artifact")?;
            let q = oracles[&resp.device].quality(t, served);
            let score = scores
                .iter_mut()
                .find(|s| s.device == resp.device)
                .context("response from unknown device")?;
            score.served += 1;
            if pinned.is_none() {
                score.routed += 1;
                free_requests += 1;
            }
            score.quality_sum += q;
            score.epoch_max = score.epoch_max.max(resp.epoch);
            let good = q >= GOOD_QUALITY;
            if good {
                score.good += 1;
            }
            if final_wave {
                score.served_final += 1;
                score.quality_final += q;
                if good {
                    score.good_final += 1;
                }
            }
            requests_total += 1;
            overall_quality += q;
            if good {
                overall_good += 1;
            }
        }
        wall += t0.elapsed();
        // Per-device adaptation between waves, each on its own ring and
        // policy slot — the fleet-wide analogue of the drift experiment's
        // deterministic adapt step.
        sampled_total +=
            (cfg.requests_per_wave + cfg.devices.len() * mix.len()) as u64;
        let expected = (cfg.telemetry_fraction >= 1.0).then_some(sampled_total);
        let ring_refs: Vec<&TelemetryRing> = rings.iter().map(|r| r.as_ref()).collect();
        await_taps(&ring_refs, expected);
        for (i, &d) in cfg.devices.iter().enumerate() {
            let trainer = trainers.get_mut(&d).expect("trainer per device");
            let outcome = adapt_step(trainer, &rings[i], &handles[i]);
            if outcome.swapped_epoch.is_some() {
                scores[i].swaps += 1;
            }
        }
    }
    drop(handle);
    let _ = server.shutdown();

    Ok(HeteroReport {
        cfg,
        mix,
        devices: scores,
        requests: requests_total,
        free_requests,
        wall,
        total_flops,
        overall_good,
        overall_quality,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        crate::testing::sample_manifest()
    }

    #[test]
    fn legal_roster_filters_per_device() {
        let m = manifest();
        let p100 = legal_roster(&m, DeviceId::NvidiaP100);
        let mali = legal_roster(&m, DeviceId::MaliT860);
        // i2's 1024-thread work-group is illegal on Mali only.
        assert_eq!(p100.len(), 3);
        assert_eq!(mali.len(), 2);
    }

    #[test]
    fn mix_only_contains_universally_servable_triples() {
        let m = manifest();
        let devices = DeviceId::all();
        let mix = hetero_mix(&m, &devices);
        assert!(!mix.is_empty());
        for &t in &mix {
            // Mali's only legal bucket is 128^3 here, so every mix triple
            // must fit it (or the exact 64^3 direct artifact).
            assert!(
                t.m <= 128 && t.n <= 128 && t.k <= 128,
                "{t} not servable on mali"
            );
        }
    }

    #[test]
    fn device_policy_selects_only_device_legal_configs() {
        let m = manifest();
        for d in DeviceId::all() {
            let profile = DeviceProfile::get(d);
            let policy = device_policy(&m, d).unwrap();
            for t in [Triple::new(8, 8, 8), Triple::new(2000, 2000, 2000)] {
                assert!(
                    profile.is_legal(&policy.select(t)),
                    "{d}: illegal initial selection for {t}"
                );
            }
        }
    }

    #[test]
    fn oracle_quality_is_peak_relative() {
        let mut o = DeviceOracle { perf: HashMap::new(), peak: HashMap::new() };
        let t = Triple::new(64, 64, 64);
        let m = manifest();
        let a = m.artifacts[0].config;
        let b = m.artifacts[1].config;
        o.insert(t, a, 10.0);
        o.insert(t, b, 8.0);
        assert_eq!(o.quality(t, a), 1.0);
        assert!((o.quality(t, b) - 0.8).abs() < 1e-12);
        // Unknown config / triple scores zero.
        assert_eq!(o.quality(t, m.artifacts[2].config), 0.0);
        assert_eq!(o.quality(Triple::new(1, 1, 1), a), 0.0);
    }
}
