//! Regenerate the paper's tables.
//!
//! * Table 1 — tuning-space statistics (kernels, parameter counts, sizes)
//! * Table 2 — hardware description of the two simulated devices
//! * Table 3 — dataset statistics + best decision tree, Nvidia P100
//! * Table 4 — dataset statistics + best decision tree, ARM Mali-T860
//! * Table 5 — full (H, L) tree statistics, go2 @ P100
//! * Table 6 — full (H, L) tree statistics, AntonNet @ Mali

use crate::config::{direct_space, xgemm_space};
use crate::dataset::DatasetKind;
use crate::device::{DeviceId, DeviceProfile};
use crate::util::csv::CsvWriter;
use crate::util::table;

use super::context::Context;

/// Rendered experiment output: ASCII (for the terminal) + CSV (for plots).
pub struct Rendered {
    pub id: &'static str,
    pub ascii: String,
    pub csv: CsvWriter,
}

impl std::fmt::Debug for Rendered {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Rendered").finish_non_exhaustive()
    }
}

impl Rendered {
    pub fn save(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.txt", self.id)), &self.ascii)?;
        self.csv.save(&dir.join(format!("{}.csv", self.id)))?;
        Ok(())
    }
}

pub fn table1() -> Rendered {
    let rows: Vec<Vec<String>> = [xgemm_space(), direct_space()]
        .iter()
        .map(|s| {
            vec![
                s.kernel.to_string(),
                s.num_params().to_string(),
                s.raw_size().to_string(),
            ]
        })
        .collect();
    let ascii = table::render(
        "Table 1: Tuning size statistics as used for this case-study",
        &["Kernel", "Tunable Parameters", "Search Space Size"],
        &rows,
    );
    let mut csv = CsvWriter::new(&["kernel", "params", "space_size"]);
    for r in &rows {
        csv.row(r);
    }
    Rendered { id: "table1", ascii, csv }
}

pub fn table2() -> Rendered {
    let devs = [DeviceProfile::nvidia_p100(), DeviceProfile::mali_t860()];
    let mut rows = Vec::new();
    let field = |f: &dyn Fn(&DeviceProfile) -> String, name: &str| {
        let mut row = vec![name.to_string()];
        for d in &devs {
            row.push(f(d));
        }
        row
    };
    rows.push(field(&|d| d.market_segment.into(), "Market segment"));
    rows.push(field(&|d| d.microarchitecture.into(), "Micro-architecture"));
    rows.push(field(&|d| d.cores_desc.into(), "Number of available cores"));
    rows.push(field(&|d| format!("{} MHz", d.boost_mhz), "Boost frequency"));
    rows.push(field(
        &|d| {
            if d.peak_gflops >= 1000.0 {
                format!("{:.1} TFLOPS", d.peak_gflops / 1000.0)
            } else {
                format!("{:.1} GFLOPS", d.peak_gflops)
            }
        },
        "Processing power",
    ));
    rows.push(field(&|d| format!("{} GB", d.memory_gb), "Memory available"));
    rows.push(field(&|d| d.memory_type.into(), "Memory type"));
    let ascii = table::render(
        "Table 2: Nvidia P100 and ARM Mali-T860 hardware description",
        &["Device name", "Nvidia P100", "ARM Mali-T860"],
        &rows,
    );
    let mut csv = CsvWriter::new(&["field", "p100", "mali"]);
    for r in &rows {
        csv.row(r);
    }
    Rendered { id: "table2", ascii, csv }
}

fn dataset_stats_table(
    ctx: &mut Context,
    device: DeviceId,
    kinds: &[DatasetKind],
    id: &'static str,
    title: &str,
) -> Rendered {
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&[
        "dataset", "size", "uniq_xgemm", "uniq_direct", "best_tree",
        "accuracy_pct", "dtpr", "dttr",
    ]);
    for &kind in kinds {
        let sweep = ctx.sweep(device, kind);
        let (ux, ud) = sweep.labeled.classes.unique_per_kernel();
        let best = sweep.best_model();
        let row = vec![
            kind.name().to_string(),
            sweep.labeled.len().to_string(),
            ux.to_string(),
            ud.to_string(),
            best.scores.model.clone(),
            table::f(best.scores.accuracy, 1),
            table::f(best.scores.dtpr, 3),
            table::f(best.scores.dttr, 3),
        ];
        csv.row(&row);
        rows.push(row);
    }
    let ascii = table::render(
        title,
        &[
            "Dataset", "Size", "Uniq Xgemm", "Uniq XgemmDirect",
            "Best Tree", "Accuracy %", "DTPR", "DTTR",
        ],
        &rows,
    );
    Rendered { id, ascii, csv }
}

pub fn table3(ctx: &mut Context) -> Rendered {
    dataset_stats_table(
        ctx,
        DeviceId::NvidiaP100,
        &[DatasetKind::AntonNet, DatasetKind::Po2, DatasetKind::Go2],
        "table3",
        "Table 3: Dataset statistics - Nvidia P100 (best tree = highest DTPR)",
    )
}

pub fn table4(ctx: &mut Context) -> Rendered {
    dataset_stats_table(
        ctx,
        DeviceId::MaliT860,
        &[DatasetKind::AntonNet, DatasetKind::Po2],
        "table4",
        "Table 4: Dataset statistics - ARM Mali-T860 (best tree = highest DTPR)",
    )
}

fn model_sweep_table(
    ctx: &mut Context,
    device: DeviceId,
    kind: DatasetKind,
    id: &'static str,
    title: &str,
) -> Rendered {
    let sweep = ctx.sweep(device, kind);
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&[
        "model", "accuracy_pct", "dtpr", "dttr", "leaves", "height",
        "min_samples", "uniq_xgemm", "uniq_direct", "leaves_xgemm",
        "leaves_direct",
    ]);
    for m in &sweep.models {
        let row = vec![
            m.scores.model.clone(),
            table::f(m.scores.accuracy, 1),
            table::f(m.scores.dtpr, 3),
            table::f(m.scores.dttr, 3),
            m.stats.n_leaves.to_string(),
            m.stats.height.to_string(),
            m.params.min_samples_leaf.label(),
            m.stats.unique_configs_xgemm.to_string(),
            m.stats.unique_configs_direct.to_string(),
            m.stats.leaves_xgemm.to_string(),
            m.stats.leaves_direct.to_string(),
        ];
        csv.row(&row);
        rows.push(row);
    }
    let ascii = table::render(
        title,
        &[
            "Model", "Acc %", "DTPR", "DTTR", "Leaves", "Height", "MinLeaf",
            "UniqX", "UniqD", "LeafX", "LeafD",
        ],
        &rows,
    );
    Rendered { id, ascii, csv }
}

pub fn table5(ctx: &mut Context) -> Rendered {
    model_sweep_table(
        ctx,
        DeviceId::NvidiaP100,
        DatasetKind::Go2,
        "table5",
        "Table 5: Decision trees trained from go2 by varying H and L - Nvidia P100",
    )
}

pub fn table6(ctx: &mut Context) -> Rendered {
    model_sweep_table(
        ctx,
        DeviceId::MaliT860,
        DatasetKind::AntonNet,
        "table6",
        "Table 6: Decision trees trained from AntonNet by varying H and L - ARM Mali-T860",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_exactly() {
        let r = table1();
        assert!(r.ascii.contains("8748"));
        assert!(r.ascii.contains("3888"));
        assert!(r.ascii.contains("14"));
        assert!(r.ascii.contains("9"));
        assert_eq!(r.csv.len(), 2);
    }

    #[test]
    fn table2_contains_profiles() {
        let r = table2();
        assert!(r.ascii.contains("Pascal"));
        assert!(r.ascii.contains("Midgard 4th gen"));
        assert!(r.ascii.contains("9.7 TFLOPS"));
        assert!(r.ascii.contains("23.8 GFLOPS"));
    }

    #[test]
    fn table4_shape() {
        let mut ctx = Context::new();
        ctx.model_limit = Some(3);
        let r = table4(&mut ctx);
        assert!(r.ascii.contains("antonnet"));
        assert!(r.ascii.contains("po2"));
        assert_eq!(r.csv.len(), 2);
    }
}
