//! §5.4 microbenchmark: the overhead of the generated if-then-else
//! selector relative to GEMM execution time.  The paper reports <2% on
//! small matrices (deepest leaf) and <1% on average for the hMax-L1 model
//! trained from go2 (1200 leaves, depth 19).

use std::time::Instant;

use crate::codegen::FlatTree;
use crate::dataset::DatasetKind;
use crate::device::{sim, DeviceId, DeviceProfile};
use crate::util::csv::CsvWriter;
use crate::util::table;

use super::context::Context;
use super::tables::Rendered;

/// Measure the mean selector traversal time over the test triples.
pub fn selector_ns(flat: &FlatTree, triples: &[(u32, u32, u32)]) -> f64 {
    // Warm.
    let mut acc = 0u64;
    for &(m, n, k) in triples {
        acc = acc.wrapping_add(flat.predict(m, n, k) as u64);
    }
    let reps = 2000usize;
    let t0 = Instant::now();
    for _ in 0..reps {
        for &(m, n, k) in triples {
            acc = acc.wrapping_add(flat.predict(m, n, k) as u64);
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    std::hint::black_box(acc);
    elapsed / (reps * triples.len()) as f64 * 1e9
}

/// The §5.4 experiment: selector overhead vs simulated kernel time at
/// several matrix sizes, for the best go2 model on the P100.
pub fn selector_overhead(ctx: &mut Context) -> Rendered {
    let sweep = ctx.sweep(DeviceId::NvidiaP100, DatasetKind::Go2);
    let best = sweep.best_model();
    let flat = FlatTree::from_tree(&best.tree);
    let dev = DeviceProfile::nvidia_p100();

    // Traversal cost measured over the test set (mean) and the deepest
    // leaf (the paper's worst case).
    let test_triples: Vec<(u32, u32, u32)> = sweep
        .test_idx
        .iter()
        .map(|&i| {
            let t = sweep.labeled.entries[i].0;
            (t.m, t.n, t.k)
        })
        .collect();
    let avg_ns = selector_ns(&flat, &test_triples);
    let depth = best.tree.depth();
    let n_leaves = best.tree.n_leaves();

    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&[
        "mnk", "selector_ns", "kernel_us_sim", "overhead_pct",
    ]);
    for &size in &[64u32, 128, 256, 512, 1024, 2048] {
        let t = crate::config::Triple::new(size, size, size);
        let cfg = sweep.db.best(t).map(|(c, _)| *c).unwrap_or_else(|| {
            crate::tuner::clblast_default(t)
        });
        let gflops = sim::measure_gflops(&dev, &cfg, t).unwrap_or(1.0);
        let kernel_us = t.flops() / (gflops * 1e9) * 1e6;
        let overhead = avg_ns / 1e3 / kernel_us * 100.0;
        let row = vec![
            format!("{size}^3"),
            table::f(avg_ns, 1),
            table::f(kernel_us, 2),
            table::f(overhead, 4),
        ];
        csv.row(&row);
        rows.push(row);
    }
    let mut ascii = format!(
        "Section 5.4 microbenchmark: selector overhead\n\
         model {} | {} leaves | depth {} | mean traversal {:.1} ns\n\n",
        best.scores.model, n_leaves, depth, avg_ns
    );
    ascii.push_str(&table::render(
        "Selector overhead vs simulated P100 kernel time",
        &["M=N=K", "selector ns", "kernel µs (sim)", "overhead %"],
        &rows,
    ));
    Rendered { id: "micro_selector", ascii, csv }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dtree::{DecisionTree, Node};

    #[test]
    fn selector_ns_is_nanoseconds() {
        let tree = DecisionTree {
            nodes: vec![
                Node::Split { feature: 0, threshold: 100.0, left: 1, right: 2 },
                Node::Leaf { class: 0, n_samples: 1 },
                Node::Leaf { class: 1, n_samples: 1 },
            ],
            name: "t".into(),
        };
        let flat = FlatTree::from_tree(&tree);
        let ns = selector_ns(&flat, &[(64, 64, 64), (128, 128, 128)]);
        assert!(ns > 0.0 && ns < 10_000.0, "implausible traversal {ns} ns");
    }
}
