//! Overload experiment: the serving path under offered load beyond
//! capacity — bounded admission, load shedding and the pressure pick,
//! measured on the real host-CPU PJRT runtime.
//!
//! The run calibrates the server's service rate on the mixed e2e
//! workload, then sweeps an *open-loop* (paced, non-blocking) arrival
//! process at 1x/2x/4x of the calibrated capacity through two arms:
//!
//! * **policy** — the model/default selection alone
//!   (`pressure_threshold = MAX`);
//! * **pressure** — deadline-aware selection enabled: envelopes that
//!   queue past the threshold resolve through the modeled-cheapest
//!   servable artifact within the slowdown bound
//!   (`ServerConfig::pressure_{threshold,slowdown}`).
//!
//! Per load point the report records p50/p99 latency, the shed rate
//! (typed `Admission::Shed` outcomes from `try_submit`), the peak queue
//! depth (asserted `<= queue_capacity` — the bounded-memory guarantee),
//! pressure-pick counts, and DTPR (mean served quality vs the measured
//! host oracle).  `BENCH_overload.json` carries the machine-readable
//! summary; CI gates `shed_rate_1x == 0`, `depth_bounded == true` and
//! the committed p99 floor via `adaptd bench-compare`.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context as _, Result};

use crate::config::{KernelConfig, Triple};
use crate::coordinator::{
    Admission, GemmServer, RequestOutcome, SelectPolicy, ServerConfig,
};
use crate::runtime::{Manifest, PjrtBackend};
use crate::tuner::Backend;
use crate::util::json::Json;
use crate::util::stats::{mean, percentile};

use super::e2e::{request_stream_from, workload_triples};

/// Offered load is paced against capacity / SAFETY so "1x" sits at a
/// utilization the server genuinely sustains (~0.67): calibration is a
/// point estimate on a possibly-noisy machine, and the 1x shed-rate gate
/// must not flake because the runner slowed down after calibration.
const CALIBRATION_SAFETY: f64 = 1.5;

/// Knobs of the overload run.
#[derive(Debug, Clone)]
pub struct OverloadConfig {
    /// Offered requests per load point.
    pub requests: usize,
    /// Offered-load factors relative to calibrated capacity.
    pub load_factors: Vec<f64>,
    pub shards: usize,
    /// Per-class queue bound under test.
    pub queue_capacity: usize,
    /// Measurement repetitions for the host oracle.
    pub reps: usize,
    /// Pressure threshold in ms; 0 = auto (4x calibrated mean service).
    pub pressure_threshold_ms: f64,
    /// Modeled-slowdown bound of the pressure pick.
    pub pressure_slowdown: f64,
    /// Max same-shape requests fused per dispatch (1 disables fusion).
    /// Under overload the windows fill, so same-shape runs fuse and the
    /// per-request dispatch cost drops — occupancy is reported per load
    /// point.
    pub max_fuse: usize,
    /// Run the loopback network arm: the same paced sweep through the
    /// framed front door (`net::NetServer`), with latencies measured at
    /// the client (framing + decode included) and shed accounting
    /// reconciled between wire status frames and `ServeStats`.
    pub net: bool,
    /// Per-connection in-flight cap for the network arm; 0 auto-sizes
    /// to the sweep length so socket-level `Busy` refusals never mask
    /// the fleet-admission behaviour under test.
    pub net_inflight: usize,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        OverloadConfig {
            requests: 120,
            load_factors: vec![1.0, 2.0, 4.0],
            shards: 1,
            queue_capacity: 24,
            reps: 1,
            pressure_threshold_ms: 0.0,
            pressure_slowdown: 1.25,
            max_fuse: 16,
            net: true,
            net_inflight: 0,
        }
    }
}

/// One (arm, load factor) measurement.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    /// Offered load relative to calibrated capacity.
    pub load: f64,
    pub offered: usize,
    pub admitted: usize,
    /// Typed `Admission::Shed` outcomes from the open-loop submitter.
    pub shed: usize,
    pub errors: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Peak outstanding requests during the measured phase.
    pub peak_depth: usize,
    /// Responses whose selection the pressure pick overrode.
    pub pressure_picks: u64,
    /// Mean served quality vs the measured host oracle (DTPR analogue).
    pub dtpr: f64,
    /// Request-weighted mean fused-batch occupancy of served requests.
    pub occupancy_mean: f64,
}

impl LoadPoint {
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("load", Json::num(self.load)),
            ("offered", Json::num(self.offered as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("shed_rate", Json::num(self.shed_rate())),
            ("errors", Json::num(self.errors as f64)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("peak_depth", Json::num(self.peak_depth as f64)),
            ("pressure_picks", Json::num(self.pressure_picks as f64)),
            ("dtpr", Json::num(self.dtpr)),
            ("occupancy_mean", Json::num(self.occupancy_mean)),
        ])
    }
}

/// One (load factor) measurement of the loopback network arm.  Latency
/// is measured at the client — encode, socket, decode and the fleet all
/// included — and there is no DTPR column: the wire response carries
/// the payload, not the serving artifact's name.
#[derive(Debug, Clone)]
pub struct NetPoint {
    /// Offered load relative to calibrated capacity.
    pub load: f64,
    pub offered: usize,
    /// Requests answered with a response payload.
    pub served: usize,
    /// Typed `Shed`/`Quarantined` status frames observed at the client.
    pub shed: usize,
    /// Any other non-payload answer (expired, drained, error, …).
    pub errors: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Peak outstanding requests in the fleet during the paced phase.
    pub peak_depth: usize,
}

impl NetPoint {
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("load", Json::num(self.load)),
            ("offered", Json::num(self.offered as f64)),
            ("served", Json::num(self.served as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("shed_rate", Json::num(self.shed_rate())),
            ("errors", Json::num(self.errors as f64)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("peak_depth", Json::num(self.peak_depth as f64)),
        ])
    }
}

/// The full overload run: both arms over the load sweep.
pub struct OverloadReport {
    pub cfg: OverloadConfig,
    pub mix: Vec<Triple>,
    /// Calibrated mean service seconds of one request.
    pub service_secs: f64,
    /// Offered request rate at load factor 1.0.
    pub offered_1x_rps: f64,
    /// Effective pressure threshold of the pressure arm.
    pub pressure_threshold: Duration,
    /// Policy-only arm, one point per load factor.
    pub policy: Vec<LoadPoint>,
    /// Pressure-pick arm, one point per load factor.
    pub pressure: Vec<LoadPoint>,
    /// Loopback network arm, one point per load factor (empty when
    /// `cfg.net` is false).
    pub net: Vec<NetPoint>,
    pub wall: Duration,
}

impl std::fmt::Debug for OverloadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OverloadReport").finish_non_exhaustive()
    }
}

impl OverloadReport {
    fn point_at(points: &[LoadPoint], load: f64) -> Option<&LoadPoint> {
        points.iter().find(|p| (p.load - load).abs() < 1e-9)
    }

    fn max_load(&self) -> f64 {
        self.cfg
            .load_factors
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Shed rate at 1x offered load — worst across both arms; the CI
    /// gate pins this to zero (a server shedding below capacity is
    /// misconfigured admission, not overload).
    pub fn shed_rate_1x(&self) -> f64 {
        [&self.policy, &self.pressure]
            .iter()
            .filter_map(|pts| Self::point_at(pts, 1.0))
            .map(|p| p.shed_rate())
            .fold(0.0, f64::max)
    }

    /// Every point stayed within the queue bound (asserted per point at
    /// run time too — this is the machine-readable echo).
    pub fn depth_bounded(&self) -> bool {
        self.policy
            .iter()
            .chain(self.pressure.iter())
            .all(|p| p.peak_depth <= self.cfg.queue_capacity)
    }

    /// p99 at 1x load, policy arm — the committed-floor gate metric.
    pub fn p99_1x_ms(&self) -> f64 {
        Self::point_at(&self.policy, 1.0).map_or(0.0, |p| p.p99_ms)
    }

    pub fn p99_overload_policy_ms(&self) -> f64 {
        Self::point_at(&self.policy, self.max_load()).map_or(0.0, |p| p.p99_ms)
    }

    pub fn p99_overload_pressure_ms(&self) -> f64 {
        Self::point_at(&self.pressure, self.max_load()).map_or(0.0, |p| p.p99_ms)
    }

    /// Did the pressure arm's p99 at the deepest overload beat (or tie)
    /// the policy-only arm's?
    pub fn pressure_p99_improved(&self) -> bool {
        self.p99_overload_pressure_ms() <= self.p99_overload_policy_ms()
    }

    pub fn dtpr_1x_policy(&self) -> f64 {
        Self::point_at(&self.policy, 1.0).map_or(0.0, |p| p.dtpr)
    }

    pub fn dtpr_1x_pressure(&self) -> f64 {
        Self::point_at(&self.pressure, 1.0).map_or(0.0, |p| p.dtpr)
    }

    pub fn peak_depth_max(&self) -> usize {
        self.policy
            .iter()
            .chain(self.pressure.iter())
            .map(|p| p.peak_depth)
            .max()
            .unwrap_or(0)
    }

    fn net_point_at(&self, load: f64) -> Option<&NetPoint> {
        self.net.iter().find(|p| (p.load - load).abs() < 1e-9)
    }

    /// Shed rate at 1x over the wire — the network analogue of
    /// [`OverloadReport::shed_rate_1x`]; gated to zero by CI.
    pub fn net_shed_rate_1x(&self) -> f64 {
        self.net_point_at(1.0).map_or(0.0, |p| p.shed_rate())
    }

    /// Client-observed p99 at 1x load (framing + decode + serve) — the
    /// committed network floor gate metric.
    pub fn net_p99_1x_ms(&self) -> f64 {
        self.net_point_at(1.0).map_or(0.0, |p| p.p99_ms)
    }

    /// The fleet stayed within its queue bound at every network-arm
    /// load point (the wire cannot bypass bounded admission).
    pub fn net_depth_bounded(&self) -> bool {
        self.net.iter().all(|p| p.peak_depth <= self.cfg.queue_capacity)
    }

    pub fn to_json(&self) -> Json {
        let arm = |pressure: bool, points: &[LoadPoint]| {
            Json::obj(vec![
                ("pressure", Json::Bool(pressure)),
                ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
            ])
        };
        let mut json = Json::obj(vec![
            ("bench", Json::str("overload")),
            ("requests_per_point", Json::num(self.cfg.requests as f64)),
            ("shards", Json::num(self.cfg.shards as f64)),
            ("queue_capacity", Json::num(self.cfg.queue_capacity as f64)),
            ("max_fuse", Json::num(self.cfg.max_fuse as f64)),
            ("service_ms", Json::num(self.service_secs * 1e3)),
            ("offered_1x_rps", Json::num(self.offered_1x_rps)),
            (
                "pressure_threshold_ms",
                Json::num(self.pressure_threshold.as_secs_f64() * 1e3),
            ),
            ("pressure_slowdown", Json::num(self.cfg.pressure_slowdown)),
            (
                "mix",
                Json::Arr(self.mix.iter().map(|t| t.to_json()).collect()),
            ),
            (
                "arms",
                Json::Arr(vec![arm(false, &self.policy), arm(true, &self.pressure)]),
            ),
            ("shed_rate_1x", Json::num(self.shed_rate_1x())),
            ("depth_bounded", Json::Bool(self.depth_bounded())),
            ("p99_1x_ms", Json::num(self.p99_1x_ms())),
            ("p99_overload_policy_ms", Json::num(self.p99_overload_policy_ms())),
            (
                "p99_overload_pressure_ms",
                Json::num(self.p99_overload_pressure_ms()),
            ),
            ("pressure_p99_improved", Json::Bool(self.pressure_p99_improved())),
            ("dtpr_1x_policy", Json::num(self.dtpr_1x_policy())),
            ("dtpr_1x_pressure", Json::num(self.dtpr_1x_pressure())),
            ("peak_depth_max", Json::num(self.peak_depth_max() as f64)),
        ]);
        if self.net.is_empty() {
            return json;
        }
        // The network-arm keys are present exactly when the arm ran, so
        // a `--no-net` run cannot green-light the network gate with
        // vacuous zeros (bench-compare skips absent keys).
        let Json::Obj(ref mut fields) = json else { unreachable!("obj built above") };
        fields.insert(
            "net_arm".to_string(),
            Json::obj(vec![(
                "points",
                Json::Arr(self.net.iter().map(|p| p.to_json()).collect()),
            )]),
        );
        fields.insert("net_shed_rate_1x".to_string(), Json::num(self.net_shed_rate_1x()));
        fields.insert("net_p99_1x_ms".to_string(), Json::num(self.net_p99_1x_ms()));
        fields.insert("net_depth_bounded".to_string(), Json::Bool(self.net_depth_bounded()));
        json
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "=== Overload sweep: {} requests/point, {} shard(s), queue bound {}, \
             calibrated service {:.2}ms (1x = {:.0} req/s) ===\n",
            self.cfg.requests,
            self.cfg.shards,
            self.cfg.queue_capacity,
            self.service_secs * 1e3,
            self.offered_1x_rps,
        );
        for (name, points) in [("policy", &self.policy), ("pressure", &self.pressure)] {
            s.push_str(&format!("--- {name} arm ---\n"));
            for p in points.iter() {
                s.push_str(&format!(
                    "{:>4.1}x: admitted {:4}/{:<4} shed {:5.1}%  p50 {:7.2}ms  \
                     p99 {:7.2}ms  peak depth {:3}  picks {:3}  dtpr {:.3}  \
                     occ {:.2}\n",
                    p.load,
                    p.admitted,
                    p.offered,
                    100.0 * p.shed_rate(),
                    p.p50_ms,
                    p.p99_ms,
                    p.peak_depth,
                    p.pressure_picks,
                    p.dtpr,
                    p.occupancy_mean,
                ));
            }
        }
        if !self.net.is_empty() {
            s.push_str("--- network arm (loopback, client-observed) ---\n");
            for p in self.net.iter() {
                s.push_str(&format!(
                    "{:>4.1}x: served {:4}/{:<4} shed {:5.1}%  p50 {:7.2}ms  \
                     p99 {:7.2}ms  peak depth {:3}  errors {:3}\n",
                    p.load,
                    p.served,
                    p.offered,
                    100.0 * p.shed_rate(),
                    p.p50_ms,
                    p.p99_ms,
                    p.peak_depth,
                    p.errors,
                ));
            }
            s.push_str(&format!(
                "net: shed rate at 1x {:.1}%  p99 at 1x {:.2}ms  depth {}\n",
                100.0 * self.net_shed_rate_1x(),
                self.net_p99_1x_ms(),
                if self.net_depth_bounded() { "bounded" } else { "EXCEEDED" },
            ));
        }
        s.push_str(&format!(
            "p99 at {:.0}x: policy {:.2}ms vs pressure {:.2}ms ({})  |  \
             dtpr at 1x: policy {:.3} vs pressure {:.3}\n\
             shed rate at 1x: {:.1}%  peak depth max {} (bound {}: {})\n",
            self.max_load(),
            self.p99_overload_policy_ms(),
            self.p99_overload_pressure_ms(),
            if self.pressure_p99_improved() { "improved" } else { "NOT improved" },
            self.dtpr_1x_policy(),
            self.dtpr_1x_pressure(),
            100.0 * self.shed_rate_1x(),
            self.peak_depth_max(),
            self.cfg.queue_capacity,
            if self.depth_bounded() { "bounded" } else { "EXCEEDED" },
        ));
        s
    }

    /// Write the machine-readable summary (the CI gate input).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// Measured ground truth on the host: GFLOP/s per (triple, config) and
/// the per-triple peak — the DTPR denominator.
struct HostOracle {
    perf: HashMap<(Triple, KernelConfig), f64>,
    peak: HashMap<Triple, f64>,
}

impl HostOracle {
    fn build(artifacts: &Path, mix: &[Triple], reps: usize) -> Result<HostOracle> {
        let mut backend = PjrtBackend::open(artifacts)?;
        backend.reps = reps.max(1);
        let mut oracle = HostOracle { perf: HashMap::new(), peak: HashMap::new() };
        for &t in mix {
            for cfg in backend.candidates(t) {
                if let Some(g) = backend.measure(&cfg, t) {
                    oracle.perf.insert((t, cfg), g);
                    let peak = oracle.peak.entry(t).or_insert(g);
                    if g > *peak {
                        *peak = g;
                    }
                }
            }
            anyhow::ensure!(
                oracle.peak.contains_key(&t),
                "no measurable config for {t} on the host"
            );
        }
        Ok(oracle)
    }

    fn quality(&self, t: Triple, cfg: KernelConfig) -> f64 {
        match (self.perf.get(&(t, cfg)), self.peak.get(&t)) {
            (Some(g), Some(peak)) if *peak > 0.0 => g / peak,
            _ => 0.0,
        }
    }
}

/// The host-class default policy, built from the already-loaded manifest
/// (no second backend/artifact open per load point).
fn host_policy(manifest: &Manifest) -> Result<Box<dyn SelectPolicy>> {
    super::hetero::device_policy(manifest, crate::device::DeviceId::HostCpu)
}

/// Closed-loop calibration: serve the mix sequentially (depth 1, no
/// queueing) and return the mean service seconds of one request.  The
/// first pass warms compile caches and is discarded.
fn calibrate(
    artifacts: &Path,
    manifest: &Manifest,
    mix: &[Triple],
    cfg: &ServerConfig,
) -> Result<f64> {
    let server = GemmServer::start(artifacts, host_policy(manifest)?, *cfg)?;
    let handle = server.handle();
    let mut secs = Vec::new();
    for rep in 0..2u64 {
        for (i, &t) in mix.iter().enumerate() {
            let req = request_stream_from(&[t], 1, 0xCA11B + rep * 1000 + i as u64)
                .pop()
                .expect("one request");
            let resp = handle.call(req)?;
            resp.out.with_context(|| format!("calibration request {t} failed"))?;
            if rep > 0 {
                secs.push(resp.service.as_secs_f64());
            }
        }
    }
    drop(handle);
    let _ = server.shutdown();
    Ok(mean(&secs))
}

/// One open-loop load point: fresh server, warm pass, paced non-blocking
/// arrivals at `offered_rps`, full response collection, bounded-depth
/// assertion.
#[allow(clippy::too_many_arguments)]
fn run_point(
    artifacts: &Path,
    manifest: &Manifest,
    oracle: &HostOracle,
    mix: &[Triple],
    scfg: ServerConfig,
    load: f64,
    offered_rps: f64,
    n_requests: usize,
    seed: u64,
) -> Result<LoadPoint> {
    let server = GemmServer::start(artifacts, host_policy(manifest)?, scfg)?;
    let handle = server.handle();
    // Warm pass: an unpaced blocking burst through the same submit path,
    // sized to touch every mix triple on every shard — compiles both the
    // policy's picks and (under the queue pressure the burst itself
    // builds) the pressure arm's alternates.  Discarded from stats.
    let warm = request_stream_from(mix, 2 * mix.len() * scfg.shards, seed ^ 0xAAAA);
    let pending: Vec<_> = warm.into_iter().map(|r| handle.submit(r)).collect();
    for rx in pending {
        let _ = rx.recv();
    }
    // The warm burst legitimately fills the queue; measure the watermark
    // from the paced phase only.
    handle.reset_peak_depth();

    let requests = request_stream_from(mix, n_requests, seed);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests.len());
    let mut shed = 0usize;
    for (i, req) in requests.into_iter().enumerate() {
        let target = t0 + Duration::from_secs_f64(i as f64 / offered_rps);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let t = req.triple();
        match handle.try_submit(req) {
            Admission::Enqueued(rx) => pending.push((t, rx)),
            // No faults are injected in this experiment, so quarantine
            // refusals should never fire; counting them as sheds keeps
            // the sweep total honest if they ever do.
            Admission::Shed { .. } | Admission::Quarantined { .. } => shed += 1,
            Admission::Rejected { reason } => {
                anyhow::bail!("invalid request in the overload stream: {reason}")
            }
        }
    }
    let admitted = pending.len();
    let mut lat = Vec::with_capacity(admitted);
    let mut quality = Vec::with_capacity(admitted);
    let mut errors = 0usize;
    let mut picks = 0u64;
    for (t, rx) in pending {
        let resp = rx.recv().map_err(|_| anyhow!("server dropped mid-sweep"))?;
        if resp.pressure_pick {
            picks += 1;
        }
        if resp.outcome == RequestOutcome::Ok {
            lat.push((resp.queue + resp.service).as_secs_f64());
            let served = manifest
                .find(&resp.artifact)
                .map(|a| a.config)
                .context("response names unknown artifact")?;
            quality.push(oracle.quality(t, served));
        } else {
            errors += 1;
        }
    }
    drop(handle);
    let stats = server.shutdown().context("overload point served nothing")?;
    let peak_depth = stats.peak_depth();
    // The bounded-memory guarantee: admission must never let the queue
    // grow past its configured bound, at any offered load.
    anyhow::ensure!(
        peak_depth <= scfg.queue_capacity,
        "peak queue depth {peak_depth} exceeded the bound {}",
        scfg.queue_capacity
    );
    anyhow::ensure!(
        stats.shed() == shed as u64,
        "shed accounting diverged: counter {} vs submitter {shed}",
        stats.shed()
    );
    let pct = |xs: &[f64], p: f64| {
        if xs.is_empty() {
            0.0
        } else {
            percentile(xs, p) * 1e3
        }
    };
    Ok(LoadPoint {
        load,
        offered: n_requests,
        admitted,
        shed,
        errors,
        p50_ms: pct(&lat, 50.0),
        p99_ms: pct(&lat, 99.0),
        peak_depth,
        pressure_picks: picks,
        dtpr: if quality.is_empty() { 0.0 } else { mean(&quality) },
        occupancy_mean: stats.occupancy.mean,
    })
}

/// One loopback network load point: fresh fleet + front door, a warm
/// pass over the wire, then the same paced open-loop arrival process
/// driven by a split client — the sender paces frames while the
/// receiver collects replies concurrently.  Latency is client-observed
/// (encode + socket + decode + serve).  Shed accounting is reconciled
/// three ways: wire status frames seen by the client, the front door's
/// own counters, and the fleet's `ServeStats`.
#[allow(clippy::too_many_arguments)]
fn run_net_point(
    artifacts: &Path,
    manifest: &Manifest,
    mix: &[Triple],
    scfg: ServerConfig,
    max_inflight: usize,
    load: f64,
    offered_rps: f64,
    n_requests: usize,
    seed: u64,
) -> Result<NetPoint> {
    use crate::net::{ClientReply, NetClient, NetConfig, NetServer, WireStatus};

    let server = GemmServer::start(artifacts, host_policy(manifest)?, scfg)?;
    let net = NetServer::bind(
        "127.0.0.1:0".parse().expect("loopback addr"),
        server.handle(),
        NetConfig { max_inflight, ..NetConfig::default() },
    )?;
    let handle = server.handle();
    let mut client = NetClient::connect(net.local_addr())?;

    // Warm pass over the wire, strictly sequential (send one, await its
    // answer): depth never exceeds 1, so warming cannot shed and the
    // cumulative counters stay clean for the reconciliation below.
    let warm = request_stream_from(mix, 2 * mix.len() * scfg.shards, seed ^ 0xAAAA);
    for (i, req) in warm.into_iter().enumerate() {
        let reply = client
            .call(i as u64, 0, "", &req)?
            .context("connection closed during warm pass")?;
        anyhow::ensure!(
            matches!(reply, ClientReply::Served { .. }),
            "warm request answered with {reply:?}"
        );
    }
    handle.reset_peak_depth();

    // Paced open-loop phase.  Replies on one connection come back in
    // request order, so the receiver pairs the k-th reply with the k-th
    // send timestamp handed over the channel.
    let requests = request_stream_from(mix, n_requests, seed);
    let (sender_half, mut receiver_half) = client.split()?;
    let (stamp_tx, stamp_rx) = std::sync::mpsc::channel::<(u64, Instant)>();

    let collector = std::thread::spawn(move || -> Result<(Vec<f64>, usize, usize, usize)> {
        let mut lat = Vec::new();
        let (mut served, mut shed, mut errors) = (0usize, 0usize, 0usize);
        for _ in 0..n_requests {
            let reply = receiver_half
                .recv()
                .map_err(|e| anyhow!("receive failed mid-sweep: {e}"))?
                .context("connection closed mid-sweep")?;
            let (sent_id, sent_at) =
                stamp_rx.recv().map_err(|_| anyhow!("sender died mid-sweep"))?;
            anyhow::ensure!(
                reply.id() == sent_id,
                "reply order diverged: got id {}, expected {sent_id}",
                reply.id()
            );
            match reply {
                ClientReply::Served { .. } => {
                    served += 1;
                    lat.push(sent_at.elapsed().as_secs_f64());
                }
                ClientReply::Status { status, .. } => match status {
                    // Quarantine refusals count as sheds, mirroring the
                    // in-process arm's submitter accounting.
                    WireStatus::Shed | WireStatus::Quarantined => shed += 1,
                    WireStatus::Rejected
                    | WireStatus::Expired
                    | WireStatus::Drained
                    | WireStatus::Busy
                    | WireStatus::Error
                    | WireStatus::Malformed => errors += 1,
                },
            }
        }
        Ok((lat, served, shed, errors))
    });

    let mut sender_half = sender_half;
    let t0 = Instant::now();
    for (i, req) in requests.into_iter().enumerate() {
        let target = t0 + Duration::from_secs_f64(i as f64 / offered_rps);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let sent_at = Instant::now();
        // Stamp before the write so the reply can never race its stamp.
        stamp_tx
            .send((i as u64, sent_at))
            .map_err(|_| anyhow!("collector died mid-sweep"))?;
        sender_half.send(i as u64, 0, "", &req)?;
    }
    drop(stamp_tx);

    let (lat, served, shed, errors) = collector
        .join()
        .map_err(|_| anyhow!("collector thread panicked"))??;
    sender_half.finish()?;

    let net_stats = net.shutdown();
    drop(handle);
    let stats = server.shutdown().context("network point served nothing")?;
    let peak_depth = stats.peak_depth();
    anyhow::ensure!(
        peak_depth <= scfg.queue_capacity,
        "peak queue depth {peak_depth} exceeded the bound {} over the wire",
        scfg.queue_capacity
    );
    // Wire-vs-fleet reconciliation: every shed status frame the client
    // saw must have a fleet-side refusal behind it, and the front
    // door's own ledger must agree with both.
    anyhow::ensure!(
        stats.shed() + stats.quarantined() == shed as u64,
        "shed accounting diverged: fleet {}+{} vs wire {shed}",
        stats.shed(),
        stats.quarantined()
    );
    anyhow::ensure!(
        net_stats.shed + net_stats.quarantined == shed as u64,
        "front-door ledger diverged: {}+{} vs wire {shed}",
        net_stats.shed,
        net_stats.quarantined
    );
    anyhow::ensure!(
        net_stats.served as usize >= served,
        "front door reports fewer served ({}) than the client saw ({served})",
        net_stats.served
    );
    let pct = |xs: &[f64], p: f64| {
        if xs.is_empty() {
            0.0
        } else {
            percentile(xs, p) * 1e3
        }
    };
    Ok(NetPoint {
        load,
        offered: n_requests,
        served,
        shed,
        errors,
        p50_ms: pct(&lat, 50.0),
        p99_ms: pct(&lat, 99.0),
        peak_depth,
    })
}

/// Run the full overload experiment.
pub fn run(artifacts: &Path, cfg: OverloadConfig) -> Result<OverloadReport> {
    anyhow::ensure!(cfg.requests > 0, "overload needs requests > 0");
    anyhow::ensure!(!cfg.load_factors.is_empty(), "overload needs load factors");
    // The CI gates read the 1x point (shed_rate_1x, p99_1x_ms); a sweep
    // without it would report vacuous zeros and green-light the gate.
    anyhow::ensure!(
        cfg.load_factors.iter().any(|&f| (f - 1.0).abs() < 1e-9),
        "load factors must include 1.0 (the shed-rate/p99 gate point)"
    );
    let manifest = Manifest::load(artifacts)?;
    let mix = workload_triples();
    let t_run = Instant::now();

    // ------------------------------------------------ measured oracle
    let oracle = HostOracle::build(artifacts, &mix, cfg.reps)?;

    // ------------------------------------------------ calibration
    let base = ServerConfig {
        shards: cfg.shards,
        queue_capacity: cfg.queue_capacity,
        pressure_slowdown: cfg.pressure_slowdown,
        max_fuse: cfg.max_fuse,
        ..ServerConfig::default()
    };
    let service_secs = calibrate(artifacts, &manifest, &mix, &base)?;
    anyhow::ensure!(
        service_secs.is_finite() && service_secs > 0.0,
        "calibration produced no service time"
    );
    let capacity_rps = cfg.shards as f64 / service_secs;
    let offered_1x = capacity_rps / CALIBRATION_SAFETY;
    let threshold = if cfg.pressure_threshold_ms > 0.0 {
        Duration::from_secs_f64(cfg.pressure_threshold_ms / 1e3)
    } else {
        Duration::from_secs_f64((4.0 * service_secs).max(1e-3))
    };

    // ------------------------------------------------ the sweep
    let mut policy_points = Vec::new();
    let mut pressure_points = Vec::new();
    for (ai, (pressurized, points)) in [
        (false, &mut policy_points),
        (true, &mut pressure_points),
    ]
    .into_iter()
    .enumerate()
    {
        let scfg = ServerConfig {
            pressure_threshold: if pressurized { threshold } else { Duration::MAX },
            ..base
        };
        for (fi, &load) in cfg.load_factors.iter().enumerate() {
            anyhow::ensure!(load > 0.0, "load factors must be positive");
            let seed = 0x0E71 + (ai * 100 + fi) as u64;
            points.push(run_point(
                artifacts,
                &manifest,
                &oracle,
                &mix,
                scfg,
                load,
                offered_1x * load,
                cfg.requests,
                seed,
            )?);
        }
    }

    // -------------------------------------------- the network arm
    // Same mix, same pacing, through the framed loopback front door
    // (policy selection only — the wire adds framing/decode on top of
    // the path the policy arm measured).
    let mut net_points = Vec::new();
    if cfg.net {
        let max_inflight = if cfg.net_inflight == 0 {
            // Auto: never let the socket cap interfere — the arm
            // measures fleet admission, not connection backpressure.
            cfg.requests.max(2 * mix.len() * cfg.shards)
        } else {
            cfg.net_inflight
        };
        for (fi, &load) in cfg.load_factors.iter().enumerate() {
            net_points.push(run_net_point(
                artifacts,
                &manifest,
                &mix,
                base,
                max_inflight,
                load,
                offered_1x * load,
                cfg.requests,
                0x2E70 + fi as u64,
            )?);
        }
    }

    Ok(OverloadReport {
        cfg,
        mix,
        service_secs,
        offered_1x_rps: offered_1x,
        pressure_threshold: threshold,
        policy: policy_points,
        pressure: pressure_points,
        net: net_points,
        wall: t_run.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(load: f64, shed: usize, peak: usize, p99: f64, dtpr: f64) -> LoadPoint {
        LoadPoint {
            load,
            offered: 100,
            admitted: 100 - shed,
            shed,
            errors: 0,
            p50_ms: p99 / 2.0,
            p99_ms: p99,
            peak_depth: peak,
            pressure_picks: 0,
            dtpr,
            occupancy_mean: 1.0,
        }
    }

    fn net_point(load: f64, shed: usize, peak: usize, p99: f64) -> NetPoint {
        NetPoint {
            load,
            offered: 100,
            served: 100 - shed,
            shed,
            errors: 0,
            p50_ms: p99 / 2.0,
            p99_ms: p99,
            peak_depth: peak,
        }
    }

    fn report() -> OverloadReport {
        OverloadReport {
            cfg: OverloadConfig::default(),
            mix: workload_triples(),
            service_secs: 3e-3,
            offered_1x_rps: 200.0,
            pressure_threshold: Duration::from_millis(12),
            policy: vec![
                point(1.0, 0, 3, 8.0, 0.8),
                point(2.0, 10, 24, 90.0, 0.8),
                point(4.0, 55, 24, 120.0, 0.8),
            ],
            pressure: vec![
                point(1.0, 0, 3, 8.5, 0.8),
                point(2.0, 8, 24, 70.0, 0.75),
                point(4.0, 50, 24, 95.0, 0.7),
            ],
            net: vec![
                net_point(1.0, 0, 4, 9.5),
                net_point(2.0, 12, 24, 95.0),
                net_point(4.0, 60, 24, 130.0),
            ],
            wall: Duration::from_secs(2),
        }
    }

    #[test]
    fn summary_metrics_read_the_right_points() {
        let r = report();
        assert_eq!(r.shed_rate_1x(), 0.0);
        assert!(r.depth_bounded());
        assert_eq!(r.p99_1x_ms(), 8.0);
        assert_eq!(r.p99_overload_policy_ms(), 120.0);
        assert_eq!(r.p99_overload_pressure_ms(), 95.0);
        assert!(r.pressure_p99_improved());
        assert_eq!(r.peak_depth_max(), 24);
        assert_eq!(r.dtpr_1x_policy(), 0.8);
    }

    #[test]
    fn depth_bound_violation_and_1x_sheds_are_visible() {
        let mut r = report();
        r.pressure[0].shed = 3; // sheds at 1x on one arm
        assert!((r.shed_rate_1x() - 0.03).abs() < 1e-12);
        r.policy[2].peak_depth = 99; // past the bound of 24
        assert!(!r.depth_bounded());
        let rendered = r.render();
        assert!(rendered.contains("EXCEEDED"), "{rendered}");
    }

    #[test]
    fn json_summary_carries_the_gate_fields() {
        let json = report().to_json();
        assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "overload");
        assert_eq!(json.get("shed_rate_1x").unwrap().as_f64().unwrap(), 0.0);
        assert!(json.get("depth_bounded").unwrap().as_bool().unwrap());
        assert_eq!(json.get("p99_1x_ms").unwrap().as_f64().unwrap(), 8.0);
        assert!(json.get("pressure_p99_improved").unwrap().as_bool().unwrap());
        let arms = json.get("arms").unwrap().as_arr().unwrap();
        assert_eq!(arms.len(), 2);
        let pts = arms[1].get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 3);
        assert!(pts[1].get("shed_rate").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn net_arm_metrics_read_the_1x_point() {
        let r = report();
        assert_eq!(r.net_shed_rate_1x(), 0.0);
        assert_eq!(r.net_p99_1x_ms(), 9.5);
        assert!(r.net_depth_bounded());
        let mut bad = report();
        bad.net[0].shed = 5;
        assert!((bad.net_shed_rate_1x() - 0.05).abs() < 1e-12);
        bad.net[2].peak_depth = 999;
        assert!(!bad.net_depth_bounded());
        let rendered = bad.render();
        assert!(rendered.contains("network arm"), "{rendered}");
        assert!(rendered.contains("EXCEEDED"), "{rendered}");
    }

    #[test]
    fn net_arm_json_keys_present_iff_the_arm_ran() {
        let json = report().to_json();
        assert_eq!(json.get("net_shed_rate_1x").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(json.get("net_p99_1x_ms").unwrap().as_f64().unwrap(), 9.5);
        assert!(json.get("net_depth_bounded").unwrap().as_bool().unwrap());
        let pts = json
            .get("net_arm")
            .unwrap()
            .get("points")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(pts.len(), 3);
        assert!(pts[2].get("shed_rate").unwrap().as_f64().unwrap() > 0.0);

        let mut skipped = report();
        skipped.net.clear();
        let json = skipped.to_json();
        assert!(json.get("net_shed_rate_1x").is_none());
        assert!(json.get("net_arm").is_none());
        // The in-process gate keys are unaffected by skipping the arm.
        assert_eq!(json.get("p99_1x_ms").unwrap().as_f64().unwrap(), 8.0);
    }
}
