//! End-to-end experiment on the *real* device: the full adaptive-library
//! loop over the CPU PJRT runtime and the AOT Pallas artifacts.
//!
//! Off-line: tune the artifact roster per workload triple (real
//! wall-clock), train a decision tree, build the model policy.
//! On-line: serve a batched request stream through the coordinator under
//! (a) the model policy and (b) the CLBlast-default policy, and compare
//! latency/throughput — the paper's Figure 6/7 experiment, measured.

use std::path::Path;

use anyhow::{Context as _, Result};

use crate::config::Triple;
use crate::coordinator::{
    DefaultPolicy, GemmRequest, GemmServer, ModelPolicy, SelectPolicy, ServeStats,
    ServerConfig,
};
use crate::dataset::ClassTable;
use crate::dtree::{train, DecisionTree, MinSamples, TrainParams};
use crate::metrics::accuracy;
use crate::runtime::PjrtBackend;
use crate::tuner::{Backend, Tuner, TuningDb};
use crate::util::prng::Rng;

/// Workload triples for the e2e run: shapes the roster serves exactly
/// (direct artifacts) plus in-bucket shapes (indirect artifacts).
pub fn workload_triples() -> Vec<Triple> {
    vec![
        Triple::new(64, 64, 64),
        Triple::new(128, 128, 128),
        Triple::new(200, 50, 100),
        Triple::new(50, 200, 75),
        Triple::new(31, 31, 31),
        Triple::new(100, 100, 1),
        // In-bucket shapes (served by padding into 128/256 buckets).
        Triple::new(100, 100, 100),
        Triple::new(120, 120, 64),
        Triple::new(96, 128, 96),
        Triple::new(250, 250, 250),
        Triple::new(200, 200, 200),
        Triple::new(128, 250, 128),
    ]
}

/// Result of the off-line phase on the real device.
pub struct E2eModel {
    pub tree: DecisionTree,
    pub classes: ClassTable,
    pub db: TuningDb,
    pub train_accuracy: f64,
    pub tuned_triples: usize,
}

impl std::fmt::Debug for E2eModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("E2eModel").finish_non_exhaustive()
    }
}

/// Off-line: tune every workload triple on the PJRT backend and train.
pub fn offline_train(artifacts: &Path, reps: usize) -> Result<E2eModel> {
    let mut backend = PjrtBackend::open(artifacts)?;
    backend.reps = reps;
    let tuner = Tuner::default();
    let mut db = TuningDb::new(backend.device_name());
    let mut classes = ClassTable::new();
    let mut entries = Vec::new();
    for t in workload_triples() {
        let (cfg, g) = tuner
            .tune_triple(&mut backend, t)
            .with_context(|| format!("no artifact serves {t}"))?;
        db.insert(t, cfg, g);
        entries.push((t, classes.intern(cfg)));
    }
    let tree = train(
        &entries,
        classes.len(),
        TrainParams { max_depth: None, min_samples_leaf: MinSamples::Count(1) },
    );
    let train_accuracy = accuracy(&tree, &entries);
    Ok(E2eModel {
        tree,
        classes,
        db,
        train_accuracy,
        tuned_triples: entries.len(),
    })
}

/// Build a deterministic request stream over the workload triples.
pub fn request_stream(n: usize, seed: u64) -> Vec<GemmRequest> {
    request_stream_from(&workload_triples(), n, seed)
}

/// Build a deterministic request stream over an explicit triple mix —
/// the drift experiment switches mixes mid-run through this.
pub fn request_stream_from(triples: &[Triple], n: usize, seed: u64) -> Vec<GemmRequest> {
    assert!(!triples.is_empty(), "request stream needs a triple mix");
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let t = *rng.choose(triples);
            let (m, n_, k) = (t.m as usize, t.n as usize, t.k as usize);
            let gen = |rng: &mut Rng, len: usize| -> Vec<f32> {
                (0..len).map(|_| rng.f32() - 0.5).collect()
            };
            GemmRequest {
                m,
                n: n_,
                k,
                a: gen(&mut rng, m * k),
                b: gen(&mut rng, k * n_),
                c: gen(&mut rng, m * n_),
                alpha: 1.0,
                beta: 0.0,
            }
        })
        .collect()
}

/// On-line: serve `requests` through a policy; returns serving stats.
pub fn serve(
    artifacts: &Path,
    policy: Box<dyn SelectPolicy>,
    requests: Vec<GemmRequest>,
    cfg: ServerConfig,
) -> Result<ServeStats> {
    let server = GemmServer::start(artifacts, policy, cfg)?;
    let handle = server.handle();
    // Submit everything, then wait for all responses (closed-loop client
    // with a submission window to exercise the batcher).
    let mut pending = Vec::with_capacity(requests.len());
    for req in requests {
        pending.push(handle.submit(req));
    }
    let mut errors = 0usize;
    for rx in pending {
        match rx.recv() {
            Ok(resp) if resp.out.is_ok() => {}
            _ => errors += 1,
        }
    }
    drop(handle);
    let stats = server.shutdown().context("no requests served")?;
    anyhow::ensure!(errors == 0, "{errors} requests failed");
    Ok(stats)
}

/// Full e2e comparison: model-driven vs default policy.
pub struct E2eReport {
    pub model: E2eModel,
    pub stats_model: ServeStats,
    pub stats_default: ServeStats,
}

impl std::fmt::Debug for E2eReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("E2eReport").finish_non_exhaustive()
    }
}

impl E2eReport {
    pub fn speedup(&self) -> f64 {
        self.stats_model.gflops() / self.stats_default.gflops()
    }

    pub fn render(&self) -> String {
        format!(
            "=== E2E adaptive serving (CPU PJRT, real measurements) ===\n\
             off-line: tuned {} triples, tree '{}' ({} leaves, depth {}), train accuracy {:.0}%\n\n\
             --- model-driven policy ---\n{}\n\
             --- default policy ---\n{}\n\
             aggregate speedup (model vs default): {:.2}x\n",
            self.model.tuned_triples,
            self.model.tree.name,
            self.model.tree.n_leaves(),
            self.model.tree.depth(),
            self.model.train_accuracy,
            self.stats_model.report(),
            self.stats_default.report(),
            self.speedup(),
        )
    }
}

/// Run the whole experiment with the default (single-shard) coordinator.
pub fn run(artifacts: &Path, n_requests: usize, reps: usize) -> Result<E2eReport> {
    run_with(artifacts, n_requests, reps, ServerConfig::default())
}

/// Run the whole experiment under an explicit coordinator configuration
/// (e.g. a sharded dispatcher).
pub fn run_with(
    artifacts: &Path,
    n_requests: usize,
    reps: usize,
    cfg: ServerConfig,
) -> Result<E2eReport> {
    let model = offline_train(artifacts, reps)?;
    let requests = request_stream(n_requests, 0xE2E);

    let model_policy = Box::new(ModelPolicy::new(&model.tree, &model.classes));
    let stats_model = serve(artifacts, model_policy, requests.clone(), cfg)?;

    let mut backend = PjrtBackend::open(artifacts)?;
    let roster = backend.roster_configs();
    let _ = &mut backend;
    let default_policy = Box::new(
        DefaultPolicy::from_roster(&roster).context("roster lacks a kernel kind")?,
    );
    let stats_default = serve(artifacts, default_policy, requests, cfg)?;

    Ok(E2eReport { model, stats_model, stats_default })
}
