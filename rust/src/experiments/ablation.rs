//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! 1. **Tuner budget** (paper §4.1: "It is possible to trade off quality
//!    versus time by sampling randomly"): exhaustive vs random-sample vs
//!    simulated annealing at several budgets — peak fraction achieved.
//! 2. **Classifier choice** (paper §3/§7): CART vs k-NN vs majority
//!    baseline — accuracy on the same split.
//! 3. **Cross-validation** of the paper's best CART settings.

use crate::dataset::DatasetKind;
use crate::device::{DeviceId, DeviceProfile};
use crate::dtree::{
    classifier_accuracy, cross_validate, KNearest, MajorityClass,
};
use crate::tuner::{anneal, AnnealParams, SearchStrategy, SimBackend, Tuner};
use crate::util::csv::CsvWriter;
use crate::util::stats::mean;
use crate::util::table;

use super::context::Context;
use super::tables::Rendered;

/// Ablation 1: search-budget quality on a sample of po2 triples.
pub fn tuner_budget(device: DeviceId) -> Rendered {
    let mut backend = SimBackend::new(DeviceProfile::get(device));
    let triples: Vec<_> = crate::dataset::po2_triples()
        .into_iter()
        .step_by(9) // 24 representative triples
        .collect();
    let exhaustive = Tuner::default();
    let peaks: Vec<f64> = triples
        .iter()
        .map(|&t| exhaustive.tune_triple(&mut backend, t).unwrap().1)
        .collect();

    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["strategy", "budget", "peak_fraction"]);
    let push = |name: &str, budget: usize, frac: f64,
                    rows: &mut Vec<Vec<String>>, csv: &mut CsvWriter| {
        let row = vec![name.to_string(), budget.to_string(), table::f(frac, 3)];
        csv.row(&row);
        rows.push(row);
    };
    push("exhaustive", backend.legal_count(), 1.0, &mut rows, &mut csv);

    for budget in [50usize, 200, 800] {
        // Random sampling.
        let sampler = Tuner::new(SearchStrategy::RandomSample { count: budget, seed: 1 });
        let fracs: Vec<f64> = triples
            .iter()
            .zip(&peaks)
            .map(|(&t, &p)| sampler.tune_triple(&mut backend, t).unwrap().1 / p)
            .collect();
        push("random", budget, mean(&fracs), &mut rows, &mut csv);
        // Simulated annealing at the same budget.
        let fracs: Vec<f64> = triples
            .iter()
            .zip(&peaks)
            .map(|(&t, &p)| {
                anneal(&mut backend, t, AnnealParams { evaluations: budget, ..Default::default() })
                    .unwrap()
                    .1
                    / p
            })
            .collect();
        push("anneal", budget, mean(&fracs), &mut rows, &mut csv);
    }
    let ascii = table::render(
        &format!("Ablation: tuner budget vs peak fraction ({device}, po2 sample)"),
        &["Strategy", "Budget (evals)", "Peak fraction"],
        &rows,
    );
    Rendered { id: "ablation_tuner", ascii, csv }
}

/// Ablation 2+3: classifier comparison and CV on one sweep's split.
pub fn classifiers(ctx: &mut Context, device: DeviceId, kind: DatasetKind) -> Rendered {
    let sweep = ctx.sweep(device, kind);
    let train_set = sweep.labeled.subset(&sweep.train_idx);
    let test_set = sweep.labeled.subset(&sweep.test_idx);
    let n_classes = sweep.labeled.classes.len();

    let best = sweep.best_model();
    let majority = MajorityClass::fit(&train_set, n_classes);
    let knn1 = KNearest::fit(&train_set, n_classes, 1);
    let knn5 = KNearest::fit(&train_set, n_classes, 5);

    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["classifier", "test_accuracy_pct", "deployable"]);
    let entries: Vec<(String, f64, &str)> = vec![
        (
            best.tree.name.clone(),
            classifier_accuracy(&best.tree, &test_set),
            "yes (codegen if-then-else)",
        ),
        (
            "majority".into(),
            classifier_accuracy(&majority, &test_set),
            "yes (trivial)",
        ),
        ("knn-1".into(), classifier_accuracy(&knn1, &test_set), "no (needs training set)"),
        ("knn-5".into(), classifier_accuracy(&knn5, &test_set), "no (needs training set)"),
    ];
    for (name, acc, deploy) in entries {
        let row = vec![name, table::f(acc, 1), deploy.to_string()];
        csv.row(&row);
        rows.push(row);
    }
    let mut ascii = table::render(
        &format!("Ablation: classifier comparison ({device}/{kind})"),
        &["Classifier", "Test accuracy %", "Deployable in-library?"],
        &rows,
    );
    // Cross-validation of the best model's hyper-parameters.
    let (cv_mean, cv_sd) = cross_validate(
        &sweep.labeled.entries,
        n_classes,
        best.params,
        5,
        0xCF,
    );
    ascii.push_str(&format!(
        "\n5-fold CV of {} on the full dataset: {:.1}% ± {:.1}%\n",
        best.params.name(),
        cv_mean,
        cv_sd
    ));
    Rendered { id: "ablation_classifiers", ascii, csv }
}

/// Run both ablations with default settings.
pub fn run_all(ctx: &mut Context) -> Vec<Rendered> {
    vec![
        tuner_budget(DeviceId::NvidiaP100),
        classifiers(ctx, DeviceId::NvidiaP100, DatasetKind::Po2),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifier_ablation_renders() {
        let mut ctx = Context::new();
        ctx.model_limit = Some(3);
        let r = classifiers(&mut ctx, DeviceId::MaliT860, DatasetKind::Po2);
        assert!(r.ascii.contains("knn-5"));
        assert!(r.ascii.contains("5-fold CV"));
        assert_eq!(r.csv.len(), 4);
    }
}
