//! Hand-rolled CLI argument parser (no clap offline): subcommands,
//! `--flag value` / `--flag=value` options, boolean switches, positional
//! arguments, and generated usage text.

use std::collections::BTreeMap;

#[derive(Debug, PartialEq)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    InvalidValue(String, String),
    MissingCommand(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(o) => write!(f, "unknown option: {o}"),
            CliError::MissingValue(o) => write!(f, "option {o} requires a value"),
            CliError::InvalidValue(o, v) => write!(f, "invalid value for {o}: {v}"),
            CliError::MissingCommand(c) => {
                write!(f, "missing subcommand; expected one of: {c}")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Declarative option spec.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Vec<String>,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                CliError::InvalidValue(name.to_string(), s.to_string())
            }),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Parse argv against a spec: `specs` lists value-taking options,
/// `switches` boolean flags.  The first `n_command` non-option tokens are
/// treated as the (sub)command path; the rest are positional.
pub fn parse(
    argv: &[String],
    specs: &[OptSpec],
    switches: &[&str],
    n_command: usize,
) -> Result<Args, CliError> {
    let mut args = Args::default();
    for s in specs {
        if let (true, Some(d)) = (s.takes_value, s.default) {
            args.opts.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(stripped) = tok.strip_prefix("--") {
            let (name, inline_val) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            if switches.contains(&name) {
                if inline_val.is_some() {
                    return Err(CliError::InvalidValue(
                        name.to_string(),
                        "switch takes no value".to_string(),
                    ));
                }
                args.switches.push(name.to_string());
            } else if let Some(spec) = specs.iter().find(|s| s.name == name) {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.into()))?
                    }
                };
                let _ = spec;
                args.opts.insert(name.to_string(), val);
            } else {
                return Err(CliError::UnknownOption(tok.clone()));
            }
        } else if args.command.len() < n_command {
            args.command.push(tok.clone());
        } else {
            args.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render usage text from specs.  `switches` are the boolean flags
/// accepted alongside the value-taking options, with their help text.
pub fn usage(
    program: &str,
    commands: &[(&str, &str)],
    specs: &[OptSpec],
    switches: &[(&str, &str)],
) -> String {
    let mut out = format!("usage: {program} <command> [options]\n\ncommands:\n");
    for (c, h) in commands {
        out.push_str(&format!("  {c:<18} {h}\n"));
    }
    if !specs.is_empty() {
        out.push_str("\noptions:\n");
        for s in specs {
            let val = if s.takes_value { " <value>" } else { "" };
            let def = s
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            out.push_str(&format!("  --{}{val:<10} {}{def}\n", s.name, s.help));
        }
    }
    if !switches.is_empty() {
        out.push_str("\nswitches:\n");
        for (name, help) in switches {
            out.push_str(&format!("  --{name:<16} {help}\n"));
        }
    }
    out
}

/// Parse a socket address option (`host:port`) through the typed error
/// path: an invalid value yields a [`CliError::InvalidValue`] whose
/// message spells out the expected form instead of panicking.
pub fn parse_addr(name: &str, value: &str) -> Result<std::net::SocketAddr, CliError> {
    value.parse().map_err(|_| {
        CliError::InvalidValue(
            name.to_string(),
            format!("{value} — expected <ip>:<port>, e.g. 127.0.0.1:7070"),
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "device", help: "", takes_value: true, default: Some("p100") },
            OptSpec { name: "out", help: "", takes_value: true, default: None },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_opts_positional() {
        let a = parse(
            &sv(&["exp", "table3", "--device", "mali", "extra"]),
            &specs(),
            &["quiet"],
            2,
        )
        .unwrap();
        assert_eq!(a.command, vec!["exp", "table3"]);
        assert_eq!(a.get("device"), Some("mali"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = parse(&sv(&["run", "--device=cpu"]), &specs(), &[], 1).unwrap();
        assert_eq!(a.get("device"), Some("cpu"));
        let b = parse(&sv(&["run"]), &specs(), &[], 1).unwrap();
        assert_eq!(b.get("device"), Some("p100")); // default applied
        assert_eq!(b.get("out"), None); // no default
    }

    #[test]
    fn switches() {
        let a = parse(&sv(&["x", "--quiet"]), &specs(), &["quiet"], 1).unwrap();
        assert!(a.has("quiet"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn errors() {
        assert_eq!(
            parse(&sv(&["--bogus"]), &specs(), &[], 0).unwrap_err(),
            CliError::UnknownOption("--bogus".into())
        );
        assert_eq!(
            parse(&sv(&["--out"]), &specs(), &[], 0).unwrap_err(),
            CliError::MissingValue("out".into())
        );
    }

    #[test]
    fn get_parse_types() {
        let a = parse(&sv(&["x", "--device", "42"]), &specs(), &[], 1).unwrap();
        let v: u32 = a.get_parse("device", 0).unwrap();
        assert_eq!(v, 42);
        let bad: Result<u32, _> = parse(&sv(&["x", "--device", "zz"]), &specs(), &[], 1)
            .unwrap()
            .get_parse("device", 0);
        assert!(bad.is_err());
    }

    #[test]
    fn parse_addr_typed_errors() {
        let ok = parse_addr("listen", "127.0.0.1:7070").unwrap();
        assert_eq!(ok.port(), 7070);
        let any_port = parse_addr("listen", "0.0.0.0:0").unwrap();
        assert_eq!(any_port.port(), 0);
        match parse_addr("listen", "localhost") {
            Err(CliError::InvalidValue(name, v)) => {
                assert_eq!(name, "listen");
                assert!(v.contains("expected <ip>:<port>"), "message lists the form: {v}");
            }
            other => panic!("expected InvalidValue, got {other:?}"),
        }
        assert!(parse_addr("listen", "1.2.3.4:notaport").is_err());
        assert!(parse_addr("listen", "").is_err());
    }

    #[test]
    fn usage_lists_commands_options_and_switches() {
        let u = usage(
            "adaptd",
            &[("tune", "run the tuner")],
            &specs(),
            &[("quiet", "suppress progress output")],
        );
        assert!(u.contains("tune") && u.contains("--device"));
        assert!(u.contains("switches:") && u.contains("--quiet"));
        // No switches: the section is omitted entirely.
        let u = usage("adaptd", &[], &specs(), &[]);
        assert!(!u.contains("switches:"));
    }
}
