//! Tuning database: the per-triple best configuration + its GFLOP/s —
//! the paper's "peak of the tuner" oracle, persisted as JSON.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::{KernelConfig, Triple};
use crate::util::json::Json;

/// Best-known tuning result per triple on one device.
#[derive(Debug, Clone, Default)]
pub struct TuningDb {
    pub device: String,
    entries: HashMap<Triple, (KernelConfig, f64)>,
}

impl TuningDb {
    pub fn new(device: impl Into<String>) -> Self {
        TuningDb { device: device.into(), entries: HashMap::new() }
    }

    pub fn insert(&mut self, t: Triple, cfg: KernelConfig, gflops: f64) {
        match self.entries.get(&t) {
            Some((_, old)) if *old >= gflops => {}
            _ => {
                self.entries.insert(t, (cfg, gflops));
            }
        }
    }

    pub fn best(&self, t: Triple) -> Option<&(KernelConfig, f64)> {
        self.entries.get(&t)
    }

    /// Peak GFLOP/s (the tuner upper bound) for a triple.
    pub fn peak(&self, t: Triple) -> Option<f64> {
        self.entries.get(&t).map(|(_, g)| *g)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&Triple, &(KernelConfig, f64))> {
        self.entries.iter()
    }

    // ------------------------------------------------------- persistence

    pub fn to_json(&self) -> Json {
        let mut rows: Vec<(&Triple, &(KernelConfig, f64))> =
            self.entries.iter().collect();
        rows.sort_by_key(|(t, _)| **t);
        Json::obj(vec![
            ("device", Json::str(self.device.clone())),
            (
                "entries",
                Json::Arr(
                    rows.into_iter()
                        .map(|(t, (cfg, g))| {
                            Json::obj(vec![
                                ("triple", t.to_json()),
                                ("config", cfg.to_json()),
                                ("gflops", Json::num(*g)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let mut db = TuningDb::new(v.get("device")?.as_str()?);
        for e in v.get("entries")?.as_arr()? {
            let t = Triple::from_json(e.get("triple")?)?;
            let cfg = KernelConfig::from_json(e.get("config")?)?;
            let g = e.get("gflops")?.as_f64()?;
            db.insert(t, cfg, g);
        }
        Ok(db)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::XgemmParams;

    #[test]
    fn insert_keeps_best() {
        let mut db = TuningDb::new("test");
        let t = Triple::new(1, 2, 3);
        let cfg = KernelConfig::Xgemm(XgemmParams::default());
        db.insert(t, cfg, 10.0);
        db.insert(t, cfg, 5.0); // worse: ignored
        assert_eq!(db.peak(t), Some(10.0));
        db.insert(t, cfg, 20.0); // better: replaces
        assert_eq!(db.peak(t), Some(20.0));
    }

    #[test]
    fn json_roundtrip() {
        let mut db = TuningDb::new("p100");
        db.insert(
            Triple::new(64, 64, 64),
            KernelConfig::Xgemm(XgemmParams::default()),
            42.5,
        );
        let back = TuningDb::from_json(&db.to_json()).unwrap();
        assert_eq!(back.device, "p100");
        assert_eq!(back.peak(Triple::new(64, 64, 64)), Some(42.5));
    }
}
