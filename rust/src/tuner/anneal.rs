//! Simulated-annealing search — the meta-heuristic CLTune itself offers
//! and the paper cites as the standard huge-search-space mitigation
//! (§6, [39][49]).  Used by the quality-vs-cost ablation
//! (`adaptd exp ablation`): how close does a budgeted search get to the
//! exhaustive tuner's peak?

use crate::config::{KernelConfig, Triple};
use crate::util::prng::Rng;

use super::Backend;

/// Annealing-schedule parameters.
#[derive(Debug, Clone, Copy)]
pub struct AnnealParams {
    /// Total measurements (the budget).
    pub evaluations: usize,
    /// Initial acceptance temperature as a fraction of the first value.
    pub t0_frac: f64,
    pub seed: u64,
}

impl Default for AnnealParams {
    fn default() -> Self {
        AnnealParams { evaluations: 200, t0_frac: 0.3, seed: 0xA11EA1 }
    }
}

/// Search `backend`'s candidate space for `triple` with simulated
/// annealing over the *index space* of the candidate list (neighbours =
/// nearby indices; the list is static-efficiency-ordered on SimBackend,
/// so index distance approximates config similarity).
pub fn anneal<B: Backend + ?Sized>(
    backend: &mut B,
    triple: Triple,
    params: AnnealParams,
) -> Option<(KernelConfig, f64)> {
    let candidates = backend.candidates_shared(triple);
    if candidates.is_empty() {
        return None;
    }
    let n = candidates.len();
    let mut rng = Rng::new(
        params.seed ^ ((triple.m as u64) << 40) ^ ((triple.n as u64) << 20)
            ^ triple.k as u64,
    );

    // Start from a random measurable point.
    let mut cur_idx = rng.below(n as u64) as usize;
    let mut cur_g = f64::MIN;
    for _ in 0..n {
        if let Some(g) = backend.measure(&candidates[cur_idx], triple) {
            cur_g = g;
            break;
        }
        cur_idx = rng.below(n as u64) as usize;
    }
    if cur_g == f64::MIN {
        return None;
    }
    let mut best = (candidates[cur_idx], cur_g);

    let evals = params.evaluations.max(2);
    let t0 = params.t0_frac * cur_g.abs().max(1e-9);
    for step in 0..evals {
        // Geometric cooling to ~1% of t0.
        let temp = t0 * (0.01f64).powf(step as f64 / evals as f64);
        // Neighbour: jump within a window that shrinks as we cool.
        let window = ((n as f64) * 0.25 * (temp / t0).max(0.02)) as i64 + 1;
        let delta = rng.below(2 * window as u64 + 1) as i64 - window;
        let next_idx = (cur_idx as i64 + delta).rem_euclid(n as i64) as usize;
        let Some(next_g) = backend.measure(&candidates[next_idx], triple) else {
            continue;
        };
        if next_g > best.1 {
            best = (candidates[next_idx], next_g);
        }
        let accept = next_g >= cur_g || {
            let p = ((next_g - cur_g) / temp).exp();
            rng.f64() < p
        };
        if accept {
            cur_idx = next_idx;
            cur_g = next_g;
        }
    }
    Some(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceProfile;
    use crate::tuner::{SimBackend, Tuner};

    #[test]
    fn anneal_finds_near_peak_with_small_budget() {
        let mut backend = SimBackend::new(DeviceProfile::nvidia_p100());
        let t = Triple::new(512, 512, 512);
        let (_, exhaustive) = Tuner::default().tune_triple(&mut backend, t).unwrap();
        let (_, annealed) = anneal(
            &mut backend,
            t,
            AnnealParams { evaluations: 300, ..Default::default() },
        )
        .unwrap();
        // 300 evals over a ~4-6k space should land within 25% of peak.
        assert!(
            annealed >= 0.75 * exhaustive,
            "anneal {annealed:.1} vs exhaustive {exhaustive:.1}"
        );
        assert!(annealed <= exhaustive + 1e-9, "anneal cannot beat exhaustive");
    }

    #[test]
    fn anneal_deterministic_per_seed() {
        let mut backend = SimBackend::new(DeviceProfile::mali_t860());
        let t = Triple::new(256, 128, 256);
        let p = AnnealParams { evaluations: 60, ..Default::default() };
        let a = anneal(&mut backend, t, p).unwrap();
        let b = anneal(&mut backend, t, p).unwrap();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn bigger_budget_does_not_hurt() {
        let mut backend = SimBackend::new(DeviceProfile::mali_t860());
        let t = Triple::new(1024, 256, 512);
        let small = anneal(
            &mut backend,
            t,
            AnnealParams { evaluations: 30, ..Default::default() },
        )
        .unwrap()
        .1;
        let large = anneal(
            &mut backend,
            t,
            AnnealParams { evaluations: 500, ..Default::default() },
        )
        .unwrap()
        .1;
        assert!(large >= small * 0.999, "large {large} < small {small}");
    }
}
