//! Data-driven roster selection — "A Few Fit Most" (arxiv 2507.15277):
//! instead of shipping a hand-picked host-variant roster, take the
//! tuner's *measured* sweep results and keep the top-K variants per
//! padding bucket.  The emitted JSON carries full `HostParams` configs
//! in the manifest's `host_simd` field format, so a curated roster file
//! can replace the hard-coded `host_variants()` four (plus packed
//! twins) without touching the expansion machinery.

use std::collections::BTreeMap;

use crate::config::{HostParams, Triple};
use crate::util::json::Json;

/// One measured sweep point: a host variant run against one triple that
/// pads into `bucket`.
#[derive(Debug, Clone, Copy)]
pub struct SweepSample {
    /// The padding bucket `(mb, nb, kb)` the triple falls into.
    pub bucket: (u32, u32, u32),
    pub params: HostParams,
    pub triple: Triple,
    pub gflops: f64,
}

/// The measured top-K host variants of one padding bucket, best first.
#[derive(Debug, Clone)]
pub struct BucketRoster {
    pub bucket: (u32, u32, u32),
    /// `(variant, mean measured GFLOP/s across the bucket's triples)`,
    /// sorted by mean descending (name ascending on exact ties, so the
    /// output is deterministic).
    pub variants: Vec<(HostParams, f64)>,
}

impl BucketRoster {
    /// Manifest-shaped JSON: each entry carries the variant name plus
    /// the exact `config` object `Manifest::load`'s `host_simd` parser
    /// consumes (tier/mr/nr/ku/packed), and the measurement that ranked
    /// it — everything a curation step needs to emit roster artifacts.
    pub fn to_json(&self) -> Json {
        let (mb, nb, kb) = self.bucket;
        Json::obj(vec![
            (
                "bucket",
                Json::Arr(vec![Json::num(mb), Json::num(nb), Json::num(kb)]),
            ),
            (
                "variants",
                Json::Arr(
                    self.variants
                        .iter()
                        .map(|(p, g)| {
                            Json::obj(vec![
                                ("name", Json::str(p.name())),
                                ("config", p.to_json()),
                                ("mean_gflops", Json::Num(*g)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Reduce raw sweep samples to the measured top-K variants per bucket.
///
/// Samples are grouped by bucket; within a bucket each variant's score
/// is the mean GFLOP/s over every triple it was swept on (so a variant
/// that only shines on one corner of the bucket does not displace one
/// that fits most of it — the paper's selection criterion).  Buckets
/// come back in ascending `(mb, nb, kb)` order.
pub fn measured_roster(samples: &[SweepSample], k: usize) -> Vec<BucketRoster> {
    // bucket -> variant name -> (params, sum, count).  BTreeMaps keep
    // the whole reduction deterministic.
    let mut acc: BTreeMap<(u32, u32, u32), BTreeMap<String, (HostParams, f64, u32)>> =
        BTreeMap::new();
    for s in samples {
        let e = acc
            .entry(s.bucket)
            .or_default()
            .entry(s.params.name())
            .or_insert((s.params, 0.0, 0));
        e.1 += s.gflops;
        e.2 += 1;
    }
    acc.into_iter()
        .map(|(bucket, by_variant)| {
            let mut variants: Vec<(HostParams, f64)> = by_variant
                .into_values()
                .map(|(p, sum, n)| (p, sum / n as f64))
                .collect();
            variants.sort_by(|(pa, ga), (pb, gb)| {
                gb.partial_cmp(ga)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| pa.name().cmp(&pb.name()))
            });
            variants.truncate(k);
            BucketRoster { bucket, variants }
        })
        .collect()
}

/// The full curated-roster document: one entry per bucket.
pub fn roster_to_json(rosters: &[BucketRoster]) -> Json {
    Json::obj(vec![
        ("version", Json::num(1u32)),
        ("kind", Json::str("host_variant_roster")),
        (
            "buckets",
            Json::Arr(rosters.iter().map(BucketRoster::to_json).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{host_variants, SimdTier};

    /// A synthetic sweep with a known ranking: per bucket, score each
    /// variant by a deterministic formula and check `measured_roster`
    /// recovers the top-K in order, averaging across triples.
    #[test]
    fn top_k_per_bucket_from_synthetic_sweep() {
        let buckets = [(128u32, 128u32, 128u32), (256, 256, 256)];
        let vs = host_variants();
        let mut samples = Vec::new();
        for (bi, &bucket) in buckets.iter().enumerate() {
            for (vi, p) in vs.iter().enumerate() {
                // Two triples per (bucket, variant) whose mean is
                // vi-ranked in bucket 0 and reverse-ranked in bucket 1.
                let base = if bi == 0 {
                    10.0 + vi as f64
                } else {
                    10.0 + (vs.len() - vi) as f64
                };
                for (t, wobble) in [
                    (Triple::new(100, 100, 100), -1.0),
                    (Triple::new(120, 120, 120), 1.0),
                ] {
                    samples.push(SweepSample {
                        bucket,
                        params: *p,
                        triple: t,
                        gflops: base + wobble,
                    });
                }
            }
        }
        let rosters = measured_roster(&samples, 3);
        assert_eq!(rosters.len(), 2);
        assert_eq!(rosters[0].bucket, buckets[0]);
        assert_eq!(rosters[1].bucket, buckets[1]);
        for r in &rosters {
            assert_eq!(r.variants.len(), 3);
            // Means descend.
            assert!(r.variants.windows(2).all(|w| w[0].1 >= w[1].1));
        }
        // Bucket 0 ranks the last roster variant first, bucket 1 the
        // first — the helper followed the measurements, not the roster
        // order.
        assert_eq!(rosters[0].variants[0].0, vs[vs.len() - 1]);
        assert_eq!(rosters[1].variants[0].0, vs[0]);
        // The mean is the average of the two wobbled triples.
        assert!((rosters[1].variants[0].1 - (10.0 + vs.len() as f64)).abs() < 1e-9);
    }

    /// The emitted config objects round-trip through the same parser the
    /// manifest uses, packed axis included — the wiring that lets a
    /// curated roster replace the hand-picked four later.
    #[test]
    fn roster_json_configs_roundtrip_as_host_params() {
        let p = HostParams {
            tier: SimdTier::Avx2Fma,
            mr: 8,
            nr: 8,
            ku: 4,
            packed: true,
        };
        let samples = [SweepSample {
            bucket: (128, 128, 128),
            params: p,
            triple: Triple::new(100, 100, 100),
            gflops: 42.0,
        }];
        let rosters = measured_roster(&samples, 4);
        let doc = roster_to_json(&rosters);
        let buckets = doc.get("buckets").unwrap();
        let Json::Arr(bs) = buckets else { panic!("buckets not an array") };
        let entry = bs[0].get("variants").unwrap();
        let Json::Arr(vars) = entry else { panic!("variants not an array") };
        assert_eq!(vars.len(), 1);
        assert_eq!(
            vars[0].get("name").unwrap().as_str().unwrap(),
            "h_avx2_t8x8_u4_p"
        );
        let cfg = vars[0].get("config").unwrap();
        assert_eq!(HostParams::from_json(cfg).unwrap(), p);
    }
}
