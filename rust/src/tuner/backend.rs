//! Measurement backends for the tuner.
//!
//! `Backend` abstracts "run configuration c on input i, report GFLOP/s" —
//! the paper's objective function `f_a(i)`.  Two implementations:
//!
//! * [`SimBackend`] — the analytical device model (P100 / Mali), used to
//!   regenerate the paper's tables and figures;
//! * `runtime::PjrtBackend` — real wall-clock measurements of the AOT'd
//!   Pallas artifacts on the CPU PJRT client (the end-to-end path).

use crate::config::{direct_space, xgemm_space, KernelConfig, Triple};
use crate::device::{sim, DeviceProfile};

use std::sync::Arc;

/// Stable per-config fingerprint (shared with the simulator's noise).
fn fingerprint(cfg: &KernelConfig) -> u64 {
    match cfg {
        KernelConfig::Xgemm(p) => p.fingerprint(),
        KernelConfig::Direct(p) => p.fingerprint(),
        KernelConfig::HostSimd(p) => p.fingerprint(),
    }
}

/// The tuner's measurement interface: the objective function f_a(i).
pub trait Backend {
    /// Human-readable device name (goes into datasets / results).
    fn device_name(&self) -> String;

    /// GFLOP/s of `cfg` on `triple`; `None` when the config is illegal or
    /// unavailable on this backend.
    fn measure(&mut self, cfg: &KernelConfig, triple: Triple) -> Option<f64>;

    /// Candidate configurations for `triple` (the searchable space).
    fn candidates(&self, triple: Triple) -> Vec<KernelConfig>;

    /// Shared candidate list for the exhaustive hot path (§Perf: avoids
    /// cloning a multi-thousand-entry Vec once per triple).  Backends
    /// with a triple-independent space override this with an Arc clone.
    /// Backends may order this list best-first to maximize pruning.
    fn candidates_shared(&self, triple: Triple) -> Arc<Vec<KernelConfig>> {
        Arc::new(self.candidates(triple))
    }

    /// Admissible upper bound on `measure(cfg, triple)` when one can be
    /// computed cheaply: the tuner skips a candidate whose bound falls
    /// below the best measurement so far without changing the argmax.
    /// `None` disables pruning (default, and for real-hardware backends).
    fn measure_upper_bound(&self, _cfg: &KernelConfig, _triple: Triple) -> Option<f64> {
        None
    }
}

/// Simulated backend over an analytical device model.
pub struct SimBackend {
    pub profile: DeviceProfile,
    /// Legal configs sorted by descending static efficiency so the
    /// pruning bound kicks in as early as possible (§Perf).
    legal: Arc<Vec<KernelConfig>>,
    /// static_eff keyed by config fingerprint (cheaper to hash than the
    /// full 14-field struct on the pruning hot path).
    static_eff: std::collections::HashMap<u64, f64>,
}

impl std::fmt::Debug for SimBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SimBackend").finish_non_exhaustive()
    }
}

impl SimBackend {
    pub fn new(profile: DeviceProfile) -> Self {
        // Pre-filter device legality once: CLTune does the same with its
        // constraint system before launching any kernel.
        let mut legal: Vec<KernelConfig> = xgemm_space()
            .iter()
            .chain(direct_space().iter())
            .filter(|c| profile.is_legal(c))
            .collect();
        let static_eff: std::collections::HashMap<u64, f64> = legal
            .iter()
            .map(|c| (fingerprint(c), sim::static_eff(&profile, c)))
            .collect();
        legal.sort_by(|a, b| {
            static_eff[&fingerprint(b)]
                .partial_cmp(&static_eff[&fingerprint(a)])
                .unwrap()
        });
        SimBackend { profile, legal: Arc::new(legal), static_eff }
    }

    pub fn legal_count(&self) -> usize {
        self.legal.len()
    }
}

impl Backend for SimBackend {
    fn device_name(&self) -> String {
        self.profile.id.name().to_string()
    }

    fn measure(&mut self, cfg: &KernelConfig, triple: Triple) -> Option<f64> {
        sim::measure_gflops(&self.profile, cfg, triple)
    }

    fn candidates(&self, _triple: Triple) -> Vec<KernelConfig> {
        (*self.legal).clone()
    }

    fn candidates_shared(&self, _triple: Triple) -> Arc<Vec<KernelConfig>> {
        Arc::clone(&self.legal)
    }

    fn measure_upper_bound(&self, cfg: &KernelConfig, triple: Triple) -> Option<f64> {
        let eff = *self.static_eff.get(&fingerprint(cfg))?;
        Some(sim::upper_bound_gflops(&self.profile, cfg, triple, eff))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_backend_precomputes_legal_space() {
        let b = SimBackend::new(DeviceProfile::nvidia_p100());
        assert!(b.legal_count() > 100);
        let total = xgemm_space().raw_size() + direct_space().raw_size();
        assert!((b.legal_count() as u64) < total);
    }

    #[test]
    fn measure_matches_sim() {
        let mut b = SimBackend::new(DeviceProfile::mali_t860());
        let t = Triple::new(256, 256, 256);
        let cfg = b.candidates(t)[0];
        assert_eq!(
            b.measure(&cfg, t),
            sim::measure_gflops(&b.profile, &cfg, t)
        );
    }
}
