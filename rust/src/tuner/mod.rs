//! The tuner — CLTune's role in the paper: search the configuration space
//! per input triple, record the best, and label datasets for training.

pub mod anneal;
mod backend;
mod db;
pub mod roster;

pub use anneal::{anneal, AnnealParams};
pub use backend::{Backend, SimBackend};
pub use db::TuningDb;
pub use roster::{measured_roster, roster_to_json, BucketRoster, SweepSample};

use crate::config::{DirectParams, KernelConfig, Triple, XgemmParams};
use crate::dataset::{ClassTable, Dataset, LabeledDataset};
use crate::util::prng::Rng;

/// Search strategy over the candidate space.
#[derive(Debug, Clone, Copy)]
pub enum SearchStrategy {
    /// Evaluate every legal candidate (the paper's choice: "we explore the
    /// entire search space ... avoiding perturbations due to sampling").
    Exhaustive,
    /// Evaluate a random subset of the candidates (the paper's suggested
    /// quality/time trade-off; used by the ablation bench).
    RandomSample { count: usize, seed: u64 },
}

/// The tuner: searches a backend's candidate space per triple.
#[derive(Debug, Clone, Copy)]
pub struct Tuner {
    pub strategy: SearchStrategy,
}

impl Default for Tuner {
    fn default() -> Self {
        Tuner { strategy: SearchStrategy::Exhaustive }
    }
}

impl Tuner {
    pub fn new(strategy: SearchStrategy) -> Self {
        Tuner { strategy }
    }

    /// Best (config, GFLOP/s) for one triple, or `None` if nothing is
    /// measurable (empty candidate set).
    pub fn tune_triple<B: Backend + ?Sized>(
        &self,
        backend: &mut B,
        triple: Triple,
    ) -> Option<(KernelConfig, f64)> {
        // Exhaustive search iterates the shared (Arc) candidate list —
        // no per-triple clone of a multi-thousand-entry Vec (§Perf).
        let shared = backend.candidates_shared(triple);
        let sampled: Option<Vec<KernelConfig>> =
            if let SearchStrategy::RandomSample { count, seed } = self.strategy {
                let mut candidates = (*shared).clone();
                let mut rng = Rng::new(
                    seed ^ (triple.m as u64) << 32
                        ^ (triple.n as u64) << 16
                        ^ triple.k as u64,
                );
                rng.shuffle(&mut candidates);
                candidates.truncate(count.max(1));
                Some(candidates)
            } else {
                None
            };
        let iter: &[KernelConfig] = sampled.as_deref().unwrap_or(&shared);
        let mut best: Option<(KernelConfig, f64)> = None;
        for cfg in iter {
            // Sound pruning: skip candidates whose admissible upper bound
            // cannot beat the best measurement so far (§Perf).
            if let (Some((_, bg)), Some(ub)) =
                (best, backend.measure_upper_bound(cfg, triple))
            {
                if ub <= bg {
                    continue;
                }
            }
            if let Some(g) = backend.measure(cfg, triple) {
                match best {
                    Some((_, bg)) if bg >= g => {}
                    _ => best = Some((*cfg, g)),
                }
            }
        }
        best
    }

    /// Tune every triple of a dataset, producing the labeled dataset
    /// D = {(I, C)} and filling the tuning database (the peak oracle).
    pub fn label_dataset<B: Backend + ?Sized>(
        &self,
        backend: &mut B,
        dataset: &Dataset,
        db: &mut TuningDb,
    ) -> LabeledDataset {
        let mut classes = ClassTable::new();
        let mut entries = Vec::with_capacity(dataset.len());
        for &t in &dataset.triples {
            if let Some((cfg, g)) = self.tune_triple(backend, t) {
                db.insert(t, cfg, g);
                entries.push((t, classes.intern(cfg)));
            }
        }
        LabeledDataset {
            kind: dataset.kind,
            device: backend.device_name(),
            entries,
            classes,
        }
    }
}

/// CLBlast's *default* (non-adaptive) behaviour — the paper's baseline:
/// one configuration per kernel, tuned for the default matrix size
/// (M=N=K=1024 for xgemm, 256 for xgemm_direct), then selected at run
/// time by a threshold ("linear cut") on the operand sizes.
#[derive(Debug, Clone, Copy)]
pub struct TunedDefault {
    pub xgemm: KernelConfig,
    pub direct: KernelConfig,
    pub threshold_geo: f64,
}

impl TunedDefault {
    /// Tune the two default configurations on a backend, exactly as
    /// CLBlast ships: per-device, at the default sizes.
    pub fn tune<B: Backend + ?Sized>(backend: &mut B) -> TunedDefault {
        let tuner = Tuner::default();
        let at = |backend: &mut B, t: Triple, kind: crate::config::KernelKind| {
            let mut best: Option<(KernelConfig, f64)> = None;
            for cfg in backend.candidates(t) {
                if cfg.kind() != kind {
                    continue;
                }
                if let Some(g) = backend.measure(&cfg, t) {
                    match best {
                        Some((_, bg)) if bg >= g => {}
                        _ => best = Some((cfg, g)),
                    }
                }
            }
            best.map(|(c, _)| c)
        };
        let _ = &tuner;
        let xgemm = at(
            backend,
            Triple::new(1024, 1024, 1024),
            crate::config::KernelKind::Xgemm,
        )
        .unwrap_or(KernelConfig::Xgemm(XgemmParams::default()));
        let direct = at(
            backend,
            Triple::new(256, 256, 256),
            crate::config::KernelKind::XgemmDirect,
        )
        .unwrap_or(KernelConfig::Direct(DirectParams::default()));
        TunedDefault { xgemm, direct, threshold_geo: 384.0 }
    }

    /// The run-time threshold selection.
    pub fn select(&self, triple: Triple) -> KernelConfig {
        let geo = (triple.m as f64 * triple.n as f64 * triple.k as f64).cbrt();
        if geo < self.threshold_geo {
            self.direct
        } else {
            self.xgemm
        }
    }
}

/// Shorthand: the untuned fallback default (used where no backend exists).
pub fn clblast_default(triple: Triple) -> KernelConfig {
    TunedDefault {
        xgemm: KernelConfig::Xgemm(XgemmParams::default()),
        direct: KernelConfig::Direct(DirectParams::default()),
        threshold_geo: 384.0,
    }
    .select(triple)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::device::DeviceProfile;

    #[test]
    fn tune_triple_finds_positive_best() {
        let mut b = SimBackend::new(DeviceProfile::nvidia_p100());
        let (cfg, g) = Tuner::default()
            .tune_triple(&mut b, Triple::new(256, 256, 256))
            .unwrap();
        assert!(g > 0.0);
        assert!(b.profile.is_legal(&cfg));
    }

    #[test]
    fn random_sample_no_better_than_exhaustive() {
        let mut b = SimBackend::new(DeviceProfile::mali_t860());
        let t = Triple::new(512, 512, 512);
        let (_, g_ex) = Tuner::default().tune_triple(&mut b, t).unwrap();
        let (_, g_rs) = Tuner::new(SearchStrategy::RandomSample {
            count: 50,
            seed: 1,
        })
        .tune_triple(&mut b, t)
        .unwrap();
        assert!(g_rs <= g_ex + 1e-9, "sampled {g_rs} > exhaustive {g_ex}");
    }

    #[test]
    fn label_dataset_covers_all_triples() {
        let mut b = SimBackend::new(DeviceProfile::nvidia_p100());
        let ds = Dataset::generate(DatasetKind::Po2);
        let mut db = TuningDb::new(b.device_name());
        let labeled = Tuner::default().label_dataset(&mut b, &ds, &mut db);
        assert_eq!(labeled.len(), ds.len());
        assert_eq!(db.len(), ds.len());
        assert!(labeled.classes.len() > 1, "po2 should need >1 config");
        // Every label points at a valid class.
        assert!(labeled
            .entries
            .iter()
            .all(|(_, c)| (*c as usize) < labeled.classes.len()));
    }

    #[test]
    fn default_policy_switches_on_size() {
        assert!(matches!(
            clblast_default(Triple::new(64, 64, 64)),
            KernelConfig::Direct(_)
        ));
        assert!(matches!(
            clblast_default(Triple::new(1024, 1024, 1024)),
            KernelConfig::Xgemm(_)
        ));
    }
}
