//! Deterministic exhaustive-interleaving scheduler for model checking.
//!
//! This is a loom-style model checker, scaled to the needs of this
//! repo's lock-free serving path and the offline build constraint (no
//! external deps).  A test body runs many times; each run is driven by
//! a *schedule* — a sequence of decisions about which thread runs at
//! each scheduling point.  Scheduling points are injected by the
//! modeled primitives below ([`AtomicU64`], [`Mutex`], ...), which the
//! production code picks up through the `crate::util::sync` facade when
//! built with `--features model-check`.
//!
//! Exploration is a depth-first search over schedules: the first run
//! always picks the lowest-numbered runnable thread, and each
//! subsequent run flips the last decision that still has an untried
//! alternative.  Preemptions (switching away from a thread that could
//! have kept running) are bounded by [`Config::max_preemptions`], which
//! keeps the search space polynomial while still catching almost all
//! real interleaving bugs (most require only 1–2 preemptions).
//!
//! Failing schedules are reported as a dotted decision string (e.g.
//! `"0.1.0.2"`) that can be fed back through [`Config::replay`] to
//! deterministically reproduce the failure under a debugger.
//!
//! Mechanics: model threads are real OS threads, but a baton protocol
//! (mutex + condvar) guarantees exactly one runs at a time, so every
//! modeled operation is sequentially consistent and the decision trace
//! fully determines the execution.  Threads blocked on a modeled mutex
//! are parked in the scheduler (not spinning) and re-enabled on unlock;
//! a state with live threads and nothing runnable is reported as a
//! deadlock.  After a failure the scheduler aborts the run: every
//! thread panics with a private sentinel at its next scheduling point,
//! and those unwinds are swallowed so the report carries only the
//! original failure.

use std::any::Any;
use std::cell::RefCell;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, LockResult, Once, PoisonError, TryLockError};
use std::sync::{Mutex as StdMutex, MutexGuard as StdMutexGuard};

const SC: Ordering = Ordering::SeqCst;

// ---------------------------------------------------------------------------
// Scheduler core
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum ThreadState {
    Runnable,
    /// Parked until the resource (mutex address or join token) signals.
    Blocked(u64),
    Finished,
}

/// One decision point: how many options were enabled and which index
/// was taken.  The option list itself is recomputed deterministically
/// on replay, so only the counts need to be stored.
#[derive(Clone, Copy, Debug)]
struct Choice {
    options: usize,
    chosen: usize,
}

#[derive(Default)]
struct State {
    threads: Vec<ThreadState>,
    current: usize,
    /// Decision prefix to replay; past its end the DFS default (index
    /// 0) is taken.
    replay: Vec<usize>,
    /// Decisions actually taken this run.
    trace: Vec<Choice>,
    preemptions: usize,
    abort: bool,
    failure: Option<String>,
    done: bool,
    /// Model threads not yet finished.
    live: usize,
}

struct Scheduler {
    mu: StdMutex<State>,
    cv: Condvar,
    max_preemptions: usize,
}

/// Sentinel panic payload used to tear threads down after a failure.
struct ModelAbort;

fn model_abort() -> ! {
    std::panic::panic_any(ModelAbort)
}

#[derive(Clone)]
struct Ctx {
    sched: Arc<Scheduler>,
    id: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

fn current_ctx() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

/// Scheduling hook for modeled primitives: a no-op outside a model run
/// (so the modeled types degrade to plain sequentially-consistent std
/// types), a yield point inside one.
fn hook() {
    if let Some(ctx) = current_ctx() {
        ctx.sched.yield_point(ctx.id);
    }
}

/// Join tokens live at the top of the resource space; mutex resources
/// are heap addresses and cannot reach them.
fn join_resource(id: usize) -> u64 {
    u64::MAX - id as u64
}

impl Scheduler {
    fn lock_state(&self) -> StdMutexGuard<'_, State> {
        self.mu.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Pick the next thread to run.  `voluntary` marks switches forced
    /// by the current thread blocking or finishing; only a switch away
    /// from a still-runnable thread counts against the preemption
    /// budget.  Must be called with the state lock held by the thread
    /// that currently owns the baton.
    fn pick_next(&self, s: &mut State, voluntary: bool) {
        let enabled: Vec<usize> = s
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, ThreadState::Runnable))
            .map(|(i, _)| i)
            .collect();
        if enabled.is_empty() {
            if s.live == 0 {
                s.done = true;
            } else if s.failure.is_none() {
                let blocked = s
                    .threads
                    .iter()
                    .filter(|t| matches!(t, ThreadState::Blocked(_)))
                    .count();
                s.failure = Some(format!(
                    "deadlock: {blocked} thread(s) blocked with none runnable"
                ));
                s.abort = true;
            } else {
                s.abort = true;
            }
            return;
        }
        let me = s.current;
        let me_runnable = enabled.contains(&me);
        let options = if !voluntary && me_runnable && s.preemptions >= self.max_preemptions {
            vec![me]
        } else {
            enabled
        };
        let pos = s.trace.len();
        let chosen = if pos < s.replay.len() {
            s.replay[pos].min(options.len() - 1)
        } else {
            0
        };
        s.trace.push(Choice { options: options.len(), chosen });
        let next = options[chosen];
        if !voluntary && me_runnable && next != me {
            s.preemptions += 1;
        }
        s.current = next;
    }

    /// Offer a context switch, then wait until scheduled again.
    fn yield_point(&self, me: usize) {
        let mut s = self.lock_state();
        if s.abort {
            drop(s);
            model_abort();
        }
        debug_assert_eq!(s.current, me, "yield from a thread without the baton");
        self.pick_next(&mut s, false);
        self.cv.notify_all();
        while !s.abort && s.current != me {
            s = self.cv.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        if s.abort {
            drop(s);
            model_abort();
        }
    }

    /// Park the current thread on `resource` until another thread
    /// signals it (mutex unlock / thread exit) and the scheduler picks
    /// it again.
    fn block_on(&self, me: usize, resource: u64) {
        let mut s = self.lock_state();
        if s.abort {
            drop(s);
            model_abort();
        }
        s.threads[me] = ThreadState::Blocked(resource);
        self.pick_next(&mut s, true);
        self.cv.notify_all();
        while !s.abort && s.current != me {
            s = self.cv.wait(s).unwrap_or_else(|e| e.into_inner());
        }
        if s.abort {
            drop(s);
            model_abort();
        }
    }

    /// Re-enable every thread parked on `resource`.
    fn unblock(&self, resource: u64) {
        let mut s = self.lock_state();
        for t in s.threads.iter_mut() {
            if *t == ThreadState::Blocked(resource) {
                *t = ThreadState::Runnable;
            }
        }
    }

    fn record_failure(&self, msg: String) {
        let mut s = self.lock_state();
        if s.failure.is_none() {
            s.failure = Some(msg);
        }
        s.abort = true;
        self.cv.notify_all();
    }

    fn finish_thread(&self, id: usize) {
        let mut s = self.lock_state();
        s.threads[id] = ThreadState::Finished;
        s.live -= 1;
        let join = join_resource(id);
        for t in s.threads.iter_mut() {
            if *t == ThreadState::Blocked(join) {
                *t = ThreadState::Runnable;
            }
        }
        if s.live == 0 {
            s.done = true;
        } else if s.current == id && !s.abort {
            self.pick_next(&mut s, true);
        }
        self.cv.notify_all();
    }
}

fn payload_to_string(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "model thread panicked".to_string()
    }
}

/// Silence the default panic hook for threads inside a model run: the
/// DFS *expects* to drive assertions into failures and the teardown
/// sentinel unwinds through every live thread, neither of which should
/// spam stderr.  Panics outside a model run keep the default hook.
fn install_quiet_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let in_model = CTX.with(|c| c.borrow().is_some());
            if !in_model {
                prev(info);
            }
        }));
    });
}

// ---------------------------------------------------------------------------
// Thread spawn/join inside a model run
// ---------------------------------------------------------------------------

/// Handle to a model thread created by [`spawn`].
pub struct JoinHandle<T> {
    id: usize,
    os: Option<std::thread::JoinHandle<Option<T>>>,
    sched: Arc<Scheduler>,
}

impl<T> fmt::Debug for JoinHandle<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("JoinHandle").field("id", &self.id).finish_non_exhaustive()
    }
}

impl<T> JoinHandle<T> {
    /// Wait (through the scheduler) for the thread to finish.  Returns
    /// `None` if the thread was torn down by an abort before producing
    /// a value.
    pub fn join(mut self) -> Option<T> {
        let ctx = current_ctx().expect("interleave::JoinHandle::join outside a model run");
        loop {
            let finished = {
                let s = self.sched.lock_state();
                if s.abort {
                    drop(s);
                    model_abort();
                }
                matches!(s.threads[self.id], ThreadState::Finished)
            };
            if finished {
                break;
            }
            self.sched.block_on(ctx.id, join_resource(self.id));
        }
        let os = self.os.take().expect("join called twice");
        os.join().ok().flatten()
    }
}

/// Spawn a model thread.  Panics if called outside [`explore`].
pub fn spawn<T, F>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let ctx = current_ctx().expect("interleave::spawn outside a model run");
    let sched = Arc::clone(&ctx.sched);
    let id = {
        let mut s = sched.lock_state();
        s.threads.push(ThreadState::Runnable);
        s.live += 1;
        s.threads.len() - 1
    };
    let child_sched = Arc::clone(&sched);
    let os = std::thread::spawn(move || {
        CTX.with(|c| {
            *c.borrow_mut() = Some(Ctx { sched: Arc::clone(&child_sched), id });
        });
        // Do not run a single instruction of the closure until the
        // scheduler hands this thread the baton.
        {
            let mut s = child_sched.lock_state();
            while !s.abort && s.current != id {
                s = child_sched.cv.wait(s).unwrap_or_else(|e| e.into_inner());
            }
            let aborted = s.abort;
            drop(s);
            if aborted {
                child_sched.finish_thread(id);
                return None;
            }
        }
        let out = match catch_unwind(AssertUnwindSafe(f)) {
            Ok(v) => Some(v),
            Err(payload) => {
                if !payload.is::<ModelAbort>() {
                    child_sched.record_failure(payload_to_string(&*payload));
                }
                None
            }
        };
        child_sched.finish_thread(id);
        out
    });
    // Scheduling point right after the spawn so the child can be
    // interleaved against the rest of the parent immediately.
    sched.yield_point(ctx.id);
    JoinHandle { id, os: Some(os), sched }
}

// ---------------------------------------------------------------------------
// Exploration driver
// ---------------------------------------------------------------------------

/// Exploration parameters.
#[derive(Clone, Debug)]
pub struct Config {
    /// Budget of involuntary context switches per schedule.  2 catches
    /// the overwhelming majority of real interleaving bugs; raise it in
    /// the weekly full-depth sweep.
    pub max_preemptions: usize,
    /// Stop after this many schedules (0 = exhaustive).  A truncated
    /// run is reported via [`Report::truncated`].
    pub max_schedules: usize,
    /// Replay a single failing schedule (the dotted string from
    /// [`Failure::schedule`]) instead of exploring.
    pub replay: Option<String>,
}

impl Default for Config {
    fn default() -> Self {
        Config { max_preemptions: 2, max_schedules: 0, replay: None }
    }
}

/// A schedule that violated an invariant, with its replay seed.
#[derive(Clone, Debug)]
pub struct Failure {
    /// Dotted decision string; feed through [`Config::replay`].
    pub schedule: String,
    /// Panic message of the failed assertion (or deadlock report).
    pub message: String,
}

/// Outcome of an [`explore`] call.
#[derive(Clone, Debug)]
pub struct Report {
    /// Number of distinct schedules executed.
    pub schedules: usize,
    /// True when `max_schedules` stopped the search before exhaustion.
    pub truncated: bool,
    /// First failing schedule, if any.
    pub failure: Option<Failure>,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.failure.is_none()
    }
}

fn format_schedule(trace: &[Choice]) -> String {
    let parts: Vec<String> = trace.iter().map(|c| c.chosen.to_string()).collect();
    parts.join(".")
}

fn parse_schedule(s: &str) -> Vec<usize> {
    s.split('.').filter_map(|p| p.trim().parse::<usize>().ok()).collect()
}

/// Flip the deepest decision that still has an untried alternative;
/// `None` when the space is exhausted.
fn next_prefix(trace: &[Choice]) -> Option<Vec<usize>> {
    let mut i = trace.len();
    while i > 0 {
        i -= 1;
        if trace[i].chosen + 1 < trace[i].options {
            let mut prefix: Vec<usize> = trace[..i].iter().map(|c| c.chosen).collect();
            prefix.push(trace[i].chosen + 1);
            return Some(prefix);
        }
    }
    None
}

struct RunOutcome {
    trace: Vec<Choice>,
    failure: Option<String>,
}

fn run_once(sched: &Arc<Scheduler>, body: &Arc<dyn Fn() + Send + Sync>) -> RunOutcome {
    {
        let mut s = sched.lock_state();
        s.threads.clear();
        s.threads.push(ThreadState::Runnable);
        s.current = 0;
        s.trace.clear();
        s.preemptions = 0;
        s.abort = false;
        s.failure = None;
        s.done = false;
        s.live = 1;
    }
    let root_sched = Arc::clone(sched);
    let body = Arc::clone(body);
    let root = std::thread::spawn(move || {
        CTX.with(|c| {
            *c.borrow_mut() = Some(Ctx { sched: Arc::clone(&root_sched), id: 0 });
        });
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| body())) {
            if !payload.is::<ModelAbort>() {
                root_sched.record_failure(payload_to_string(&*payload));
            }
        }
        root_sched.finish_thread(0);
    });
    {
        let mut s = sched.lock_state();
        while !s.done {
            s = sched.cv.wait(s).unwrap_or_else(|e| e.into_inner());
        }
    }
    let _ = root.join();
    let s = sched.lock_state();
    RunOutcome { trace: s.trace.clone(), failure: s.failure.clone() }
}

/// Run `body` under every schedule within the configured bounds.  The
/// body is re-executed from scratch per schedule, so it must build its
/// own state and spawn its threads via [`spawn`].
pub fn explore<F>(cfg: Config, body: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    install_quiet_hook();
    let sched = Arc::new(Scheduler {
        mu: StdMutex::new(State::default()),
        cv: Condvar::new(),
        max_preemptions: cfg.max_preemptions,
    });
    let body: Arc<dyn Fn() + Send + Sync> = Arc::new(body);
    let mut prefix: Vec<usize> = match &cfg.replay {
        Some(s) => parse_schedule(s),
        None => Vec::new(),
    };
    let mut schedules = 0usize;
    loop {
        {
            let mut s = sched.lock_state();
            s.replay = std::mem::take(&mut prefix);
        }
        let out = run_once(&sched, &body);
        schedules += 1;
        if let Some(message) = out.failure {
            return Report {
                schedules,
                truncated: false,
                failure: Some(Failure { schedule: format_schedule(&out.trace), message }),
            };
        }
        if cfg.replay.is_some() {
            // Replay mode: a single deterministic run.
            return Report { schedules, truncated: false, failure: None };
        }
        match next_prefix(&out.trace) {
            Some(p) => prefix = p,
            None => return Report { schedules, truncated: false, failure: None },
        }
        if cfg.max_schedules != 0 && schedules >= cfg.max_schedules {
            return Report { schedules, truncated: true, failure: None };
        }
    }
}

// ---------------------------------------------------------------------------
// Modeled primitives
// ---------------------------------------------------------------------------

macro_rules! modeled_int_atomic {
    ($name:ident, $std:ty, $ty:ty) => {
        /// Modeled atomic: every operation is a scheduling point inside
        /// a model run, a plain SeqCst std operation outside one.
        #[derive(Debug, Default)]
        pub struct $name {
            v: $std,
        }

        impl $name {
            pub const fn new(v: $ty) -> Self {
                Self { v: <$std>::new(v) }
            }

            pub fn load(&self, _o: Ordering) -> $ty {
                hook();
                self.v.load(SC)
            }

            pub fn store(&self, val: $ty, _o: Ordering) {
                hook();
                self.v.store(val, SC)
            }

            pub fn swap(&self, val: $ty, _o: Ordering) -> $ty {
                hook();
                self.v.swap(val, SC)
            }

            pub fn fetch_add(&self, val: $ty, _o: Ordering) -> $ty {
                hook();
                self.v.fetch_add(val, SC)
            }

            pub fn fetch_sub(&self, val: $ty, _o: Ordering) -> $ty {
                hook();
                self.v.fetch_sub(val, SC)
            }

            pub fn fetch_max(&self, val: $ty, _o: Ordering) -> $ty {
                hook();
                self.v.fetch_max(val, SC)
            }

            pub fn fetch_min(&self, val: $ty, _o: Ordering) -> $ty {
                hook();
                self.v.fetch_min(val, SC)
            }

            pub fn compare_exchange(
                &self,
                current: $ty,
                new: $ty,
                _s: Ordering,
                _f: Ordering,
            ) -> Result<$ty, $ty> {
                hook();
                self.v.compare_exchange(current, new, SC, SC)
            }

            pub fn compare_exchange_weak(
                &self,
                current: $ty,
                new: $ty,
                _s: Ordering,
                _f: Ordering,
            ) -> Result<$ty, $ty> {
                hook();
                // Strong inner CAS: spurious failure would make replay
                // nondeterministic.
                self.v.compare_exchange(current, new, SC, SC)
            }

            pub fn into_inner(self) -> $ty {
                self.v.into_inner()
            }
        }
    };
}

modeled_int_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);
modeled_int_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
modeled_int_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

/// Modeled atomic bool; see the integer atomics above.
#[derive(Debug, Default)]
pub struct AtomicBool {
    v: std::sync::atomic::AtomicBool,
}

impl AtomicBool {
    pub const fn new(v: bool) -> Self {
        Self { v: std::sync::atomic::AtomicBool::new(v) }
    }

    pub fn load(&self, _o: Ordering) -> bool {
        hook();
        self.v.load(SC)
    }

    pub fn store(&self, val: bool, _o: Ordering) {
        hook();
        self.v.store(val, SC)
    }

    pub fn swap(&self, val: bool, _o: Ordering) -> bool {
        hook();
        self.v.swap(val, SC)
    }

    pub fn compare_exchange(
        &self,
        current: bool,
        new: bool,
        _s: Ordering,
        _f: Ordering,
    ) -> Result<bool, bool> {
        hook();
        self.v.compare_exchange(current, new, SC, SC)
    }
}

/// Modeled mutex.  Lock contention parks the thread in the scheduler
/// (no spinning); unlock re-enables the waiters and yields so the
/// explorer can hand the lock to any of them.
#[derive(Debug, Default)]
pub struct Mutex<T: ?Sized> {
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Self {
        Mutex { inner: StdMutex::new(t) }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }
}

impl<T: ?Sized> Mutex<T> {
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let resource = self as *const Mutex<T> as *const () as u64;
        match current_ctx() {
            None => match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { inner: Some(g), resource }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    inner: Some(p.into_inner()),
                    resource,
                })),
            },
            Some(ctx) => loop {
                ctx.sched.yield_point(ctx.id);
                match self.inner.try_lock() {
                    Ok(g) => return Ok(MutexGuard { inner: Some(g), resource }),
                    Err(TryLockError::WouldBlock) => ctx.sched.block_on(ctx.id, resource),
                    Err(TryLockError::Poisoned(p)) => {
                        return Err(PoisonError::new(MutexGuard {
                            inner: Some(p.into_inner()),
                            resource,
                        }))
                    }
                }
            },
        }
    }
}

/// Guard for [`Mutex`]; releasing it wakes scheduler-parked waiters.
pub struct MutexGuard<'a, T: ?Sized + 'a> {
    inner: Option<StdMutexGuard<'a, T>>,
    resource: u64,
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for MutexGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

impl<T: ?Sized> Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard already released")
    }
}

impl<T: ?Sized> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard already released")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.inner.take());
        if let Some(ctx) = current_ctx() {
            ctx.sched.unblock(self.resource);
            // Yielding would panic on an aborted run; during an unwind
            // that would escalate to a process abort, so skip it — the
            // teardown no longer needs scheduling fairness.
            if !std::thread::panicking() {
                ctx.sched.yield_point(ctx.id);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Self-tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passthrough_outside_model_run() {
        let a = AtomicU64::new(1);
        assert_eq!(a.fetch_add(2, Ordering::SeqCst), 1);
        assert_eq!(a.load(Ordering::SeqCst), 3);
        let m = Mutex::new(5u32);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 6);
    }

    #[test]
    fn zero_preemptions_is_a_single_schedule() {
        let report = explore(
            Config { max_preemptions: 0, ..Config::default() },
            || {
                let a = Arc::new(AtomicU64::new(0));
                let t = {
                    let a = Arc::clone(&a);
                    spawn(move || a.fetch_add(1, SC))
                };
                a.fetch_add(1, SC);
                let _ = t.join();
                assert_eq!(a.load(SC), 2);
            },
        );
        assert!(report.ok(), "unexpected failure: {:?}", report.failure);
        assert_eq!(report.schedules, 1);
    }

    #[test]
    fn atomic_increment_is_clean_across_schedules() {
        let report = explore(Config::default(), || {
            let a = Arc::new(AtomicU64::new(0));
            let t = {
                let a = Arc::clone(&a);
                spawn(move || a.fetch_add(1, SC))
            };
            a.fetch_add(1, SC);
            let _ = t.join();
            assert_eq!(a.load(SC), 2);
        });
        assert!(report.ok(), "unexpected failure: {:?}", report.failure);
        assert!(report.schedules > 1, "explorer did not branch");
    }

    #[test]
    fn torn_read_modify_write_is_caught_and_replays() {
        // Classic lost update: load-then-store instead of fetch_add.
        let body = |a: Arc<AtomicU64>| {
            let v = a.load(SC);
            a.store(v + 1, SC);
        };
        let run = move || {
            let a = Arc::new(AtomicU64::new(0));
            let t = {
                let a = Arc::clone(&a);
                spawn(move || body(a))
            };
            body(Arc::clone(&a));
            let _ = t.join();
            assert_eq!(a.load(SC), 2, "lost update");
        };
        let report = explore(Config::default(), run);
        let failure = report.failure.expect("model checker missed the lost update");
        assert!(failure.message.contains("lost update"), "wrong failure: {failure:?}");
        assert!(!failure.schedule.is_empty());

        // The reported seed must reproduce the same failure in one run.
        let replayed = explore(
            Config { replay: Some(failure.schedule.clone()), ..Config::default() },
            run,
        );
        assert_eq!(replayed.schedules, 1);
        let rf = replayed.failure.expect("replay seed did not reproduce");
        assert!(rf.message.contains("lost update"));
    }

    #[test]
    fn mutex_protects_read_modify_write() {
        let report = explore(Config::default(), || {
            let m = Arc::new(Mutex::new(0u64));
            let t = {
                let m = Arc::clone(&m);
                spawn(move || {
                    let mut g = m.lock().unwrap();
                    *g += 1;
                })
            };
            {
                let mut g = m.lock().unwrap();
                *g += 1;
            }
            let _ = t.join();
            assert_eq!(*m.lock().unwrap(), 2);
        });
        assert!(report.ok(), "mutex run failed: {:?}", report.failure);
        assert!(report.schedules > 1);
    }

    #[test]
    fn max_schedules_truncates() {
        let report = explore(
            Config { max_schedules: 2, ..Config::default() },
            || {
                let a = Arc::new(AtomicU64::new(0));
                let t = {
                    let a = Arc::clone(&a);
                    spawn(move || a.fetch_add(1, SC))
                };
                a.fetch_add(1, SC);
                let _ = t.join();
            },
        );
        assert!(report.ok());
        assert!(report.truncated);
        assert_eq!(report.schedules, 2);
    }
}
