//! Testing utilities: proptest-lite (a minimal property-based testing
//! framework — no proptest crate offline: deterministic generation from
//! a seeded PRNG plus greedy shrinking) and shared test fixtures — the
//! sample manifest and the seeded workload-mix builder ([`MixSpec`])
//! the fusion, overload and fleet integration tests all draw from.
//!
//! [`interleave`] is the model-checking half: the deterministic
//! exhaustive-interleaving scheduler behind `--features model-check`.

pub mod interleave;

use std::time::Duration;

use crate::coordinator::GemmRequest;
use crate::device::DeviceId;
use crate::util::prng::Rng;

/// Deterministic request fixture: `a` all `fill`, `b` all ones, `c`
/// zero, `alpha = 1`, `beta = 0` — every element of a correctly served
/// result equals `fill * k`, so integration tests can assert
/// correctness without carrying an oracle around.
pub fn fill_request(m: usize, n: usize, k: usize, fill: f32) -> GemmRequest {
    GemmRequest {
        m,
        n,
        k,
        a: vec![fill; m * k],
        b: vec![1.0; k * n],
        c: vec![0.0; m * n],
        alpha: 1.0,
        beta: 0.0,
    }
}

/// One request of a seeded workload mix, with its routing/deadline
/// intent and its correctness oracle.
#[derive(Debug, Clone)]
pub struct MixRequest {
    pub req: GemmRequest,
    /// Fill value of the `a` operand (see [`fill_request`]).
    pub fill: f32,
    /// Device class to pin the request to (`None` = free-routed).
    pub device: Option<DeviceId>,
    /// Deadline to stamp at submit time, relative to the submit instant
    /// (`None` = no deadline).
    pub deadline_in: Option<Duration>,
}

impl MixRequest {
    /// Expected value of every element of a correctly served result.
    pub fn expected_element(&self) -> f32 {
        self.fill * self.req.k as f32
    }
}

/// Seeded deterministic workload-mix builder — shapes × devices ×
/// deadlines from one fixture, so fusion, overload and fleet tests stop
/// growing ad-hoc request builders.  Shapes are drawn by a seeded PRNG
/// (same seed → same mix); fills, devices and deadlines cycle by
/// request index.
#[derive(Debug, Clone)]
pub struct MixSpec {
    pub shapes: Vec<(usize, usize, usize)>,
    pub fills: Vec<f32>,
    pub devices: Vec<Option<DeviceId>>,
    pub deadlines: Vec<Option<Duration>>,
    pub seed: u64,
}

impl MixSpec {
    /// The classic integration mix: one exact-direct shape, two bucket
    /// shapes (one bucket-exact: the `m == mb` pad edge), one tiny
    /// irregular shape; free-routed, no deadlines, unit fill.
    pub fn new(seed: u64) -> MixSpec {
        MixSpec {
            shapes: vec![(64, 64, 64), (100, 100, 100), (128, 128, 128), (31, 31, 31)],
            fills: vec![1.0],
            devices: vec![None],
            deadlines: vec![None],
            seed,
        }
    }

    pub fn shapes(mut self, shapes: &[(usize, usize, usize)]) -> MixSpec {
        self.shapes = shapes.to_vec();
        self
    }

    pub fn fills(mut self, fills: &[f32]) -> MixSpec {
        self.fills = fills.to_vec();
        self
    }

    pub fn devices(mut self, devices: &[Option<DeviceId>]) -> MixSpec {
        self.devices = devices.to_vec();
        self
    }

    pub fn deadlines(mut self, deadlines: &[Option<Duration>]) -> MixSpec {
        self.deadlines = deadlines.to_vec();
        self
    }

    /// Build `n` deterministic requests.
    pub fn build(&self, n: usize) -> Vec<MixRequest> {
        assert!(!self.shapes.is_empty(), "mix needs at least one shape");
        assert!(!self.fills.is_empty(), "mix needs at least one fill");
        assert!(!self.devices.is_empty(), "mix needs a device entry (None = free)");
        assert!(!self.deadlines.is_empty(), "mix needs a deadline entry (None = off)");
        let mut rng = Rng::new(self.seed);
        (0..n)
            .map(|i| {
                let (m, nn, k) =
                    self.shapes[rng.below(self.shapes.len() as u64) as usize];
                let fill = self.fills[i % self.fills.len()];
                MixRequest {
                    req: fill_request(m, nn, k, fill),
                    fill,
                    device: self.devices[i % self.devices.len()],
                    deadline_in: self.deadlines[i % self.deadlines.len()],
                }
            })
            .collect()
    }
}

/// Shared three-artifact manifest fixture for engine / coordinator /
/// hetero test modules (one definition, so the legal/illegal split stays
/// consistent everywhere):
///
/// * `d1` — exact 64^3 direct artifact, legal on every device profile;
/// * `i1` — 128^3 bucket, 16x16 work-group (256 threads): legal
///   everywhere, exactly at the Mali-T860's work-group limit;
/// * `i2` — 256^3 bucket, 32x32 work-group (1024 threads): legal on the
///   host CPU and P100, **illegal on the Mali-T860** — the split the
///   fleet's device-legality tests exercise.
pub fn sample_manifest() -> crate::runtime::Manifest {
    const SAMPLE: &str = r#"{
 "version": 1, "roster": "small", "dtype": "f32",
 "artifacts": [
  {"name": "d1", "kernel": "xgemm_direct", "file": "d1.hlo.txt",
   "m": 64, "n": 64, "k": 64, "trans_a": false, "trans_b": false,
   "hlo_bytes": 10,
   "config": {"wgd": 32, "mdimcd": 8, "ndimcd": 8, "vwmd": 2, "vwnd": 2,
              "kwid": 2, "pada": 1, "padb": 1}},
  {"name": "i1", "kernel": "xgemm", "file": "i1.hlo.txt",
   "mb": 128, "nb": 128, "kb": 128, "hlo_bytes": 11,
   "config": {"mwg": 64, "nwg": 64, "kwg": 32, "mdimc": 16, "ndimc": 16,
              "vwm": 4, "vwn": 4, "sa": 1, "sb": 1}},
  {"name": "i2", "kernel": "xgemm", "file": "i2.hlo.txt",
   "mb": 256, "nb": 256, "kb": 256, "hlo_bytes": 12,
   "config": {"mwg": 128, "nwg": 128, "kwg": 32, "mdimc": 32, "ndimc": 32,
              "vwm": 2, "vwn": 2, "sa": 1, "sb": 1}}
 ]
}"#;
    crate::runtime::Manifest::parse(SAMPLE, std::path::Path::new("/tmp/fixture"))
        .expect("fixture manifest parses")
}

/// A generated-value strategy.
pub trait Strategy {
    type Value;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate shrinks, most aggressive first (default: none).
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform integer in an inclusive range.
pub struct RangeU32 {
    pub lo: u32,
    pub hi: u32,
}

impl std::fmt::Debug for RangeU32 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RangeU32").finish_non_exhaustive()
    }
}

impl Strategy for RangeU32 {
    type Value = u32;

    fn generate(&self, rng: &mut Rng) -> u32 {
        self.lo + rng.below((self.hi - self.lo + 1) as u64) as u32
    }

    fn shrink(&self, value: &u32) -> Vec<u32> {
        let mut out = Vec::new();
        if *value > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*value - self.lo) / 2);
            out.push(*value - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform choice from a fixed slice.
pub struct OneOf<T: Clone>(pub Vec<T>);

impl<T: Clone> std::fmt::Debug for OneOf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("OneOf").field(&self.0.len()).finish()
    }
}

impl<T: Clone> Strategy for OneOf<T> {
    type Value = T;

    fn generate(&self, rng: &mut Rng) -> T {
        self.0[rng.below(self.0.len() as u64) as usize].clone()
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<V> {
    Ok { cases: usize },
    Failed { minimal: V, cases: usize, message: String },
}

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 100, seed: 0xADA9_71B5, max_shrink_steps: 200 }
    }
}

/// Check `prop` over `cases` generated values; on failure, greedily
/// shrink.  Returns the (possibly shrunk) counterexample.
pub fn check<S: Strategy>(
    cfg: &PropConfig,
    strategy: &S,
    prop: impl Fn(&S::Value) -> Result<(), String>,
) -> PropResult<S::Value> {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = strategy.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in strategy.shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            return PropResult::Failed {
                minimal: best,
                cases: case + 1,
                message: best_msg,
            };
        }
    }
    PropResult::Ok { cases: cfg.cases }
}

/// Assert helper: panics with the minimal counterexample.
pub fn assert_prop<S: Strategy>(
    cfg: &PropConfig,
    strategy: &S,
    prop: impl Fn(&S::Value) -> Result<(), String>,
) where
    S::Value: std::fmt::Debug,
{
    match check(cfg, strategy, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { minimal, cases, message } => {
            panic!(
                "property failed after {cases} cases; \
                 minimal counterexample: {minimal:?}: {message}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let cfg = PropConfig::default();
        let s = RangeU32 { lo: 1, hi: 1000 };
        match check(&cfg, &s, |&x| {
            if x >= 1 {
                Ok(())
            } else {
                Err("x < 1".into())
            }
        }) {
            PropResult::Ok { cases } => assert_eq!(cases, cfg.cases),
            PropResult::Failed { .. } => panic!("should pass"),
        }
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let cfg = PropConfig::default();
        let s = RangeU32 { lo: 0, hi: 10_000 };
        // Fails for x >= 500; minimal counterexample should shrink near 500.
        match check(&cfg, &s, |&x| {
            if x < 500 {
                Ok(())
            } else {
                Err(format!("{x} >= 500"))
            }
        }) {
            PropResult::Failed { minimal, .. } => {
                assert!(minimal >= 500, "shrunk past the boundary: {minimal}");
                assert!(minimal <= 1000, "did not shrink: {minimal}");
            }
            PropResult::Ok { .. } => panic!("should fail"),
        }
    }

    #[test]
    fn one_of_generates_members() {
        let s = OneOf(vec!["a", "b", "c"]);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let v = s.generate(&mut rng);
            assert!(["a", "b", "c"].contains(&v));
        }
    }

    #[test]
    fn mix_builder_is_deterministic_and_cycles_fixture_axes() {
        let spec = MixSpec::new(7)
            .shapes(&[(8, 8, 8), (4, 4, 4)])
            .fills(&[0.5, 1.0])
            .devices(&[None, Some(crate::device::DeviceId::NvidiaP100)])
            .deadlines(&[None, Some(Duration::from_millis(5))]);
        let a = spec.build(8);
        let b = spec.build(8);
        assert_eq!(a.len(), 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.req.m, x.req.n, x.req.k), (y.req.m, y.req.n, y.req.k));
            assert_eq!(x.fill, y.fill);
            assert_eq!(x.device, y.device);
            assert_eq!(x.deadline_in, y.deadline_in);
        }
        // Axes cycle by index.
        assert_eq!(a[0].fill, 0.5);
        assert_eq!(a[1].fill, 1.0);
        assert_eq!(a[0].device, None);
        assert_eq!(a[1].device, Some(crate::device::DeviceId::NvidiaP100));
        assert_eq!(a[0].deadline_in, None);
        assert_eq!(a[1].deadline_in, Some(Duration::from_millis(5)));
        // A different seed draws a different shape sequence (32 draws
        // from two shapes: a whole-sequence collision is a 2^-32 event,
        // and the comparison is deterministic — pinned here).
        let long_a = spec.build(32);
        let long_b = MixSpec { seed: 8, ..spec.clone() }.build(32);
        assert!(long_a.iter().zip(&long_b).any(|(x, y)| x.req.m != y.req.m));
        // The oracle: every element of a served result must be fill * k.
        let r = &a[0];
        assert_eq!(r.expected_element(), 0.5 * r.req.k as f32);
        assert!(r.req.validate().is_ok());
    }

    #[test]
    fn fill_request_shapes_operands() {
        let r = fill_request(2, 3, 4, 0.25);
        assert_eq!((r.a.len(), r.b.len(), r.c.len()), (8, 12, 6));
        assert!(r.a.iter().all(|&x| x == 0.25));
        assert!(r.b.iter().all(|&x| x == 1.0));
        assert!(r.c.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let s = RangeU32 { lo: 0, hi: 1 << 30 };
        let gen = |seed| {
            let mut rng = Rng::new(seed);
            (0..10).map(|_| s.generate(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(gen(9), gen(9));
        assert_ne!(gen(9), gen(10));
    }
}
