//! Testing utilities: proptest-lite (a minimal property-based testing
//! framework — no proptest crate offline: deterministic generation from
//! a seeded PRNG plus greedy shrinking) and shared test fixtures.

use crate::util::prng::Rng;

/// Shared three-artifact manifest fixture for engine / coordinator /
/// hetero test modules (one definition, so the legal/illegal split stays
/// consistent everywhere):
///
/// * `d1` — exact 64^3 direct artifact, legal on every device profile;
/// * `i1` — 128^3 bucket, 16x16 work-group (256 threads): legal
///   everywhere, exactly at the Mali-T860's work-group limit;
/// * `i2` — 256^3 bucket, 32x32 work-group (1024 threads): legal on the
///   host CPU and P100, **illegal on the Mali-T860** — the split the
///   fleet's device-legality tests exercise.
pub fn sample_manifest() -> crate::runtime::Manifest {
    const SAMPLE: &str = r#"{
 "version": 1, "roster": "small", "dtype": "f32",
 "artifacts": [
  {"name": "d1", "kernel": "xgemm_direct", "file": "d1.hlo.txt",
   "m": 64, "n": 64, "k": 64, "trans_a": false, "trans_b": false,
   "hlo_bytes": 10,
   "config": {"wgd": 32, "mdimcd": 8, "ndimcd": 8, "vwmd": 2, "vwnd": 2,
              "kwid": 2, "pada": 1, "padb": 1}},
  {"name": "i1", "kernel": "xgemm", "file": "i1.hlo.txt",
   "mb": 128, "nb": 128, "kb": 128, "hlo_bytes": 11,
   "config": {"mwg": 64, "nwg": 64, "kwg": 32, "mdimc": 16, "ndimc": 16,
              "vwm": 4, "vwn": 4, "sa": 1, "sb": 1}},
  {"name": "i2", "kernel": "xgemm", "file": "i2.hlo.txt",
   "mb": 256, "nb": 256, "kb": 256, "hlo_bytes": 12,
   "config": {"mwg": 128, "nwg": 128, "kwg": 32, "mdimc": 32, "ndimc": 32,
              "vwm": 2, "vwn": 2, "sa": 1, "sb": 1}}
 ]
}"#;
    crate::runtime::Manifest::parse(SAMPLE, std::path::Path::new("/tmp/fixture"))
        .expect("fixture manifest parses")
}

/// A generated-value strategy.
pub trait Strategy {
    type Value;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate shrinks, most aggressive first (default: none).
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform integer in an inclusive range.
pub struct RangeU32 {
    pub lo: u32,
    pub hi: u32,
}

impl Strategy for RangeU32 {
    type Value = u32;

    fn generate(&self, rng: &mut Rng) -> u32 {
        self.lo + rng.below((self.hi - self.lo + 1) as u64) as u32
    }

    fn shrink(&self, value: &u32) -> Vec<u32> {
        let mut out = Vec::new();
        if *value > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*value - self.lo) / 2);
            out.push(*value - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform choice from a fixed slice.
pub struct OneOf<T: Clone>(pub Vec<T>);

impl<T: Clone> Strategy for OneOf<T> {
    type Value = T;

    fn generate(&self, rng: &mut Rng) -> T {
        self.0[rng.below(self.0.len() as u64) as usize].clone()
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<V> {
    Ok { cases: usize },
    Failed { minimal: V, cases: usize, message: String },
}

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 100, seed: 0xADA9_71B5, max_shrink_steps: 200 }
    }
}

/// Check `prop` over `cases` generated values; on failure, greedily
/// shrink.  Returns the (possibly shrunk) counterexample.
pub fn check<S: Strategy>(
    cfg: &PropConfig,
    strategy: &S,
    prop: impl Fn(&S::Value) -> Result<(), String>,
) -> PropResult<S::Value> {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = strategy.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in strategy.shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            return PropResult::Failed {
                minimal: best,
                cases: case + 1,
                message: best_msg,
            };
        }
    }
    PropResult::Ok { cases: cfg.cases }
}

/// Assert helper: panics with the minimal counterexample.
pub fn assert_prop<S: Strategy>(
    cfg: &PropConfig,
    strategy: &S,
    prop: impl Fn(&S::Value) -> Result<(), String>,
) where
    S::Value: std::fmt::Debug,
{
    match check(cfg, strategy, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { minimal, cases, message } => {
            panic!(
                "property failed after {cases} cases; \
                 minimal counterexample: {minimal:?}: {message}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let cfg = PropConfig::default();
        let s = RangeU32 { lo: 1, hi: 1000 };
        match check(&cfg, &s, |&x| {
            if x >= 1 {
                Ok(())
            } else {
                Err("x < 1".into())
            }
        }) {
            PropResult::Ok { cases } => assert_eq!(cases, cfg.cases),
            PropResult::Failed { .. } => panic!("should pass"),
        }
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let cfg = PropConfig::default();
        let s = RangeU32 { lo: 0, hi: 10_000 };
        // Fails for x >= 500; minimal counterexample should shrink near 500.
        match check(&cfg, &s, |&x| {
            if x < 500 {
                Ok(())
            } else {
                Err(format!("{x} >= 500"))
            }
        }) {
            PropResult::Failed { minimal, .. } => {
                assert!(minimal >= 500, "shrunk past the boundary: {minimal}");
                assert!(minimal <= 1000, "did not shrink: {minimal}");
            }
            PropResult::Ok { .. } => panic!("should fail"),
        }
    }

    #[test]
    fn one_of_generates_members() {
        let s = OneOf(vec!["a", "b", "c"]);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let v = s.generate(&mut rng);
            assert!(["a", "b", "c"].contains(&v));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let s = RangeU32 { lo: 0, hi: 1 << 30 };
        let gen = |seed| {
            let mut rng = Rng::new(seed);
            (0..10).map(|_| s.generate(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(gen(9), gen(9));
        assert_ne!(gen(9), gen(10));
    }
}
