//! Analytical GPU performance model — the substitute for running CLTune
//! on the paper's physical P100 / Mali-T860 (DESIGN.md §Substitutions).
//!
//! The model is a classic roofline with tile-level corrections:
//!
//! * compute time  = padded FLOPs / (peak · efficiency), where efficiency
//!   composes the kernel's reachable cap, a log-Gaussian tile-size match,
//!   a vector-width match and wave quantization over compute units;
//! * memory time   = tile-level DRAM traffic / bandwidth, with staging
//!   (SA/SB) either absorbing tile re-reads (devices with real local
//!   memory) or adding copy traffic (Midgard);
//! * the xgemm (indirect) kernel additionally pays the O(n^2) helper-pass
//!   cost (pad/transpose kernels) plus their launches — the paper's
//!   direct-vs-indirect trade-off;
//! * a deterministic hash-noise term models tuner measurement noise, so
//!   "re-running the tuner" reproduces identical tables.
//!
//! The constants are calibrated to reproduce the paper's *qualitative*
//! landscape (see DESIGN.md): on the P100 the direct kernel wins most
//! shapes (Table 3's class skew), on the Mali the indirect kernel wins
//! regular shapes while irregular AntonNet shapes split between both
//! (Table 4); dense datasets collapse to few unique best configs,
//! irregular ones fan out.

use super::DeviceProfile;
use crate::config::{DirectParams, HostParams, KernelConfig, Triple, XgemmParams};
use crate::util::prng::hash_noise;

/// Simulated tuner measurement: GFLOP/s of `cfg` on `triple`, or `None`
/// if the configuration is illegal on this device.
pub fn measure_gflops(
    dev: &DeviceProfile,
    cfg: &KernelConfig,
    triple: Triple,
) -> Option<f64> {
    if !dev.is_legal(cfg) {
        return None;
    }
    let seconds = match cfg {
        KernelConfig::Xgemm(p) => xgemm_time_s(dev, p, triple),
        KernelConfig::Direct(p) => direct_time_s(dev, p, triple),
        KernelConfig::HostSimd(p) => host_simd_time_s(dev, p, triple),
    };
    let useful_flops = triple.flops();
    let specialized = seconds / interaction(dev, cfg, triple);
    let noisy = specialized * (1.0 + noise(dev, cfg, triple));
    Some(useful_flops / noisy / 1e9)
}

/// Modeled wall-seconds of serving `triple` with `cfg` on `dev` — the
/// inverse view of [`measure_gflops`], shared by the `SimEngine` (which
/// charges this as the request's kernel time) and the fleet router's
/// device-choice prediction.  `None` when the config is illegal on the
/// device.
pub fn modeled_secs(
    dev: &DeviceProfile,
    cfg: &KernelConfig,
    triple: Triple,
) -> Option<f64> {
    measure_gflops(dev, cfg, triple).map(|g| triple.flops() / (g * 1e9))
}

/// Nominal per-dispatch launch seconds the model charges one kernel
/// dispatch of `cfg` on `dev`: the kernel launch itself, plus — for the
/// indirect kernel — the three helper-pass launches (pad A, pad B,
/// pad/unpad C).  This is the *amortizable* component of a fused batch:
/// a batch of `B` same-shape requests pays it once, so slots `1..B`
/// save it ([`crate::engine::ExecutionEngine::execute_batch_pooled`]
/// reports the modeled saving on analytical engines).
pub fn dispatch_overhead_secs(dev: &DeviceProfile, cfg: &KernelConfig) -> f64 {
    match cfg {
        KernelConfig::Xgemm(_) => 4.0 * dev.launch_us * 1e-6,
        KernelConfig::Direct(_) => dev.launch_us * 1e-6,
        // The host microkernel has one dispatch; its pad/unpad staging is
        // per-slot work a fused batch cannot amortize.
        KernelConfig::HostSimd(_) => dev.launch_us * 1e-6,
    }
}

/// Config-by-shape specialization: on a real GPU a configuration's
/// occupancy / cache / scheduling behaviour varies strongly and
/// non-monotonically with the problem region — the reason the paper's
/// single-config baselines achieve only ~0.4 of the tuner peak on average
/// (Table 5, h1 rows), while per-region winners sit near it.  Modeled as
/// a deterministic hash over (device, config, coarse log2 shape bucket):
/// regionally coherent (a CART split on M/N/K can learn the bucket
/// boundaries) but strongly config-specific.
fn interaction(dev: &DeviceProfile, cfg: &KernelConfig, t: Triple) -> f64 {
    let fp = match cfg {
        KernelConfig::Xgemm(p) => p.fingerprint(),
        KernelConfig::Direct(p) => p.fingerprint(),
        KernelConfig::HostSimd(p) => p.fingerprint(),
    };
    let dev_tag = dev.id.name().as_bytes().iter().map(|&b| b as u64).sum();
    // Value noise over log2 shape space (1.5-octave lattice, trilinearly
    // interpolated): nearby problem sizes behave similarly — which is why
    // the paper sometimes found one triple's best config performing well
    // on its neighbours (§5.2) — while distant regions decorrelate.
    const SCALE: f64 = 1.5;
    let coord = |x: u32| (x.max(1) as f64).log2() / SCALE;
    let (fm, fn_, fk) = (coord(t.m), coord(t.n), coord(t.k));
    let (im, in_, ik) = (fm.floor(), fn_.floor(), fk.floor());
    let (wm, wn, wk) = (fm - im, fn_ - in_, fk - ik);
    let mut u = 0.0;
    for (dm, dn, dk) in [
        (0u64, 0u64, 0u64), (0, 0, 1), (0, 1, 0), (0, 1, 1),
        (1, 0, 0), (1, 0, 1), (1, 1, 0), (1, 1, 1),
    ] {
        let corner = hash_noise(&[
            dev_tag,
            fp,
            im as u64 + dm,
            in_ as u64 + dn,
            ik as u64 + dk,
        ]);
        let w = (if dm == 1 { wm } else { 1.0 - wm })
            * (if dn == 1 { wn } else { 1.0 - wn })
            * (if dk == 1 { wk } else { 1.0 - wk });
        u += w * corner;
    }
    // Cliff response: most of the space is benign (a handful of globally
    // strong configs keep winning -> few unique classes, as in the dense
    // go2 dataset), but ~30% of (config, region) pairs fall off an
    // occupancy/cache cliff and crater — which is what makes
    // mispredictions expensive (the paper's h1 stumps score DTPR ~0.4).
    if u < 0.3 {
        0.35 + 0.5 * u // cliff: 0.35 .. 0.50
    } else {
        0.80 + 0.2857 * (u - 0.3) // benign: 0.80 .. 1.00
    }
}

/// Deterministic "measurement noise" in [-sigma, +sigma].
///
/// Two components: a *systematic* per-(device, config) bias (codegen /
/// scheduling quirks a real tuner measures consistently — the dominant
/// term, so the per-triple argmax stays regionally stable and datasets
/// don't explode into one-class-per-triple), plus a small per-triple
/// jitter (run-to-run variation).
fn noise(dev: &DeviceProfile, cfg: &KernelConfig, t: Triple) -> f64 {
    let fp = match cfg {
        KernelConfig::Xgemm(p) => p.fingerprint(),
        KernelConfig::Direct(p) => p.fingerprint(),
        KernelConfig::HostSimd(p) => p.fingerprint(),
    };
    let dev_tag = dev.id.name().as_bytes().iter().map(|&b| b as u64).sum();
    let u_cfg = hash_noise(&[dev_tag, fp]);
    let u_triple = hash_noise(&[dev_tag, fp, t.m as u64, t.n as u64, t.k as u64]);
    let bias = dev.noise_sigma * (2.0 * u_cfg - 1.0);
    let jitter = 0.35 * dev.noise_sigma * (2.0 * u_triple - 1.0);
    bias + jitter
}

fn ceil_to(x: u32, mult: u32) -> u64 {
    (x as u64).div_ceil(mult as u64) * mult as u64
}

/// Log-Gaussian efficiency of a tile edge vs the device's sweet spot.
/// Wide dynamic range: a badly mis-sized tile costs >2x (the paper's
/// DTPR landscape bottoms out near 0.4 — wrong configs hurt a lot).
fn tile_match(edge: f64, preferred: f64) -> f64 {
    let d = (edge.ln() - preferred.ln()) / std::f64::consts::LN_2; // in octaves
    (-0.5 * (d / 1.1) * (d / 1.1)).exp() * 0.62 + 0.38
}

/// Efficiency of a vector width vs the device's preferred width.
fn vw_match(vw: u32, preferred: u32) -> f64 {
    let d = (vw as f64).log2() - (preferred as f64).log2();
    1.0 - 0.16 * d.abs()
}

/// Wave quantization: utilization of `units` compute units by `groups`
/// independent work groups.
fn wave_utilization(groups: u64, units: u32) -> f64 {
    if groups == 0 {
        return 1.0;
    }
    let waves = groups.div_ceil(units as u64);
    let used = groups as f64 / (waves * units as u64) as f64;
    // Even a partially-filled device retains some efficiency floor.
    0.15 + 0.85 * used
}

/// Triple-independent compute-efficiency product of a configuration —
/// the expensive exp/ln/powf factors, reusable across every triple and
/// the basis of the tuner's admissible pruning bound (§Perf).
pub fn static_eff(dev: &DeviceProfile, cfg: &KernelConfig) -> f64 {
    match cfg {
        KernelConfig::Xgemm(p) => {
            let mut eff = dev.xgemm_eff_cap;
            eff *= tile_match(((p.mwg * p.nwg) as f64).sqrt(), dev.preferred_tile);
            eff *= vw_match(p.vwm, dev.preferred_vw) * vw_match(p.vwn, dev.preferred_vw);
            let per_thread = (p.mwi() * p.nwi()) as f64;
            if per_thread > 32.0 {
                eff *= (32.0 / per_thread).powf(1.3);
            }
            eff
        }
        KernelConfig::Direct(p) => {
            let mut eff = dev.direct_eff_cap;
            eff *= tile_match(p.wgd as f64, dev.preferred_tile);
            eff *= vw_match(p.vwmd, dev.preferred_vw) * vw_match(p.vwnd, dev.preferred_vw);
            eff *= match p.kwid {
                2 => 1.0,
                8 => 0.97,
                _ => 0.95,
            };
            eff
        }
        KernelConfig::HostSimd(p) => {
            // Host microkernel: lane parallelism dominates (sub-linear —
            // memory and issue width eat into perfect scaling); bigger
            // register tiles amortize loads up to the 8x8 accumulator
            // bound, and deeper unroll helps up to a point.
            let lanes = p.tier.lanes() as f64;
            let mut eff = 0.05 * lanes.powf(0.9);
            eff *= 0.85 + 0.15 * ((p.mr * p.nr) as f64 / 64.0);
            eff *= match p.ku {
                1 => 0.92,
                2 => 0.97,
                4 => 1.0,
                _ => 0.98,
            };
            // Packed panels make every inner-loop load unit-stride; the
            // benefit scales with how much strided traffic the tier's
            // vector loads were paying.  This is the *asymptotic* (deep
            // k) gain — `host_simd_time_s` rescales it down for shallow
            // k where few k-steps amortize each packed panel, keeping
            // the static value an admissible bound.
            if p.packed {
                eff *= packed_gain(p.tier);
            }
            eff
        }
    }
}

/// Admissible upper bound on `measure_gflops(dev, cfg, t)`: assumes the
/// best possible interaction (1.0), wave utilization (1.0), zero memory
/// and helper time, and maximal favourable noise.  Sound: the true
/// measurement never exceeds it, so the tuner may skip any config whose
/// bound falls below the best found so far without changing the argmax.
pub fn upper_bound_gflops(
    dev: &DeviceProfile,
    cfg: &KernelConfig,
    t: Triple,
    static_eff: f64,
) -> f64 {
    let (tm, tn, tk) = match cfg {
        KernelConfig::Xgemm(p) => (p.mwg, p.nwg, p.kwg),
        KernelConfig::Direct(p) => (p.wgd, p.wgd, p.wgd),
        KernelConfig::HostSimd(p) => (p.mr, p.nr, 1),
    };
    let (mp, np, kp) = (
        ceil_to(t.m, tm) as f64,
        ceil_to(t.n, tn) as f64,
        ceil_to(t.k, tk) as f64,
    );
    let padded = 2.0 * mp * np * kp;
    let mut t_min = padded / (dev.peak_gflops * 1e9 * static_eff);
    // Mandatory costs the real path always pays: kernel launch, and for
    // the indirect kernel the O(n^2) helper passes + their launches.
    t_min += dev.launch_us * 1e-6;
    if matches!(cfg, KernelConfig::Xgemm(_)) {
        let helper_bytes = 4.0 * 2.0 * (mp * kp + kp * np + 2.0 * mp * np);
        t_min += helper_bytes / (dev.mem_bw_gbps * 1e9) + 3.0 * dev.launch_us * 1e-6;
    }
    // The host microkernel also pays mandatory pad/unpad staging, but as
    // host copies — no helper launches (matching host_simd_time_s, so
    // the bound stays admissible).
    if matches!(cfg, KernelConfig::HostSimd(_)) {
        let helper_bytes = 4.0 * 2.0 * (mp * kp + kp * np + 2.0 * mp * np);
        t_min += helper_bytes / (dev.mem_bw_gbps * 1e9);
    }
    // noise >= -(1 + 0.35) * sigma.
    let noise_min = 1.0 - 1.35 * dev.noise_sigma;
    t.flops() / (t_min * noise_min) / 1e9
}

/// Seconds for the tiled (indirect) xgemm kernel, including helper passes.
fn xgemm_time_s(dev: &DeviceProfile, p: &XgemmParams, t: Triple) -> f64 {
    // Padded problem (the helper kernels pad to tile multiples).
    let mp = ceil_to(t.m, p.mwg);
    let np = ceil_to(t.n, p.nwg);
    let kp = ceil_to(t.k, p.kwg);
    let padded_flops = 2.0 * mp as f64 * np as f64 * kp as f64;

    // ---- compute ----  (static factors: cap, tile match, vector widths,
    // register spill — see static_eff)
    let mut eff = static_eff(dev, &KernelConfig::Xgemm(*p));
    let groups = (mp / p.mwg as u64) * (np / p.nwg as u64);
    eff *= wave_utilization(groups, dev.compute_units);
    let t_compute = padded_flops / (dev.peak_gflops * 1e9 * eff);

    // ---- memory ----
    // Each A tile is re-read once per N-tile column, B per M-tile row.
    let a_traffic = (mp * kp) as f64 * (np / p.nwg as u64) as f64;
    let b_traffic = (kp * np) as f64 * (mp / p.mwg as u64) as f64;
    let c_traffic = (mp * np) as f64;
    let stage_a = if p.sa == 1 { dev.stage_cost } else { dev.no_stage_penalty };
    let stage_b = if p.sb == 1 { dev.stage_cost } else { dev.no_stage_penalty };
    let bytes = 4.0 * (a_traffic * stage_a + b_traffic * stage_b + c_traffic);
    let t_mem = bytes / (dev.mem_bw_gbps * 1e9);

    // ---- helper kernels: pad A, pad B, pad/unpad C (read + write each) ----
    let helper_bytes =
        4.0 * 2.0 * ((mp * kp) as f64 + (kp * np) as f64 + 2.0 * (mp * np) as f64);
    let t_helpers =
        helper_bytes / (dev.mem_bw_gbps * 1e9) + 3.0 * dev.launch_us * 1e-6;

    t_compute.max(t_mem) + t_helpers + dev.launch_us * 1e-6
}

/// Seconds for the generic one-pass direct kernel.
fn direct_time_s(dev: &DeviceProfile, p: &DirectParams, t: Triple) -> f64 {
    let wgd = p.wgd;
    let mp = ceil_to(t.m, wgd);
    let np = ceil_to(t.n, wgd);
    let kp = ceil_to(t.k, wgd);
    let padded_flops = 2.0 * mp as f64 * np as f64 * kp as f64;

    // ---- compute ----  (static factors: cap, tile match, vector widths,
    // KWID unroll — see static_eff)
    let mut eff = static_eff(dev, &KernelConfig::Direct(*p));
    // PADA/PADB trade bounds checks for padded loads: unpadded access on an
    // unaligned problem costs extra predication (triple-dependent).
    let unaligned = t.m % wgd != 0 || t.n % wgd != 0 || t.k % wgd != 0;
    if unaligned {
        if p.pada == 0 {
            eff *= 0.93;
        }
        if p.padb == 0 {
            eff *= 0.93;
        }
    }
    let groups = (mp / wgd as u64) * (np / wgd as u64);
    eff *= wave_utilization(groups, dev.compute_units);
    let t_compute = padded_flops / (dev.peak_gflops * 1e9 * eff);

    // ---- memory ----  (small square tiles: re-reads scale with 1/wgd)
    let a_traffic = (mp * kp) as f64 * (np / wgd as u64) as f64;
    let b_traffic = (kp * np) as f64 * (mp / wgd as u64) as f64;
    let c_traffic = (mp * np) as f64;
    // The direct kernel always stages both operand tiles in local memory.
    let bytes = 4.0 * ((a_traffic + b_traffic) * dev.stage_cost + c_traffic);
    let t_mem = bytes / (dev.mem_bw_gbps * 1e9);

    t_compute.max(t_mem) + dev.launch_us * 1e-6
}

/// Asymptotic compute-efficiency multiplier of the packed layout per
/// tier: unit-stride panel loads replace strided B-column (and, for the
/// rank-1 packed kernels, strided A-row) access.  Wider vectors were
/// paying more for the strided loads, so they gain more.
fn packed_gain(tier: crate::config::SimdTier) -> f64 {
    match tier {
        crate::config::SimdTier::Scalar => 1.02,
        crate::config::SimdTier::Sse128 => 1.10,
        crate::config::SimdTier::Avx2Fma => 1.18,
    }
}

/// Seconds for a host SIMD microkernel variant: roofline over the
/// tile-padded problem, plus the mandatory pad/unpad staging the pooled
/// indirect path performs as host copies (no helper launches).
///
/// Packed variants (`p.packed`) model the real trade the executor makes:
/// an extra pack pass over A and B (strided gather, ~2x the streaming
/// byte cost) buys the unit-stride gain of `packed_gain`, amortized by
/// `kp/(kp+32)` — each packed panel element is reused once per k-step,
/// so skinny-k problems repay little of the pack.  Net effect: packing
/// *loses* at small k and *wins* at large k, the data-driven layout
/// choice the CART learns (`packed_crossover_in_k` pins both ends).
fn host_simd_time_s(dev: &DeviceProfile, p: &HostParams, t: Triple) -> f64 {
    let mp = ceil_to(t.m, p.mr);
    let np = ceil_to(t.n, p.nr);
    let kp = t.k.max(1) as u64;
    let padded_flops = 2.0 * mp as f64 * np as f64 * kp as f64;

    let mut eff = static_eff(dev, &KernelConfig::HostSimd(*p));
    let groups = (mp / p.mr as u64) * (np / p.nr as u64);
    eff *= wave_utilization(groups, dev.compute_units);
    if p.packed {
        // static_eff already holds the asymptotic gain; rescale to the
        // k-amortized fraction (<= 1, so the static bound stays sound).
        let gain = packed_gain(p.tier);
        let amort = kp as f64 / (kp as f64 + 32.0);
        eff *= (1.0 + (gain - 1.0) * amort) / gain;
    }
    let t_compute = padded_flops / (dev.peak_gflops * 1e9 * eff);

    // Streaming reads of A per column block, B per row block, C once.
    let a_traffic = (mp * kp) as f64 * (np / p.nr as u64) as f64;
    let b_traffic = (kp * np) as f64 * (mp / p.mr as u64) as f64;
    let c_traffic = (mp * np) as f64;
    // The L2/L3 absorbs most tile re-reads on a CPU.
    let bytes = 4.0 * (0.25 * (a_traffic + b_traffic) + c_traffic);
    let t_mem = bytes / (dev.mem_bw_gbps * 1e9);

    let helper_bytes =
        4.0 * 2.0 * ((mp * kp) as f64 + (kp * np) as f64 + 2.0 * (mp * np) as f64);
    let mut t_helpers = helper_bytes / (dev.mem_bw_gbps * 1e9);
    if p.packed {
        // Pack pass: read + write A and B panels once, at ~2x streaming
        // cost for the strided gather side.
        let pack_bytes = 4.0 * 2.0 * ((mp * kp) as f64 + (kp * np) as f64);
        t_helpers += 2.0 * pack_bytes / (dev.mem_bw_gbps * 1e9);
    }

    t_compute.max(t_mem) + t_helpers + dev.launch_us * 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{direct_space, xgemm_space};

    fn p100() -> DeviceProfile {
        DeviceProfile::nvidia_p100()
    }

    fn mali() -> DeviceProfile {
        DeviceProfile::mali_t860()
    }

    #[test]
    fn measurement_is_deterministic() {
        let dev = p100();
        let cfg = KernelConfig::Xgemm(XgemmParams::default());
        let t = Triple::new(1024, 1024, 1024);
        assert_eq!(
            measure_gflops(&dev, &cfg, t),
            measure_gflops(&dev, &cfg, t)
        );
    }

    #[test]
    fn illegal_config_measures_none() {
        let dev = mali();
        // workgroup 32*32 = 1024 > Mali's 256
        let cfg = KernelConfig::Xgemm(XgemmParams {
            mdimc: 32,
            ndimc: 32,
            mwg: 128,
            nwg: 128,
            ..Default::default()
        });
        assert!(measure_gflops(&dev, &cfg, Triple::new(256, 256, 256)).is_none());
    }

    #[test]
    fn gflops_below_peak() {
        for dev in [p100(), mali()] {
            for cfg in [
                KernelConfig::Xgemm(XgemmParams::default()),
                KernelConfig::Direct(DirectParams::default()),
            ] {
                let g = measure_gflops(&dev, &cfg, Triple::new(1024, 1024, 1024))
                    .unwrap();
                assert!(g > 0.0 && g < dev.peak_gflops, "{g} vs {}", dev.peak_gflops);
            }
        }
    }

    #[test]
    fn bigger_matrices_higher_throughput() {
        let dev = p100();
        let cfg = KernelConfig::Xgemm(XgemmParams::default());
        let small = measure_gflops(&dev, &cfg, Triple::new(128, 128, 128)).unwrap();
        let large = measure_gflops(&dev, &cfg, Triple::new(2048, 2048, 2048)).unwrap();
        assert!(large > small * 2.0, "large {large} vs small {small}");
    }

    #[test]
    fn direct_wins_small_irregular_on_p100() {
        // The paper's Table 3: on the P100 nearly all best configs are
        // xgemm_direct, driven by small/irregular AntonNet-style shapes.
        let dev = p100();
        let t = Triple::new(100, 50, 1); // K=1, 35% of AntonNet
        let best_direct = direct_space()
            .iter()
            .filter_map(|c| measure_gflops(&dev, &c, t))
            .fold(f64::MIN, f64::max);
        let best_xgemm = xgemm_space()
            .iter()
            .filter_map(|c| measure_gflops(&dev, &c, t))
            .fold(f64::MIN, f64::max);
        assert!(
            best_direct > best_xgemm,
            "direct {best_direct} !> xgemm {best_xgemm}"
        );
    }

    #[test]
    fn xgemm_wins_regular_on_mali() {
        // The paper's Table 4: on the Mali po2 dataset, 29 of 30 unique
        // best configs are xgemm.
        let dev = mali();
        let t = Triple::new(512, 512, 512);
        let best_direct = direct_space()
            .iter()
            .filter_map(|c| measure_gflops(&dev, &c, t))
            .fold(f64::MIN, f64::max);
        let best_xgemm = xgemm_space()
            .iter()
            .filter_map(|c| measure_gflops(&dev, &c, t))
            .fold(f64::MIN, f64::max);
        assert!(
            best_xgemm > best_direct,
            "xgemm {best_xgemm} !> direct {best_direct}"
        );
    }

    #[test]
    fn padding_waste_punishes_xgemm_on_tiny_k() {
        let dev = mali();
        let cfg = KernelConfig::Xgemm(XgemmParams::default()); // kwg = 32
        let k1 = measure_gflops(&dev, &cfg, Triple::new(256, 256, 1)).unwrap();
        let k32 = measure_gflops(&dev, &cfg, Triple::new(256, 256, 32)).unwrap();
        // Throughput counts *useful* flops: K=1 wastes 31/32 of the tile.
        assert!(k32 > 8.0 * k1, "k32 {k32} vs k1 {k1}");
    }

    #[test]
    fn modeled_secs_inverts_gflops() {
        let dev = p100();
        let cfg = KernelConfig::Xgemm(XgemmParams::default());
        let t = Triple::new(512, 384, 256);
        let g = measure_gflops(&dev, &cfg, t).unwrap();
        let s = modeled_secs(&dev, &cfg, t).unwrap();
        assert!((s * g * 1e9 - t.flops()).abs() < 1e-3 * t.flops());
        // Illegal on mali (workgroup too large) -> None on both views.
        let big = KernelConfig::Xgemm(XgemmParams {
            mdimc: 32,
            ndimc: 32,
            mwg: 128,
            nwg: 128,
            ..Default::default()
        });
        assert!(modeled_secs(&mali(), &big, t).is_none());
    }

    #[test]
    fn dispatch_overhead_counts_helper_launches() {
        let dev = p100();
        let xgemm = KernelConfig::Xgemm(XgemmParams::default());
        let direct = KernelConfig::Direct(DirectParams::default());
        let launch = dev.launch_us * 1e-6;
        assert_eq!(dispatch_overhead_secs(&dev, &direct), launch);
        // The indirect kernel's dispatch also pays its three helper-pass
        // launches — all amortizable across a fused batch.
        assert_eq!(dispatch_overhead_secs(&dev, &xgemm), 4.0 * launch);
        // On any non-trivial problem the overhead is a small fraction of
        // the modeled time: a fused slot's saving can never exceed what
        // the dispatch costs.
        let t = Triple::new(512, 512, 512);
        for cfg in [xgemm, direct] {
            let secs = modeled_secs(&dev, &cfg, t).unwrap();
            assert!(dispatch_overhead_secs(&dev, &cfg) < secs);
        }
    }

    #[test]
    fn host_simd_modeled_on_host_only_and_tier_ordered() {
        use crate::config::{host_variants, SimdTier};
        let host = DeviceProfile::host_cpu();
        let t = Triple::new(256, 256, 256);
        let vs = host_variants();
        let cfg_of = |tier: SimdTier| {
            KernelConfig::HostSimd(
                *vs.iter().find(|p| p.tier == tier).expect("tier in roster"),
            )
        };
        let g_scalar = measure_gflops(&host, &cfg_of(SimdTier::Scalar), t).unwrap();
        let g_sse = measure_gflops(&host, &cfg_of(SimdTier::Sse128), t).unwrap();
        let g_avx2 = measure_gflops(&host, &cfg_of(SimdTier::Avx2Fma), t).unwrap();
        assert!(
            g_avx2 > g_sse && g_sse > g_scalar,
            "tier ordering broken: {g_scalar} / {g_sse} / {g_avx2}"
        );
        // Host-only: the sim GPUs cannot model x86 SIMD.
        for dev in [p100(), mali()] {
            assert!(measure_gflops(&dev, &cfg_of(SimdTier::Avx2Fma), t).is_none());
        }
        // The admissible bound stays sound for the host family.
        for p in &vs {
            let cfg = KernelConfig::HostSimd(*p);
            let se = static_eff(&host, &cfg);
            let bound = upper_bound_gflops(&host, &cfg, t, se);
            let measured = measure_gflops(&host, &cfg, t).unwrap();
            assert!(bound >= measured, "{}: {bound} < {measured}", p.name());
        }
    }

    /// The packed layout's modeled trade crosses over in k: at skinny k
    /// the pack pass cannot amortize (packed strictly slower), at deep k
    /// the unit-stride gain dominates (packed strictly faster) — for
    /// every tier in the roster.  Tested on the raw time model (no
    /// interaction/noise terms) so the assertion is about the trade
    /// itself, not the stochastic landscape.
    #[test]
    fn packed_crossover_in_k() {
        use crate::config::{host_variants, HostParams, SimdTier};
        let host = DeviceProfile::host_cpu();
        let vs = host_variants();
        for tier in [SimdTier::Scalar, SimdTier::Sse128, SimdTier::Avx2Fma] {
            let unpacked = *vs
                .iter()
                .find(|p| p.tier == tier && !p.packed)
                .expect("unpacked variant in roster");
            let packed = HostParams { packed: true, ..unpacked };
            assert!(
                vs.contains(&packed),
                "roster is missing the packed twin of {}",
                unpacked.name()
            );
            let skinny = Triple::new(256, 256, 1);
            assert!(
                host_simd_time_s(&host, &packed, skinny)
                    > host_simd_time_s(&host, &unpacked, skinny),
                "{}: packing should lose at k=1",
                packed.name()
            );
            let deep = Triple::new(256, 256, 1024);
            assert!(
                host_simd_time_s(&host, &packed, deep)
                    < host_simd_time_s(&host, &unpacked, deep),
                "{}: packing should win at k=1024",
                packed.name()
            );
        }
    }

    #[test]
    fn noise_is_bounded() {
        let dev = mali();
        let cfg = KernelConfig::Direct(DirectParams::default());
        let t = Triple::new(777, 333, 111);
        let n = noise(&dev, &cfg, t);
        assert!(n.abs() <= dev.noise_sigma);
    }
}
