//! Cache-blocked SIMD host microkernels — the `host_simd` kernel family.
//!
//! Multi-versioned GEMM inner loops over the *padded* indirect buffers:
//! an AVX2+FMA tier, an SSE (portable-128) tier and the scalar reference,
//! each parameterized by microkernel tile (`mr` × `nr`) and K-loop unroll
//! (`ku`).  The serving tier is picked once per process by runtime
//! feature detection (`is_x86_feature_detected!`), overridable with
//! `ADAPTLIB_SIMD=scalar|sse|avx2` (always clamped to what the hardware
//! supports — the CI forced-fallback leg's lever).
//!
//! ## Bit-identity contract
//!
//! Every tier produces *bit-identical* output to the scalar reference
//! (the vendored PJRT `run_gemm`): each output element accumulates
//! `f64::from(a) * f64::from(b)` over `l` in increasing order into one
//! f64 chain, and the epilogue `alpha * acc as f32 + beta * c` runs in
//! f32.  The f32→f64 widening is exact and the product of two widened
//! f32s fits f64's mantissa exactly, so
//!
//! * SSE `mul_pd` + `add_pd` rounds exactly once per step (the product
//!   is exact), matching the scalar `acc + av * bv`;
//! * AVX2 `fmadd_pd`'s single rounding of `av * bv + acc` equals the
//!   two-step rounding when the product is exact;
//! * vectorizing across `j` keeps each element's own `l`-ordered chain;
//! * unrolling by `ku` only peels the same single chain — no split
//!   accumulators.
//!
//! Tier selection is therefore purely a performance decision, which is
//! what lets the CART treat variants as interchangeable classes.

use std::sync::OnceLock;

use crate::config::{HostParams, SimdTier, MAX_TILE};

const MAX: usize = MAX_TILE as usize;

/// The hardware's own capability tier (ignores the env override).
fn hardware_tier() -> SimdTier {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return SimdTier::Avx2Fma;
        }
        if is_x86_feature_detected!("sse2") {
            return SimdTier::Sse128;
        }
    }
    SimdTier::Scalar
}

/// The serving tier: hardware capability clamped by the
/// `ADAPTLIB_SIMD=scalar|sse|avx2` override.  Cached in a `OnceLock` so
/// the zero-alloc hot path (servability checks run per request) never
/// touches the environment again.
pub fn detected_tier() -> SimdTier {
    static TIER: OnceLock<SimdTier> = OnceLock::new();
    *TIER.get_or_init(|| {
        let hw = hardware_tier();
        match std::env::var("ADAPTLIB_SIMD") {
            Ok(v) => match SimdTier::from_name(v.trim()) {
                // The override can only *lower* the tier: forcing avx2 on
                // hardware without it would be undefined behaviour.
                Some(forced) => forced.min(hw),
                None => hw,
            },
            Err(_) => hw,
        }
    })
}

/// Whether a variant of tier `t` is executable on this host.
pub fn tier_supported(t: SimdTier) -> bool {
    t <= detected_tier()
}

/// Whether the packed-operand path is enabled for this process.
/// `ADAPTLIB_PACK=off|0|false` marks packed variants unservable and
/// degrades any packed config that still arrives down the unpacked
/// padded path (degrade-don't-fault) — the CI forced-unpacked leg's
/// lever, mirroring `ADAPTLIB_SIMD`.  Cached so per-request servability
/// checks never touch the environment.
pub fn pack_enabled() -> bool {
    static PACK: OnceLock<bool> = OnceLock::new();
    *PACK.get_or_init(|| match std::env::var("ADAPTLIB_PACK") {
        Ok(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "off" | "0" | "false"
        ),
        Err(_) => true,
    })
}

/// Length of A packed into `mr`-row panels: `ceil(m/mr)` panels of
/// `mr × k` each, zero-filled in the ragged rows of the last panel.
pub fn packed_a_len(m: usize, k: usize, mr: usize) -> usize {
    m.div_ceil(mr) * mr * k
}

/// Length of B packed into `nr`-column panels: `ceil(n/nr)` panels of
/// `k × nr` each, zero-filled in the ragged columns of the last panel.
pub fn packed_b_len(n: usize, k: usize, nr: usize) -> usize {
    n.div_ceil(nr) * nr * k
}

/// Pack row-major `a` (`m × k`) into row panels: panel `pi` holds rows
/// `pi*mr..pi*mr+mr`, stored l-major so the microkernel reads `mr`
/// adjacent A values per k-step — `pa[pi*mr*k + l*mr + ti] =
/// a[(pi*mr+ti)*k + l]`, zero for padded rows `ti >= tm`.  Fully
/// overwrites `pa` (resizing only on length change, so pooled callers
/// stay allocation-free in steady state).
pub fn pack_a_into(a: &[f32], m: usize, k: usize, mr: usize, pa: &mut Vec<f32>) {
    assert!((1..=MAX).contains(&mr), "mr out of range");
    assert_eq!(a.len(), m * k, "a size mismatch");
    let len = packed_a_len(m, k, mr);
    if pa.len() != len {
        pa.clear();
        pa.resize(len, 0.0);
    }
    for pi in 0..m.div_ceil(mr) {
        let i0 = pi * mr;
        let tm = (m - i0).min(mr);
        let base = pi * mr * k;
        for l in 0..k {
            let row = base + l * mr;
            for ti in 0..tm {
                pa[row + ti] = a[(i0 + ti) * k + l];
            }
            for ti in tm..mr {
                pa[row + ti] = 0.0;
            }
        }
    }
}

/// Pack row-major `b` (`k × n`) into column panels: panel `pj` holds
/// columns `pj*nr..pj*nr+nr`, stored l-major so the microkernel reads
/// `nr` adjacent B values per k-step — `pb[pj*k*nr + l*nr + tj] =
/// b[l*n + pj*nr+tj]`, zero for padded columns `tj >= tn`.  Fully
/// overwrites `pb` like `pack_a_into`.
pub fn pack_b_into(b: &[f32], k: usize, n: usize, nr: usize, pb: &mut Vec<f32>) {
    assert!((1..=MAX).contains(&nr), "nr out of range");
    assert_eq!(b.len(), k * n, "b size mismatch");
    let len = packed_b_len(n, k, nr);
    if pb.len() != len {
        pb.clear();
        pb.resize(len, 0.0);
    }
    for pj in 0..n.div_ceil(nr) {
        let j0 = pj * nr;
        let tn = (n - j0).min(nr);
        let base = pj * k * nr;
        for l in 0..k {
            let row = base + l * nr;
            pb[row..row + tn].copy_from_slice(&b[l * n + j0..l * n + j0 + tn]);
            for tj in tn..nr {
                pb[row + tj] = 0.0;
            }
        }
    }
}

/// GEMM over padded row-major buffers: `out[i*n+j] = alpha * Σ_l
/// a[i*k+l]·b[l*n+j] (f64 chain) + beta * c[i*n+j]`, dispatched to the
/// variant's tier clamped to the detected one.  Allocation-free: all
/// accumulators live on the stack.
#[allow(clippy::too_many_arguments)]
pub fn gemm_padded(
    p: &HostParams,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &[f32],
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    assert!(p.is_structurally_legal(), "illegal host variant {}", p.name());
    assert_eq!(a.len(), m * k, "a size mismatch");
    assert_eq!(b.len(), k * n, "b size mismatch");
    assert_eq!(c.len(), m * n, "c size mismatch");
    assert_eq!(out.len(), m * n, "out size mismatch");
    match p.tier.min(detected_tier()) {
        SimdTier::Scalar => block_scalar(p, m, n, k, a, b, c, alpha, beta, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the detected tier gates on is_x86_feature_detected!.
        SimdTier::Sse128 => unsafe {
            block_sse(p, m, n, k, a, b, c, alpha, beta, out)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — avx2+fma verified present at detection.
        SimdTier::Avx2Fma => unsafe {
            block_avx2(p, m, n, k, a, b, c, alpha, beta, out)
        },
        #[cfg(not(target_arch = "x86_64"))]
        _ => block_scalar(p, m, n, k, a, b, c, alpha, beta, out),
    }
}

/// The shared f32 epilogue — scalar in every tier (O(n²), and keeping it
/// scalar makes the bit-identity argument trivial there).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn epilogue(
    acc: &[[f64; MAX]; MAX],
    i0: usize,
    j0: usize,
    tm: usize,
    tn: usize,
    n: usize,
    c: &[f32],
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    for ti in 0..tm {
        let row = (i0 + ti) * n + j0;
        for tj in 0..tn {
            out[row + tj] = alpha * acc[ti][tj] as f32 + beta * c[row + tj];
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn block_scalar(
    p: &HostParams,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &[f32],
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    let (mr, nr, ku) = (p.mr as usize, p.nr as usize, p.ku as usize);
    let mut i0 = 0;
    while i0 < m {
        let tm = (m - i0).min(mr);
        let mut j0 = 0;
        while j0 < n {
            let tn = (n - j0).min(nr);
            let mut acc = [[0f64; MAX]; MAX];
            for ti in 0..tm {
                let arow = &a[(i0 + ti) * k..(i0 + ti) * k + k];
                let mut l = 0;
                while l + ku <= k {
                    for u in 0..ku {
                        let av = arow[l + u] as f64;
                        let brow = &b[(l + u) * n + j0..(l + u) * n + j0 + tn];
                        for tj in 0..tn {
                            acc[ti][tj] += av * brow[tj] as f64;
                        }
                    }
                    l += ku;
                }
                while l < k {
                    let av = arow[l] as f64;
                    let brow = &b[l * n + j0..l * n + j0 + tn];
                    for tj in 0..tn {
                        acc[ti][tj] += av * brow[tj] as f64;
                    }
                    l += 1;
                }
            }
            epilogue(&acc, i0, j0, tm, tn, n, c, alpha, beta, out);
            j0 += nr;
        }
        i0 += mr;
    }
}

/// SSE2 tier: 2 × f64 lanes.  `mul_pd` + `add_pd` — one rounding per
/// step since the widened product is exact, matching scalar bit-for-bit.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
#[allow(clippy::too_many_arguments)]
// SAFETY: `#[target_feature]` fn — callable only from the dispatchers
// below, which gate on the detected SIMD tier before entering.
unsafe fn block_sse(
    p: &HostParams,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &[f32],
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    // SAFETY: the dispatcher asserted the padded-tile layout (`m`/`n`/`k`
    // multiples of `mr`/`nr`/`ku`, operand slices exactly m*k / k*n / m*n),
    // so every `add`-offset pointer below stays inside its slice; SSE2 is
    // present per the target-feature gate.
    unsafe {
        use std::arch::x86_64::*;
        let (mr, nr, ku) = (p.mr as usize, p.nr as usize, p.ku as usize);
        let mut i0 = 0;
        while i0 < m {
            let tm = (m - i0).min(mr);
            let mut j0 = 0;
            while j0 < n {
                let tn = (n - j0).min(nr);
                let pairs = tn / 2;
                let mut acc = [[0f64; MAX]; MAX];
                for ti in 0..tm {
                    let arow = a.as_ptr().add((i0 + ti) * k);
                    let mut vacc = [_mm_setzero_pd(); MAX / 2];
                    let mut tail = [0f64; MAX];
                    // The ku-unrolled body peels the same single chain per
                    // element — the remainder loop repeats it verbatim.
                    let mut l = 0;
                    while l + ku <= k {
                        for u in 0..ku {
                            let av64 = *arow.add(l + u) as f64;
                            let av = _mm_set1_pd(av64);
                            let brow = b.as_ptr().add((l + u) * n + j0);
                            for (g, v) in vacc.iter_mut().take(pairs).enumerate() {
                                // 8-byte load of two adjacent f32s, widened.
                                let bv = _mm_cvtps_pd(_mm_castpd_ps(_mm_load_sd(
                                    brow.add(2 * g) as *const f64,
                                )));
                                *v = _mm_add_pd(*v, _mm_mul_pd(av, bv));
                            }
                            for (tj, t) in
                                tail.iter_mut().enumerate().take(tn).skip(pairs * 2)
                            {
                                *t += av64 * *brow.add(tj) as f64;
                            }
                        }
                        l += ku;
                    }
                    while l < k {
                        let av64 = *arow.add(l) as f64;
                        let av = _mm_set1_pd(av64);
                        let brow = b.as_ptr().add(l * n + j0);
                        for (g, v) in vacc.iter_mut().take(pairs).enumerate() {
                            let bv = _mm_cvtps_pd(_mm_castpd_ps(_mm_load_sd(
                                brow.add(2 * g) as *const f64,
                            )));
                            *v = _mm_add_pd(*v, _mm_mul_pd(av, bv));
                        }
                        for (tj, t) in
                            tail.iter_mut().enumerate().take(tn).skip(pairs * 2)
                        {
                            *t += av64 * *brow.add(tj) as f64;
                        }
                        l += 1;
                    }
                    for g in 0..pairs {
                        let mut lanes = [0f64; 2];
                        _mm_storeu_pd(lanes.as_mut_ptr(), vacc[g]);
                        acc[ti][2 * g] = lanes[0];
                        acc[ti][2 * g + 1] = lanes[1];
                    }
                    for tj in pairs * 2..tn {
                        acc[ti][tj] = tail[tj];
                    }
                }
                epilogue(&acc, i0, j0, tm, tn, n, c, alpha, beta, out);
                j0 += nr;
            }
            i0 += mr;
        }
    }
}

/// AVX2+FMA tier: 4 × f64 lanes, fused multiply-add.  The single FMA
/// rounding equals scalar's two-step rounding because the widened
/// product is exact.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
// SAFETY: `#[target_feature]` fn — callable only from the dispatchers
// below, which gate on the detected SIMD tier before entering.
unsafe fn block_avx2(
    p: &HostParams,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &[f32],
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    // SAFETY: same padded-tile layout contract as `block_sse` (asserted by
    // the dispatcher); AVX2+FMA are present per the target-feature gate, and
    // the unaligned load/store intrinsics carry no alignment requirement.
    unsafe {
        use std::arch::x86_64::*;
        let (mr, nr, ku) = (p.mr as usize, p.nr as usize, p.ku as usize);
        let mut i0 = 0;
        while i0 < m {
            let tm = (m - i0).min(mr);
            let mut j0 = 0;
            while j0 < n {
                let tn = (n - j0).min(nr);
                let quads = tn / 4;
                let mut acc = [[0f64; MAX]; MAX];
                for ti in 0..tm {
                    let arow = a.as_ptr().add((i0 + ti) * k);
                    let mut vacc = [_mm256_setzero_pd(); MAX / 4];
                    let mut tail = [0f64; MAX];
                    let mut l = 0;
                    while l + ku <= k {
                        for u in 0..ku {
                            let av64 = *arow.add(l + u) as f64;
                            let av = _mm256_set1_pd(av64);
                            let brow = b.as_ptr().add((l + u) * n + j0);
                            for (g, v) in vacc.iter_mut().take(quads).enumerate() {
                                // 16-byte load of four adjacent f32s, widened.
                                let bv =
                                    _mm256_cvtps_pd(_mm_loadu_ps(brow.add(4 * g)));
                                *v = _mm256_fmadd_pd(av, bv, *v);
                            }
                            for (tj, t) in
                                tail.iter_mut().enumerate().take(tn).skip(quads * 4)
                            {
                                *t += av64 * *brow.add(tj) as f64;
                            }
                        }
                        l += ku;
                    }
                    while l < k {
                        let av64 = *arow.add(l) as f64;
                        let av = _mm256_set1_pd(av64);
                        let brow = b.as_ptr().add(l * n + j0);
                        for (g, v) in vacc.iter_mut().take(quads).enumerate() {
                            let bv = _mm256_cvtps_pd(_mm_loadu_ps(brow.add(4 * g)));
                            *v = _mm256_fmadd_pd(av, bv, *v);
                        }
                        for (tj, t) in
                            tail.iter_mut().enumerate().take(tn).skip(quads * 4)
                        {
                            *t += av64 * *brow.add(tj) as f64;
                        }
                        l += 1;
                    }
                    for g in 0..quads {
                        let mut lanes = [0f64; 4];
                        _mm256_storeu_pd(lanes.as_mut_ptr(), vacc[g]);
                        for (o, &v) in lanes.iter().enumerate() {
                            acc[ti][4 * g + o] = v;
                        }
                    }
                    for tj in quads * 4..tn {
                        acc[ti][tj] = tail[tj];
                    }
                }
                epilogue(&acc, i0, j0, tm, tn, n, c, alpha, beta, out);
                j0 += nr;
            }
            i0 += mr;
        }
    }
}

/// GEMM over pre-packed panels (`pack_a_into` / `pack_b_into` layouts):
/// same contract as `gemm_padded`, but every inner-loop operand load is
/// unit-stride.  Bit-identical to the scalar reference: the packed
/// kernels run l-outer rank-1 updates, which reorders work *across*
/// output elements but leaves each element's own l-ordered f64 chain —
/// the thing rounding sees — untouched.  Padded panel lanes contribute
/// only to accumulator slots the epilogue never reads (`ti >= tm` rows
/// are skipped, `tj >= tn` lanes are discarded by the `tn` bound).
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed(
    p: &HostParams,
    m: usize,
    n: usize,
    k: usize,
    pa: &[f32],
    pb: &[f32],
    c: &[f32],
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    assert!(p.is_structurally_legal(), "illegal host variant {}", p.name());
    let (mr, nr) = (p.mr as usize, p.nr as usize);
    assert_eq!(pa.len(), packed_a_len(m, k, mr), "packed a size mismatch");
    assert_eq!(pb.len(), packed_b_len(n, k, nr), "packed b size mismatch");
    assert_eq!(c.len(), m * n, "c size mismatch");
    assert_eq!(out.len(), m * n, "out size mismatch");
    match p.tier.min(detected_tier()) {
        SimdTier::Scalar => packed_scalar(p, m, n, k, pa, pb, c, alpha, beta, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the detected tier gates on is_x86_feature_detected!.
        SimdTier::Sse128 => unsafe {
            packed_sse(p, m, n, k, pa, pb, c, alpha, beta, out)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — avx2+fma verified present at detection.
        SimdTier::Avx2Fma => unsafe {
            packed_avx2(p, m, n, k, pa, pb, c, alpha, beta, out)
        },
        #[cfg(not(target_arch = "x86_64"))]
        _ => packed_scalar(p, m, n, k, pa, pb, c, alpha, beta, out),
    }
}

#[allow(clippy::too_many_arguments)]
fn packed_scalar(
    p: &HostParams,
    m: usize,
    n: usize,
    k: usize,
    pa: &[f32],
    pb: &[f32],
    c: &[f32],
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    let (mr, nr, ku) = (p.mr as usize, p.nr as usize, p.ku as usize);
    let mut i0 = 0;
    while i0 < m {
        let tm = (m - i0).min(mr);
        let apan = &pa[(i0 / mr) * mr * k..][..mr * k];
        let mut j0 = 0;
        while j0 < n {
            let tn = (n - j0).min(nr);
            let bpan = &pb[(j0 / nr) * k * nr..][..k * nr];
            let mut acc = [[0f64; MAX]; MAX];
            // l-outer rank-1 updates: each k-step reads mr adjacent A
            // values and nr adjacent B values.  The ku-unrolled body
            // peels the same per-element chain; the remainder loop
            // repeats it verbatim.
            let mut l = 0;
            while l + ku <= k {
                for u in 0..ku {
                    let arow = &apan[(l + u) * mr..(l + u) * mr + mr];
                    let brow = &bpan[(l + u) * nr..(l + u) * nr + nr];
                    for (ti, accrow) in acc.iter_mut().enumerate().take(tm) {
                        let av = arow[ti] as f64;
                        for tj in 0..tn {
                            accrow[tj] += av * brow[tj] as f64;
                        }
                    }
                }
                l += ku;
            }
            while l < k {
                let arow = &apan[l * mr..l * mr + mr];
                let brow = &bpan[l * nr..l * nr + nr];
                for (ti, accrow) in acc.iter_mut().enumerate().take(tm) {
                    let av = arow[ti] as f64;
                    for tj in 0..tn {
                        accrow[tj] += av * brow[tj] as f64;
                    }
                }
                l += 1;
            }
            epilogue(&acc, i0, j0, tm, tn, n, c, alpha, beta, out);
            j0 += nr;
        }
        i0 += mr;
    }
}

/// SSE2 packed tier.  Vector lanes span the full `nr` panel width (the
/// pack zero-fill makes ragged-tile loads safe); lanes past `tn` land in
/// accumulator slots the epilogue discards.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
#[allow(clippy::too_many_arguments)]
// SAFETY: `#[target_feature]` fn — callable only from the dispatchers
// below, which gate on the detected SIMD tier before entering.
unsafe fn packed_sse(
    p: &HostParams,
    m: usize,
    n: usize,
    k: usize,
    pa: &[f32],
    pb: &[f32],
    c: &[f32],
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    // SAFETY: the packed dispatcher asserted `pa`/`pb` hold whole mr×kc /
    // kc×nr panels and `out` is exactly m*n, so the panel-pointer arithmetic
    // below stays inside those buffers; SSE2 is present per the gate.
    unsafe {
        use std::arch::x86_64::*;
        let (mr, nr, ku) = (p.mr as usize, p.nr as usize, p.ku as usize);
        let pairs = nr / 2;
        let mut i0 = 0;
        while i0 < m {
            let tm = (m - i0).min(mr);
            let apan = pa.as_ptr().add((i0 / mr) * mr * k);
            let mut j0 = 0;
            while j0 < n {
                let tn = (n - j0).min(nr);
                let bpan = pb.as_ptr().add((j0 / nr) * k * nr);
                let mut acc = [[0f64; MAX]; MAX];
                let mut vacc = [[_mm_setzero_pd(); MAX / 2]; MAX];
                let mut l = 0;
                while l + ku <= k {
                    for u in 0..ku {
                        let arow = apan.add((l + u) * mr);
                        let brow = bpan.add((l + u) * nr);
                        for ti in 0..tm {
                            let av64 = *arow.add(ti) as f64;
                            let av = _mm_set1_pd(av64);
                            for (g, v) in
                                vacc[ti].iter_mut().take(pairs).enumerate()
                            {
                                // 8-byte unit-stride load of two adjacent
                                // panel f32s, widened.
                                let bv = _mm_cvtps_pd(_mm_castpd_ps(_mm_load_sd(
                                    brow.add(2 * g) as *const f64,
                                )));
                                *v = _mm_add_pd(*v, _mm_mul_pd(av, bv));
                            }
                            for tj in pairs * 2..tn {
                                acc[ti][tj] += av64 * *brow.add(tj) as f64;
                            }
                        }
                    }
                    l += ku;
                }
                while l < k {
                    let arow = apan.add(l * mr);
                    let brow = bpan.add(l * nr);
                    for ti in 0..tm {
                        let av64 = *arow.add(ti) as f64;
                        let av = _mm_set1_pd(av64);
                        for (g, v) in vacc[ti].iter_mut().take(pairs).enumerate() {
                            let bv = _mm_cvtps_pd(_mm_castpd_ps(_mm_load_sd(
                                brow.add(2 * g) as *const f64,
                            )));
                            *v = _mm_add_pd(*v, _mm_mul_pd(av, bv));
                        }
                        for tj in pairs * 2..tn {
                            acc[ti][tj] += av64 * *brow.add(tj) as f64;
                        }
                    }
                    l += 1;
                }
                for (ti, accrow) in acc.iter_mut().enumerate().take(tm) {
                    for g in 0..pairs {
                        let mut lanes = [0f64; 2];
                        _mm_storeu_pd(lanes.as_mut_ptr(), vacc[ti][g]);
                        accrow[2 * g] = lanes[0];
                        accrow[2 * g + 1] = lanes[1];
                    }
                }
                epilogue(&acc, i0, j0, tm, tn, n, c, alpha, beta, out);
                j0 += nr;
            }
            i0 += mr;
        }
    }
}

/// AVX2+FMA packed tier — same full-panel-width lane policy as the SSE
/// packed kernel, with the single-rounding FMA equal to scalar's
/// two-step rounding because the widened product is exact.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
// SAFETY: `#[target_feature]` fn — callable only from the dispatchers
// below, which gate on the detected SIMD tier before entering.
unsafe fn packed_avx2(
    p: &HostParams,
    m: usize,
    n: usize,
    k: usize,
    pa: &[f32],
    pb: &[f32],
    c: &[f32],
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    // SAFETY: same packed-panel contract as `packed_sse` (asserted by the
    // dispatcher); AVX2+FMA are present per the target-feature gate, and the
    // unaligned intrinsics carry no alignment requirement.
    unsafe {
        use std::arch::x86_64::*;
        let (mr, nr, ku) = (p.mr as usize, p.nr as usize, p.ku as usize);
        let quads = nr / 4;
        let mut i0 = 0;
        while i0 < m {
            let tm = (m - i0).min(mr);
            let apan = pa.as_ptr().add((i0 / mr) * mr * k);
            let mut j0 = 0;
            while j0 < n {
                let tn = (n - j0).min(nr);
                let bpan = pb.as_ptr().add((j0 / nr) * k * nr);
                let mut acc = [[0f64; MAX]; MAX];
                let mut vacc = [[_mm256_setzero_pd(); MAX / 4]; MAX];
                let mut l = 0;
                while l + ku <= k {
                    for u in 0..ku {
                        let arow = apan.add((l + u) * mr);
                        let brow = bpan.add((l + u) * nr);
                        for ti in 0..tm {
                            let av64 = *arow.add(ti) as f64;
                            let av = _mm256_set1_pd(av64);
                            for (g, v) in
                                vacc[ti].iter_mut().take(quads).enumerate()
                            {
                                // 16-byte unit-stride load of four adjacent
                                // panel f32s, widened.
                                let bv =
                                    _mm256_cvtps_pd(_mm_loadu_ps(brow.add(4 * g)));
                                *v = _mm256_fmadd_pd(av, bv, *v);
                            }
                            for tj in quads * 4..tn {
                                acc[ti][tj] += av64 * *brow.add(tj) as f64;
                            }
                        }
                    }
                    l += ku;
                }
                while l < k {
                    let arow = apan.add(l * mr);
                    let brow = bpan.add(l * nr);
                    for ti in 0..tm {
                        let av64 = *arow.add(ti) as f64;
                        let av = _mm256_set1_pd(av64);
                        for (g, v) in vacc[ti].iter_mut().take(quads).enumerate() {
                            let bv = _mm256_cvtps_pd(_mm_loadu_ps(brow.add(4 * g)));
                            *v = _mm256_fmadd_pd(av, bv, *v);
                        }
                        for tj in quads * 4..tn {
                            acc[ti][tj] += av64 * *brow.add(tj) as f64;
                        }
                    }
                    l += 1;
                }
                for (ti, accrow) in acc.iter_mut().enumerate().take(tm) {
                    for g in 0..quads {
                        let mut lanes = [0f64; 4];
                        _mm256_storeu_pd(lanes.as_mut_ptr(), vacc[ti][g]);
                        for (o, &v) in lanes.iter().enumerate() {
                            accrow[4 * g + o] = v;
                        }
                    }
                }
                epilogue(&acc, i0, j0, tm, tn, n, c, alpha, beta, out);
                j0 += nr;
            }
            i0 += mr;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::host_variants;
    use crate::util::prng::Rng;

    /// Scalar reference with the vendored `run_gemm` accumulation order.
    #[allow(clippy::too_many_arguments)]
    fn reference(
        m: usize,
        n: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        c: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            let mut acc = vec![0f64; n];
            for l in 0..k {
                let av = a[i * k + l] as f64;
                for (j, s) in acc.iter_mut().enumerate() {
                    *s += av * b[l * n + j] as f64;
                }
            }
            for j in 0..n {
                out[i * n + j] = alpha * acc[j] as f32 + beta * c[i * n + j];
            }
        }
        out
    }

    fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn detection_is_stable_and_env_clamped() {
        let t = detected_tier();
        assert_eq!(t, detected_tier());
        assert!(tier_supported(SimdTier::Scalar));
        assert!(tier_supported(t));
    }

    #[test]
    fn pack_gate_is_stable() {
        assert_eq!(pack_enabled(), pack_enabled());
    }

    /// Pack roundtrip: every source element lands at its panel address,
    /// every ragged-tile slot is zero.  Shapes cover full tiles, mr/nr
    /// remainder tiles, the m==mb "already padded" edge (m a multiple of
    /// mr so no ragged panel), single-row/column extremes and the
    /// degenerate k=0.
    #[test]
    fn pack_roundtrip_addresses_and_zero_fill() {
        let mut rng = Rng::new(0x9AC4);
        for (m, n, k) in [
            (16, 16, 16), // full tiles for every roster mr/nr
            (13, 11, 9),  // remainder tiles on both axes
            (8, 8, 5),    // m==mb edge: multiples of mr/nr, ragged k only
            (1, 7, 5),    // single row, sub-tile n
            (5, 3, 0),    // degenerate k
        ] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            for (mr, nr) in [(8, 8), (4, 4), (4, 8), (3, 5), (1, 1)] {
                let mut pa = Vec::new();
                let mut pb = Vec::new();
                pack_a_into(&a, m, k, mr, &mut pa);
                pack_b_into(&b, k, n, nr, &mut pb);
                assert_eq!(pa.len(), packed_a_len(m, k, mr));
                assert_eq!(pb.len(), packed_b_len(n, k, nr));
                let mp = m.div_ceil(mr) * mr;
                let np = n.div_ceil(nr) * nr;
                for l in 0..k {
                    for i in 0..mp {
                        let got = pa[(i / mr) * mr * k + l * mr + (i % mr)];
                        let want = if i < m { a[i * k + l] } else { 0.0 };
                        assert_eq!(got.to_bits(), want.to_bits());
                    }
                    for j in 0..np {
                        let got = pb[(j / nr) * k * nr + l * nr + (j % nr)];
                        let want = if j < n { b[l * n + j] } else { 0.0 };
                        assert_eq!(got.to_bits(), want.to_bits());
                    }
                }
            }
        }
    }

    /// Pooled reuse: repacking a smaller problem into a dirty buffer
    /// must not leak stale panel content.
    #[test]
    fn pack_overwrites_stale_buffer_content() {
        let mut rng = Rng::new(0xFACE);
        let big_a = rand_vec(&mut rng, 16 * 12);
        let mut pa = Vec::new();
        pack_a_into(&big_a, 16, 12, 8, &mut pa);
        pa.iter_mut().for_each(|v| *v = f32::NAN);
        let small_a = rand_vec(&mut rng, 5 * 3);
        pack_a_into(&small_a, 5, 3, 4, &mut pa);
        assert_eq!(pa.len(), packed_a_len(5, 3, 4));
        assert!(pa.iter().all(|v| !v.is_nan()), "stale content leaked");

        let big_b = rand_vec(&mut rng, 12 * 16);
        let mut pb = Vec::new();
        pack_b_into(&big_b, 12, 16, 8, &mut pb);
        pb.iter_mut().for_each(|v| *v = f32::NAN);
        let small_b = rand_vec(&mut rng, 3 * 5);
        pack_b_into(&small_b, 3, 5, 4, &mut pb);
        assert_eq!(pb.len(), packed_b_len(5, 3, 4));
        assert!(pb.iter().all(|v| !v.is_nan()), "stale content leaked");
    }

    #[test]
    #[should_panic(expected = "a size mismatch")]
    fn pack_a_rejects_wrong_source_size() {
        pack_a_into(&[0.0; 7], 4, 2, 4, &mut Vec::new());
    }

    #[test]
    #[should_panic(expected = "b size mismatch")]
    fn pack_b_rejects_wrong_source_size() {
        pack_b_into(&[0.0; 7], 2, 4, 4, &mut Vec::new());
    }

    /// The packed kernels, fed freshly packed panels, are bit-identical
    /// to the reference chain for every roster variant at every
    /// executable tier — including the degenerate k=0 epilogue-only
    /// case.
    #[test]
    fn packed_kernels_bit_identical_to_reference() {
        let mut rng = Rng::new(0x51D1);
        for (m, n, k) in [
            (16, 16, 16),
            (8, 8, 8),
            (13, 11, 9),
            (1, 7, 5),
            (32, 16, 24),
            (6, 9, 0),
        ] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let c = rand_vec(&mut rng, m * n);
            let (alpha, beta) = (1.25f32, -0.5f32);
            let want = reference(m, n, k, &a, &b, &c, alpha, beta);
            let mut pa = Vec::new();
            let mut pb = Vec::new();
            let mut out = vec![0f32; m * n];
            for p in host_variants() {
                pack_a_into(&a, m, k, p.mr as usize, &mut pa);
                pack_b_into(&b, k, n, p.nr as usize, &mut pb);
                out.fill(f32::NAN);
                gemm_packed(&p, m, n, k, &pa, &pb, &c, alpha, beta, &mut out);
                assert_eq!(
                    out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{} packed diverges on {m}x{n}x{k}",
                    p.name(),
                );
            }
        }
    }

    /// Every variant, at every executable tier, bit-identical to the
    /// reference chain on shapes exercising full tiles, tile remainders
    /// and k-unroll remainders.
    #[test]
    fn all_variants_bit_identical_to_reference() {
        let mut rng = Rng::new(0x51D0);
        for (m, n, k) in
            [(16, 16, 16), (8, 8, 8), (13, 11, 9), (1, 7, 5), (32, 16, 24)]
        {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let c = rand_vec(&mut rng, m * n);
            let (alpha, beta) = (1.25f32, -0.5f32);
            let want = reference(m, n, k, &a, &b, &c, alpha, beta);
            let mut out = vec![0f32; m * n];
            for p in host_variants() {
                out.fill(f32::NAN);
                gemm_padded(&p, m, n, k, &a, &b, &c, alpha, beta, &mut out);
                assert_eq!(
                    out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{} diverges on {m}x{n}x{k}",
                    p.name(),
                );
            }
        }
    }
}
