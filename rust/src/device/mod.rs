//! Device models: profiles of the paper's two GPUs (Table 2) plus the
//! host CPU, device-level legality of tuning configurations, and the
//! analytical performance simulator that substitutes for the OpenCL
//! hardware we do not have (DESIGN.md §Substitutions).

pub mod microkernel;
pub mod sim;

use crate::config::KernelConfig;

/// Identifies a device profile (stable id used in datasets/results).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DeviceId {
    NvidiaP100,
    MaliT860,
    HostCpu,
}

impl DeviceId {
    pub fn name(&self) -> &'static str {
        match self {
            DeviceId::NvidiaP100 => "nvidia-p100",
            DeviceId::MaliT860 => "mali-t860",
            DeviceId::HostCpu => "host-cpu",
        }
    }

    pub fn parse(s: &str) -> Option<DeviceId> {
        match s {
            "nvidia-p100" | "p100" => Some(DeviceId::NvidiaP100),
            "mali-t860" | "mali" | "t860" => Some(DeviceId::MaliT860),
            "host-cpu" | "cpu" => Some(DeviceId::HostCpu),
            _ => None,
        }
    }

    /// Every device class, in fleet-default order (host first: it is the
    /// one real backend; the sim devices follow).
    pub fn all() -> [DeviceId; 3] {
        [DeviceId::HostCpu, DeviceId::NvidiaP100, DeviceId::MaliT860]
    }

    /// The accepted spellings, for flag help and parse errors.
    pub const VALID_NAMES: &'static str =
        "host-cpu|cpu, nvidia-p100|p100, mali-t860|mali|t860";

    /// Parse a CLI device flag — the single shared parse+error path: every
    /// `--device`/`--devices` flag goes through here so an unknown name
    /// always reports the full list of valid spellings.
    pub fn parse_flag(s: &str) -> anyhow::Result<DeviceId> {
        DeviceId::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown device '{s}' (valid: {})",
                DeviceId::VALID_NAMES
            )
        })
    }

    /// Parse a comma-separated device list (`host-cpu,p100,mali`),
    /// rejecting duplicates — the `--devices` fleet flag.
    pub fn parse_list(s: &str) -> anyhow::Result<Vec<DeviceId>> {
        let mut out = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let d = DeviceId::parse_flag(part)?;
            if out.contains(&d) {
                anyhow::bail!("device '{d}' listed twice");
            }
            out.push(d);
        }
        anyhow::ensure!(!out.is_empty(), "empty device list");
        Ok(out)
    }
}

impl std::fmt::Display for DeviceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A device profile: the Table 2 description plus the calibrated constants
/// the analytical performance model needs.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub id: DeviceId,
    // ------------------------------------------------ Table 2 description
    pub market_segment: &'static str,
    pub microarchitecture: &'static str,
    pub cores_desc: &'static str,
    pub boost_mhz: u32,
    pub peak_gflops: f64,
    pub memory_gb: f64,
    pub memory_type: &'static str,
    // ------------------------------------------------ model constants
    /// Sustained memory bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Number of parallel compute units (SMs / shader cores).
    pub compute_units: u32,
    /// Max work-group size (threads).
    pub max_workgroup: u32,
    /// Local-memory / VMEM budget per work-group, bytes.
    pub local_mem_bytes: u64,
    /// Kernel-launch overhead, microseconds.
    pub launch_us: f64,
    /// Preferred vector width (elements) — full-rate SIMD lanes.
    pub preferred_vw: u32,
    /// Preferred work-group tile edge (log-Gaussian efficiency peak).
    pub preferred_tile: f64,
    /// Peak fraction reachable by the *direct* kernel (bounds-checked
    /// generic code path; <1 everywhere, much lower on Mali).
    pub direct_eff_cap: f64,
    /// Peak fraction reachable by the tiled xgemm kernel.
    pub xgemm_eff_cap: f64,
    /// Memory-traffic multiplier for unstaged (SA/SB=0) tile re-reads —
    /// models cache quality; ~1.0 means the cache absorbs re-reads.
    pub no_stage_penalty: f64,
    /// Cost multiplier for staging through local memory where local memory
    /// is emulated (Midgard has none: staging copies through DRAM).
    pub stage_cost: f64,
    /// Relative measurement-noise sigma of the simulated tuner runs.
    pub noise_sigma: f64,
}

impl DeviceProfile {
    pub fn nvidia_p100() -> Self {
        DeviceProfile {
            id: DeviceId::NvidiaP100,
            market_segment: "Server",
            microarchitecture: "Pascal",
            cores_desc: "3584 CUDA cores (GP100)",
            boost_mhz: 1353,
            peak_gflops: 9700.0,
            memory_gb: 16.0,
            memory_type: "HBM2",
            mem_bw_gbps: 732.0,
            compute_units: 56,
            max_workgroup: 1024,
            local_mem_bytes: 48 * 1024,
            launch_us: 5.0,
            preferred_vw: 2,
            // Pascal's register file + scheduler favour modest tiles: the
            // direct kernel's 16-32 tiles sit near the sweet spot, which
            // is why the P100 runs xgemm_direct almost everywhere
            // (paper Table 3).
            preferred_tile: 48.0,
            direct_eff_cap: 0.88,
            xgemm_eff_cap: 0.95,
            no_stage_penalty: 1.35,
            stage_cost: 1.0,
            noise_sigma: 0.05,
        }
    }

    pub fn mali_t860() -> Self {
        DeviceProfile {
            id: DeviceId::MaliT860,
            market_segment: "System on Chip",
            microarchitecture: "Midgard 4th gen",
            cores_desc: "4 Mali cores",
            boost_mhz: 2000,
            peak_gflops: 23.8,
            memory_gb: 4.0,
            memory_type: "DDR3",
            mem_bw_gbps: 10.6,
            compute_units: 4,
            max_workgroup: 256,
            local_mem_bytes: 32 * 1024,
            launch_us: 40.0,
            preferred_vw: 4,
            preferred_tile: 32.0,
            direct_eff_cap: 0.55,
            xgemm_eff_cap: 0.85,
            // Midgard: no dedicated local memory — caches absorb re-reads
            // (no penalty for SA/SB=0) and staging *costs* extra traffic.
            no_stage_penalty: 1.0,
            stage_cost: 1.18,
            noise_sigma: 0.07,
        }
    }

    /// The host CPU running the real PJRT path (used for legality only;
    /// its performance is *measured*, never simulated).
    pub fn host_cpu() -> Self {
        DeviceProfile {
            id: DeviceId::HostCpu,
            market_segment: "Workstation",
            microarchitecture: "x86-64",
            cores_desc: "host cores (PJRT CPU client)",
            boost_mhz: 0,
            peak_gflops: 100.0,
            memory_gb: 16.0,
            memory_type: "DDR",
            mem_bw_gbps: 20.0,
            compute_units: 8,
            max_workgroup: 1024,
            // VMEM budget stands in for local memory on the Pallas path:
            // 16 MiB, the TPU VMEM size the kernels are structured for.
            local_mem_bytes: 16 * 1024 * 1024,
            launch_us: 20.0,
            preferred_vw: 4,
            preferred_tile: 64.0,
            direct_eff_cap: 0.7,
            xgemm_eff_cap: 0.9,
            no_stage_penalty: 1.1,
            stage_cost: 1.0,
            noise_sigma: 0.0,
        }
    }

    pub fn get(id: DeviceId) -> Self {
        match id {
            DeviceId::NvidiaP100 => Self::nvidia_p100(),
            DeviceId::MaliT860 => Self::mali_t860(),
            DeviceId::HostCpu => Self::host_cpu(),
        }
    }

    /// Device-level legality of a configuration (CLTune's constraint
    /// filtering: work-group limits + local-memory capacity).
    pub fn is_legal(&self, cfg: &KernelConfig) -> bool {
        if !cfg.is_structurally_legal() {
            return false;
        }
        if cfg.workgroup_size() > self.max_workgroup {
            return false;
        }
        match cfg {
            KernelConfig::Xgemm(p) => p.local_mem_bytes() <= self.local_mem_bytes,
            KernelConfig::Direct(p) => p.local_mem_bytes() <= self.local_mem_bytes,
            // The host microkernel family targets the CPU's own vector
            // units: only the host-CPU class can serve it (the simulated
            // GPUs model OpenCL kernels, not x86 SIMD).
            KernelConfig::HostSimd(_) => self.id == DeviceId::HostCpu,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{direct_space, xgemm_space};

    #[test]
    fn profiles_match_table2() {
        let p = DeviceProfile::nvidia_p100();
        assert_eq!(p.peak_gflops, 9700.0);
        assert_eq!(p.memory_type, "HBM2");
        let m = DeviceProfile::mali_t860();
        assert_eq!(m.peak_gflops, 23.8);
        assert_eq!(m.boost_mhz, 2000);
    }

    #[test]
    fn device_id_parse() {
        assert_eq!(DeviceId::parse("p100"), Some(DeviceId::NvidiaP100));
        assert_eq!(DeviceId::parse("mali-t860"), Some(DeviceId::MaliT860));
        assert_eq!(DeviceId::parse("t860"), Some(DeviceId::MaliT860));
        assert_eq!(DeviceId::parse("bogus"), None);
    }

    #[test]
    fn parse_flag_lists_valid_names_on_error() {
        assert_eq!(DeviceId::parse_flag("t860").unwrap(), DeviceId::MaliT860);
        let err = DeviceId::parse_flag("gtx480").unwrap_err().to_string();
        assert!(err.contains("gtx480"), "{err}");
        for name in ["host-cpu", "p100", "mali-t860", "t860"] {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }

    #[test]
    fn parse_list_rejects_duplicates_and_empties() {
        assert_eq!(
            DeviceId::parse_list("host-cpu, p100,mali").unwrap(),
            vec![DeviceId::HostCpu, DeviceId::NvidiaP100, DeviceId::MaliT860]
        );
        // Aliases of one device are duplicates.
        assert!(DeviceId::parse_list("mali,t860").is_err());
        assert!(DeviceId::parse_list("").is_err());
        assert!(DeviceId::parse_list("cpu,bogus").is_err());
    }

    #[test]
    fn legality_filters_more_on_mali() {
        let p100 = DeviceProfile::nvidia_p100();
        let mali = DeviceProfile::mali_t860();
        let space = xgemm_space();
        let n_p100 = space.iter().filter(|c| p100.is_legal(c)).count();
        let n_mali = space.iter().filter(|c| mali.is_legal(c)).count();
        assert!(n_mali < n_p100, "{n_mali} !< {n_p100}");
        assert!(n_mali > 0);
    }

    #[test]
    fn host_simd_legal_on_host_only() {
        for p in crate::config::host_variants() {
            let cfg = KernelConfig::HostSimd(p);
            assert!(DeviceProfile::host_cpu().is_legal(&cfg), "{}", cfg.name());
            assert!(!DeviceProfile::nvidia_p100().is_legal(&cfg));
            assert!(!DeviceProfile::mali_t860().is_legal(&cfg));
        }
    }

    #[test]
    fn direct_space_legal_on_all_devices() {
        for id in [DeviceId::NvidiaP100, DeviceId::MaliT860, DeviceId::HostCpu] {
            let dev = DeviceProfile::get(id);
            let n = direct_space().iter().filter(|c| dev.is_legal(c)).count();
            assert!(n > 0, "no legal direct configs on {id}");
        }
    }
}
