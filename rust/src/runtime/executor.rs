//! The PJRT executor: loads HLO-text artifacts, compiles them once on the
//! CPU PJRT client (cached densely by [`ArtifactId`]), and runs full BLAS
//! GEMMs — the on-line hot path of the adaptive library.  Python is never
//! involved here.
//!
//! Two execution paths:
//!
//! * [`GemmRuntime::gemm`] — by-name, literal-based (allocating; mirrors
//!   the xla-rs API and real host->device transfers).  Convenient for
//!   tools, tests and the off-line tuner.
//! * [`GemmRuntime::gemm_pooled`] — by-id into caller-held
//!   [`ScratchBuffers`]: no string hashing, no metadata clones, and zero
//!   heap allocations at steady state.  This is what the sharded
//!   coordinator serves requests through.

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{KernelConfig, Triple};
use crate::device::microkernel;

use super::manifest::{ArtifactId, ArtifactKind, Manifest};
use super::pad;

/// A GEMM request: row-major operands, full BLAS semantics.
#[derive(Debug, Clone)]
pub struct GemmInput<'a> {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a: &'a [f32],
    pub b: &'a [f32],
    pub c: &'a [f32],
    pub alpha: f32,
    pub beta: f32,
}

impl<'a> GemmInput<'a> {
    pub fn triple(&self) -> Triple {
        Triple::new(self.m as u32, self.n as u32, self.k as u32)
    }

    /// Operand-size validation (public so alternative execution engines
    /// can reuse the exact same contract the PJRT runtime enforces).
    pub fn validate(&self) -> Result<()> {
        if self.a.len() != self.m * self.k
            || self.b.len() != self.k * self.n
            || self.c.len() != self.m * self.n
        {
            bail!(
                "operand sizes do not match ({}, {}, {}): a={}, b={}, c={}",
                self.m,
                self.n,
                self.k,
                self.a.len(),
                self.b.len(),
                self.c.len()
            );
        }
        Ok(())
    }
}

/// A GEMM result with its timing breakdown.
#[derive(Debug, Clone)]
pub struct GemmOutput {
    pub out: Vec<f32>,
    /// Host-side helper time: pad/unpad plus literal staging (the §5.4
    /// cost model charges only device execute+transfer to kernel_time).
    pub helper_time: Duration,
    /// PJRT execute + transfer time.
    pub kernel_time: Duration,
}

impl GemmOutput {
    pub fn total_time(&self) -> Duration {
        self.helper_time + self.kernel_time
    }

    pub fn gflops(&self, t: Triple) -> f64 {
        t.flops() / self.total_time().as_secs_f64() / 1e9
    }
}

/// Timing of a pooled GEMM (the result lives in [`ScratchBuffers::out`]).
#[derive(Debug, Clone, Copy)]
pub struct GemmTimes {
    pub helper_time: Duration,
    pub kernel_time: Duration,
}

impl GemmTimes {
    pub fn total_time(&self) -> Duration {
        self.helper_time + self.kernel_time
    }
}

/// Reusable buffers for the pooled (allocation-free) serving path.
///
/// Ownership rules (see ARCHITECTURE.md): each worker thread owns exactly
/// one `ScratchBuffers`; the runtime only borrows it for the duration of a
/// `gemm_pooled` call; `out` holds the logical row-major result of the
/// *last* call and is valid until the next one.  At steady state (same
/// bucket sizes) every buffer reuses its capacity, so the indirect path
/// performs no heap allocation.
#[derive(Debug, Default)]
pub struct ScratchBuffers {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
    /// Panel-packed A/B for `packed` host variants: filled once per
    /// dispatch by `microkernel::pack_a_into`/`pack_b_into` from the
    /// padded `a`/`b`, capacity-reused at steady state like every other
    /// pool (the `simd_packed_pooled` counting-allocator gate).
    pack_a: Vec<f32>,
    pack_b: Vec<f32>,
    padded_out: Vec<f32>,
    /// Logical `m x n` result of the last pooled call.
    pub out: Vec<f32>,
}

impl ScratchBuffers {
    pub fn new() -> ScratchBuffers {
        ScratchBuffers::default()
    }

    /// Move the result out (leaves an empty buffer; the next pooled call
    /// re-grows it).  Use when the result must outlive the scratch.
    pub fn take_out(&mut self) -> Vec<f32> {
        std::mem::take(&mut self.out)
    }
}

/// Reusable buffers for the *fused* (batched) pooled path: one per
/// worker thread, like [`ScratchBuffers`].  A fused batch of `B`
/// same-`(artifact, m, n, k)` requests stages its operands into one
/// stacked, padded scratch region (slot `i` of operand X occupies
/// `[i * slot_len, (i + 1) * slot_len)` of `X`'s buffer — the layout a
/// real batched `[B, mb, kb]` kernel dispatch would consume), executes,
/// and unpads each slot into `out`.  At steady state (same artifact,
/// same shape, same batch size) every buffer reuses its capacity, so
/// the fused path performs **no heap allocation** — the `hotpath` bench
/// gates this (`allocs_per_request.fused_pooled`).
#[derive(Debug, Default)]
pub struct BatchScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
    /// Panel-packed A/B for `packed` host variants (one slot wide — A is
    /// repacked per slot; B is repacked only when a slot's raw operand
    /// differs from the previous slot's, amortizing the shared-B case).
    pack_a: Vec<f32>,
    pack_b: Vec<f32>,
    padded_out: Vec<f32>,
    /// Per-slot pool for the sequential fallback (engines without a
    /// native fused surface run `execute_pooled` per slot through this).
    pub seq: ScratchBuffers,
    /// Stacked logical `m x n` results, slot-major: slot `i` of the last
    /// batch lives at `[i * m * n, (i + 1) * m * n)` (see [`Self::slot`]).
    pub out: Vec<f32>,
    /// Per-slot §5.4 timing attribution.  Each slot's times describe
    /// that request *as if dispatched alone* (fusion amortization
    /// excluded), so telemetry samples stay comparable to the un-fused
    /// oracle measurements.
    pub times: Vec<GemmTimes>,
    /// Per-dispatch cost the fusion avoided across the whole batch:
    /// modeled on analytical engines (launch + helper-pass launches of
    /// every slot after the first), zero on the measured runtime path —
    /// there the savings are structural and show up as wall time.
    pub saved: Duration,
}

impl BatchScratch {
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }

    /// The logical `m x n` result of slot `i` of the last fused batch.
    pub fn slot(&self, i: usize, m: usize, n: usize) -> &[f32] {
        &self.out[i * m * n..(i + 1) * m * n]
    }
}

/// Resize a stacked staging buffer without the double-write a
/// `clear()`+`resize()` would cost: content is left stale — every slot
/// is fully overwritten by its staging pass.
fn resize_only(v: &mut Vec<f32>, len: usize) {
    if v.len() != len {
        v.clear();
        v.resize(len, 0f32);
    }
}

/// Loads and executes the AOT artifact roster.
pub struct GemmRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    /// Compiled executables, indexed densely by `ArtifactId`.
    cache: Vec<Option<xla::PjRtLoadedExecutable>>,
    /// Cumulative compile time (reported by `adaptd` diagnostics).
    pub compile_time: Duration,
}

impl std::fmt::Debug for GemmRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GemmRuntime").finish_non_exhaustive()
    }
}

impl GemmRuntime {
    /// Open the artifact directory (does not compile anything yet).
    pub fn open(dir: &Path) -> Result<GemmRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        let mut cache = Vec::new();
        cache.resize_with(manifest.len(), || None);
        Ok(GemmRuntime {
            client,
            manifest,
            cache,
            compile_time: Duration::ZERO,
        })
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.manifest.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        let id = self.resolve(name)?;
        self.ensure_compiled_id(id)
    }

    /// Reject ids that do not belong to this runtime's manifest (e.g. an
    /// id interned against a different or reloaded roster) — a graceful
    /// error instead of an index panic that would kill a shard thread.
    fn check_id(&self, id: ArtifactId) -> Result<()> {
        if (id.0 as usize) < self.manifest.len() {
            Ok(())
        } else {
            Err(anyhow!(
                "artifact id {} out of range for this roster ({} artifacts)",
                id.0,
                self.manifest.len()
            ))
        }
    }

    /// Compile (or fetch from cache) by dense id.
    pub fn ensure_compiled_id(&mut self, id: ArtifactId) -> Result<()> {
        self.check_id(id)?;
        // Host microkernel variants have no HLO: they dispatch straight to
        // `device::microkernel`, so there is nothing to compile (and the
        // bucket file they carry belongs to the PJRT base artifact).
        if matches!(self.manifest.meta(id).config, KernelConfig::HostSimd(_)) {
            return Ok(());
        }
        let idx = id.0 as usize;
        if self.cache[idx].is_some() {
            return Ok(());
        }
        let meta = self.manifest.meta(id);
        let name = meta.name.clone();
        let path = self.manifest.hlo_path(meta);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.compile_time += t0.elapsed();
        self.cache[idx] = Some(exe);
        Ok(())
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.iter().filter(|e| e.is_some()).count()
    }

    fn resolve(&self, name: &str) -> Result<ArtifactId> {
        self.manifest
            .id_of(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    /// Shape eligibility.  Direct artifacts with transposed operands are
    /// addressed by name/id (the serving router only routes untransposed
    /// requests), so the check here ignores the transpose flags.
    fn check_shape(&self, id: ArtifactId, input: &GemmInput) -> Result<()> {
        let meta = self.manifest.meta(id);
        let ok = match meta.kind {
            ArtifactKind::Direct { m, n, k, .. } => {
                (m, n, k) == (input.m as u32, input.n as u32, input.k as u32)
            }
            ArtifactKind::Indirect { .. } => meta.accepts(input.triple()),
        };
        if !ok {
            bail!("artifact '{}' does not accept {}", meta.name, input.triple());
        }
        Ok(())
    }

    fn exe(&self, id: ArtifactId) -> &xla::PjRtLoadedExecutable {
        self.cache[id.0 as usize]
            .as_ref()
            .expect("ensure_compiled_id preceded execution")
    }

    /// Execute a GEMM on a named artifact (allocating literal path).
    pub fn gemm(&mut self, name: &str, input: &GemmInput) -> Result<GemmOutput> {
        input.validate()?;
        let id = self.resolve(name)?;
        self.check_shape(id, input)?;
        self.ensure_compiled_id(id)?;
        // Microkernel variants share one execution path: delegate to the
        // pooled dispatch through a transient scratch (this entry point is
        // the allocating convenience surface — tools, tests, the tuner's
        // `measure` — so a fresh scratch per call is fine here).
        if matches!(self.manifest.meta(id).config, KernelConfig::HostSimd(_)) {
            let mut scratch = ScratchBuffers::new();
            let times = self.gemm_pooled(id, input, &mut scratch)?;
            return Ok(GemmOutput {
                out: scratch.take_out(),
                helper_time: times.helper_time,
                kernel_time: times.kernel_time,
            });
        }
        let kind = self.manifest.meta(id).kind;
        match kind {
            ArtifactKind::Direct { trans_a, trans_b, .. } => {
                self.run_direct(id, trans_a, trans_b, input)
            }
            ArtifactKind::Indirect { mb, nb, kb } => {
                self.run_indirect(id, input, mb as usize, nb as usize, kb as usize)
            }
        }
    }

    /// Execute a GEMM by dense id into caller-held scratch — the serving
    /// hot path: no string hashing, no metadata clone, zero steady-state
    /// heap allocations.  The result is left in `scratch.out`.
    // LINT: hot-path — per-request execute; zero steady-state allocations.
    pub fn gemm_pooled(
        &mut self,
        id: ArtifactId,
        input: &GemmInput,
        scratch: &mut ScratchBuffers,
    ) -> Result<GemmTimes> {
        input.validate()?;
        self.check_id(id)?;
        self.check_shape(id, input)?;
        self.ensure_compiled_id(id)?;
        let scalar_dims = [1i64];
        let kind = self.manifest.meta(id).kind;
        match kind {
            ArtifactKind::Direct { trans_a, trans_b, .. } => {
                let t0 = Instant::now();
                let (m, n, k) = (input.m as i64, input.n as i64, input.k as i64);
                let a_dims: [i64; 2] = if trans_a { [k, m] } else { [m, k] };
                let b_dims: [i64; 2] = if trans_b { [n, k] } else { [k, n] };
                let c_dims: [i64; 2] = [m, n];
                let ops = [
                    xla::RawOperand { data: input.a, dims: &a_dims },
                    xla::RawOperand { data: input.b, dims: &b_dims },
                    xla::RawOperand { data: input.c, dims: &c_dims },
                    xla::RawOperand {
                        data: std::slice::from_ref(&input.alpha),
                        dims: &scalar_dims,
                    },
                    xla::RawOperand {
                        data: std::slice::from_ref(&input.beta),
                        dims: &scalar_dims,
                    },
                ];
                self.exe(id)
                    .execute_into(&ops, &mut scratch.out)
                    .map_err(|e| {
                        anyhow!("executing {}: {e:?}", self.manifest.name_of(id))
                    })?;
                Ok(GemmTimes {
                    helper_time: Duration::ZERO,
                    kernel_time: t0.elapsed(),
                })
            }
            ArtifactKind::Indirect { mb, nb, kb } => {
                let (mb, nb, kb) = (mb as usize, nb as usize, kb as usize);
                let th = Instant::now();
                pad::pad_into(input.a, input.m, input.k, mb, kb, &mut scratch.a);
                pad::pad_into(input.b, input.k, input.n, kb, nb, &mut scratch.b);
                pad::pad_into(input.c, input.m, input.n, mb, nb, &mut scratch.c);
                let mut helper_pad = th.elapsed();

                let t0 = Instant::now();
                if let KernelConfig::HostSimd(p) = self.manifest.meta(id).config {
                    // Host microkernel variant: same padded buffers, same
                    // unpad — only the inner GEMM swaps from PJRT execute
                    // to the in-process SIMD microkernel (allocation-free;
                    // `resize_only` reuses capacity at steady state).
                    resize_only(&mut scratch.padded_out, mb * nb);
                    if p.packed && microkernel::pack_enabled() {
                        // Packed layout: panel-pack the padded operands
                        // once per dispatch (a helper pass, like pad —
                        // the §5.4 split the sim model mirrors), then run
                        // the unit-stride packed kernel.  Bit-identical
                        // to the unpacked path.
                        let tp = Instant::now();
                        microkernel::pack_a_into(
                            &scratch.a, mb, kb, p.mr as usize,
                            &mut scratch.pack_a,
                        );
                        microkernel::pack_b_into(
                            &scratch.b, kb, nb, p.nr as usize,
                            &mut scratch.pack_b,
                        );
                        helper_pad += tp.elapsed();
                        let tk = Instant::now();
                        microkernel::gemm_packed(
                            &p,
                            mb,
                            nb,
                            kb,
                            &scratch.pack_a,
                            &scratch.pack_b,
                            &scratch.c,
                            input.alpha,
                            input.beta,
                            &mut scratch.padded_out,
                        );
                        let kernel_time = tk.elapsed();
                        let tu = Instant::now();
                        pad::unpad_into_vec(
                            &scratch.padded_out,
                            nb,
                            input.m,
                            input.n,
                            &mut scratch.out,
                        );
                        return Ok(GemmTimes {
                            helper_time: helper_pad + tu.elapsed(),
                            kernel_time,
                        });
                    }
                    // Unpacked variant — or packed with packing disabled
                    // (`ADAPTLIB_PACK=off`): degrade-don't-fault to the
                    // padded kernel, which computes the same bits.
                    microkernel::gemm_padded(
                        &p,
                        mb,
                        nb,
                        kb,
                        &scratch.a,
                        &scratch.b,
                        &scratch.c,
                        input.alpha,
                        input.beta,
                        &mut scratch.padded_out,
                    );
                } else {
                    let a_dims = [mb as i64, kb as i64];
                    let b_dims = [kb as i64, nb as i64];
                    let c_dims = [mb as i64, nb as i64];
                    let ops = [
                        xla::RawOperand { data: &scratch.a, dims: &a_dims },
                        xla::RawOperand { data: &scratch.b, dims: &b_dims },
                        xla::RawOperand { data: &scratch.c, dims: &c_dims },
                        xla::RawOperand {
                            data: std::slice::from_ref(&input.alpha),
                            dims: &scalar_dims,
                        },
                        xla::RawOperand {
                            data: std::slice::from_ref(&input.beta),
                            dims: &scalar_dims,
                        },
                    ];
                    self.exe(id)
                        .execute_into(&ops, &mut scratch.padded_out)
                        .map_err(|e| {
                            anyhow!(
                                "executing {}: {e:?}",
                                self.manifest.name_of(id)
                            )
                        })?;
                }
                let kernel_time = t0.elapsed();

                let tu = Instant::now();
                pad::unpad_into_vec(
                    &scratch.padded_out,
                    nb,
                    input.m,
                    input.n,
                    &mut scratch.out,
                );
                Ok(GemmTimes {
                    helper_time: helper_pad + tu.elapsed(),
                    kernel_time,
                })
            }
        }
    }

    /// Execute a *fused batch* of same-`(artifact, m, n, k)` GEMMs by
    /// dense id — the serving hot path's batched surface.  Operands are
    /// staged into one stacked, padded scratch region (one slot per
    /// request), executed, and unpadded per slot into `batch.out`
    /// (slot-major); per-slot timings land in `batch.times`.
    ///
    /// Contract (property-tested by `tests/fusion_equivalence.rs`):
    ///
    /// * every slot's result is **bit-identical** to a standalone
    ///   [`gemm_pooled`](Self::gemm_pooled) call on the same operands;
    /// * every input must share one triple (mixed triples are a caller
    ///   bug — the coordinator groups by `(ArtifactId, m, n, k)` before
    ///   fusing — and fail loudly);
    /// * zero steady-state heap allocations (same batch shape: every
    ///   buffer reuses its capacity);
    /// * per-slot times exclude the fusion amortization: each slot is
    ///   timed as its own execute + its own pad/unpad share, so
    ///   telemetry stays comparable to un-fused oracle measurements.
    ///   One deliberate exception: packed host variants reuse the packed
    ///   B panels across adjacent slots that share the same raw B
    ///   operand, so those slots' helper times record the (near-zero)
    ///   work actually done — the amortization *is* the packed fused
    ///   win, and it shows up in wall time.
    ///
    /// On error the batch fails as a whole (`batch.out`/`batch.times`
    /// contents are unspecified); the coordinator answers every member
    /// with a typed per-request error.
    // LINT: hot-path — fused dispatch; per-slot work reuses pooled buffers.
    pub fn gemm_batch_pooled(
        &mut self,
        id: ArtifactId,
        inputs: &[GemmInput],
        batch: &mut BatchScratch,
    ) -> Result<()> {
        batch.times.clear();
        batch.saved = Duration::ZERO;
        let Some(first) = inputs.first() else {
            batch.out.clear();
            return Ok(());
        };
        let t = first.triple();
        for input in inputs {
            input.validate()?;
            if input.triple() != t {
                bail!("fused batch mixes triples: {} vs {t}", input.triple());
            }
        }
        self.check_id(id)?;
        self.check_shape(id, first)?;
        self.ensure_compiled_id(id)?;
        let nb_inputs = inputs.len();
        let (m, n, k) = (first.m, first.n, first.k);
        let scalar_dims = [1i64];
        resize_only(&mut batch.out, nb_inputs * m * n);
        let kind = self.manifest.meta(id).kind;
        match kind {
            ArtifactKind::Direct { trans_a, trans_b, .. } => {
                // Exact-shape artifacts take the request operands as-is:
                // no padding, so no staging pass — each slot executes
                // from the caller's slices and copies its result into
                // the stacked output (same bits as `gemm_pooled`'s
                // direct path, which writes `scratch.out` directly).
                let (mi, ni, ki) = (m as i64, n as i64, k as i64);
                let a_dims: [i64; 2] = if trans_a { [ki, mi] } else { [mi, ki] };
                let b_dims: [i64; 2] = if trans_b { [ni, ki] } else { [ki, ni] };
                let c_dims: [i64; 2] = [mi, ni];
                for (slot, input) in inputs.iter().enumerate() {
                    let t0 = Instant::now();
                    let ops = [
                        xla::RawOperand { data: input.a, dims: &a_dims },
                        xla::RawOperand { data: input.b, dims: &b_dims },
                        xla::RawOperand { data: input.c, dims: &c_dims },
                        xla::RawOperand {
                            data: std::slice::from_ref(&input.alpha),
                            dims: &scalar_dims,
                        },
                        xla::RawOperand {
                            data: std::slice::from_ref(&input.beta),
                            dims: &scalar_dims,
                        },
                    ];
                    self.exe(id)
                        .execute_into(&ops, &mut batch.padded_out)
                        .map_err(|e| {
                            anyhow!("executing {}: {e:?}", self.manifest.name_of(id))
                        })?;
                    let kernel_time = t0.elapsed();
                    let th = Instant::now();
                    batch.out[slot * m * n..(slot + 1) * m * n]
                        .copy_from_slice(&batch.padded_out);
                    // Push into the pool's capacity-retained times Vec
                    // (cleared, not shrunk, between dispatches).
                    // LINT: allow(alloc) — no steady-state allocation.
                    batch.times.push(GemmTimes {
                        helper_time: th.elapsed(),
                        kernel_time,
                    });
                }
            }
            ArtifactKind::Indirect { mb, nb, kb } => {
                let (mb, nb, kb) = (mb as usize, nb as usize, kb as usize);
                let (sa, sb, sc) = (mb * kb, kb * nb, mb * nb);
                resize_only(&mut batch.a, nb_inputs * sa);
                resize_only(&mut batch.b, nb_inputs * sb);
                resize_only(&mut batch.c, nb_inputs * sc);
                // Staging pass: pad every slot into the stacked region
                // (bit-identical per slot to `pad_into`, stale stacked
                // content notwithstanding).
                for (slot, input) in inputs.iter().enumerate() {
                    let th = Instant::now();
                    pad::pad_into_slice(
                        input.a, m, k, mb, kb,
                        &mut batch.a[slot * sa..(slot + 1) * sa],
                    );
                    pad::pad_into_slice(
                        input.b, k, n, kb, nb,
                        &mut batch.b[slot * sb..(slot + 1) * sb],
                    );
                    pad::pad_into_slice(
                        input.c, m, n, mb, nb,
                        &mut batch.c[slot * sc..(slot + 1) * sc],
                    );
                    // Same capacity-retained pool Vec as the direct-slot
                    // push above.
                    // LINT: allow(alloc) — no steady-state allocation.
                    batch.times.push(GemmTimes {
                        helper_time: th.elapsed(),
                        kernel_time: Duration::ZERO,
                    });
                }
                // Execute + unpad per slot over the stacked region.
                let host = match self.manifest.meta(id).config {
                    KernelConfig::HostSimd(p) => Some(p),
                    KernelConfig::Xgemm(_) | KernelConfig::Direct(_) => None,
                };
                let use_packed =
                    host.is_some_and(|p| p.packed && microkernel::pack_enabled());
                // B-repack amortization: fused slots share one triple, so
                // when adjacent slots also share the *same* raw B operand
                // (batched inference against one weight matrix — the
                // hotpath's fused shape) the packed B panels are reused
                // verbatim.  Identity is by raw slice (ptr, len): sound
                // because `pad_into_slice` + `pack_b_into` are pure in
                // the source bytes, and the borrow of `inputs` outlives
                // the loop so the pointer cannot be recycled mid-batch.
                let mut packed_b_for: Option<(*const f32, usize)> = None;
                let a_dims = [mb as i64, kb as i64];
                let b_dims = [kb as i64, nb as i64];
                let c_dims = [mb as i64, nb as i64];
                for (slot, input) in inputs.iter().enumerate() {
                    let t0 = Instant::now();
                    if let Some(p) = host {
                        // Microkernel variant: per-slot SIMD GEMM over the
                        // slot's padded operands — bit-identical to the
                        // standalone pooled call (same buffers, same chain).
                        resize_only(&mut batch.padded_out, sc);
                        if use_packed {
                            let tp = Instant::now();
                            microkernel::pack_a_into(
                                &batch.a[slot * sa..(slot + 1) * sa],
                                mb, kb, p.mr as usize,
                                &mut batch.pack_a,
                            );
                            let key = (input.b.as_ptr(), input.b.len());
                            if packed_b_for != Some(key) {
                                microkernel::pack_b_into(
                                    &batch.b[slot * sb..(slot + 1) * sb],
                                    kb, nb, p.nr as usize,
                                    &mut batch.pack_b,
                                );
                                packed_b_for = Some(key);
                            }
                            batch.times[slot].helper_time += tp.elapsed();
                            let tk = Instant::now();
                            microkernel::gemm_packed(
                                &p,
                                mb,
                                nb,
                                kb,
                                &batch.pack_a,
                                &batch.pack_b,
                                &batch.c[slot * sc..(slot + 1) * sc],
                                input.alpha,
                                input.beta,
                                &mut batch.padded_out,
                            );
                            batch.times[slot].kernel_time = tk.elapsed();
                            let tu = Instant::now();
                            pad::unpad_into(
                                &batch.padded_out,
                                nb,
                                m,
                                n,
                                &mut batch.out[slot * m * n..(slot + 1) * m * n],
                            );
                            batch.times[slot].helper_time += tu.elapsed();
                            continue;
                        }
                        microkernel::gemm_padded(
                            &p,
                            mb,
                            nb,
                            kb,
                            &batch.a[slot * sa..(slot + 1) * sa],
                            &batch.b[slot * sb..(slot + 1) * sb],
                            &batch.c[slot * sc..(slot + 1) * sc],
                            input.alpha,
                            input.beta,
                            &mut batch.padded_out,
                        );
                    } else {
                        let ops = [
                            xla::RawOperand {
                                data: &batch.a[slot * sa..(slot + 1) * sa],
                                dims: &a_dims,
                            },
                            xla::RawOperand {
                                data: &batch.b[slot * sb..(slot + 1) * sb],
                                dims: &b_dims,
                            },
                            xla::RawOperand {
                                data: &batch.c[slot * sc..(slot + 1) * sc],
                                dims: &c_dims,
                            },
                            xla::RawOperand {
                                data: std::slice::from_ref(&input.alpha),
                                dims: &scalar_dims,
                            },
                            xla::RawOperand {
                                data: std::slice::from_ref(&input.beta),
                                dims: &scalar_dims,
                            },
                        ];
                        self.exe(id)
                            .execute_into(&ops, &mut batch.padded_out)
                            .map_err(|e| {
                                anyhow!(
                                    "executing {}: {e:?}",
                                    self.manifest.name_of(id)
                                )
                            })?;
                    }
                    batch.times[slot].kernel_time = t0.elapsed();
                    let tu = Instant::now();
                    pad::unpad_into(
                        &batch.padded_out,
                        nb,
                        m,
                        n,
                        &mut batch.out[slot * m * n..(slot + 1) * m * n],
                    );
                    batch.times[slot].helper_time += tu.elapsed();
                }
            }
        }
        Ok(())
    }

    fn run_direct(
        &mut self,
        id: ArtifactId,
        trans_a: bool,
        trans_b: bool,
        input: &GemmInput,
    ) -> Result<GemmOutput> {
        // Literal staging is host-side helper work, not kernel time.
        let th = Instant::now();
        let (m, n, k) = (input.m as i64, input.n as i64, input.k as i64);
        // Transposed artifacts expect operands in their transposed layout.
        let a_dims: [i64; 2] = if trans_a { [k, m] } else { [m, k] };
        let b_dims: [i64; 2] = if trans_b { [n, k] } else { [k, n] };
        let lits = [
            xla::Literal::vec1(input.a).reshape(&a_dims)?,
            xla::Literal::vec1(input.b).reshape(&b_dims)?,
            xla::Literal::vec1(input.c).reshape(&[m, n])?,
            xla::Literal::vec1(&[input.alpha]),
            xla::Literal::vec1(&[input.beta]),
        ];
        let helper_time = th.elapsed();

        let t0 = Instant::now();
        let out = self.execute_tuple1(id, &lits)?;
        Ok(GemmOutput {
            out,
            helper_time,
            kernel_time: t0.elapsed(),
        })
    }

    fn run_indirect(
        &mut self,
        id: ArtifactId,
        input: &GemmInput,
        mb: usize,
        nb: usize,
        kb: usize,
    ) -> Result<GemmOutput> {
        // Helper phase: pad operands to the bucket (the measured O(n^2)
        // cost that CLBlast pays in its pad/transpose kernels) and stage
        // the literals.
        let th = Instant::now();
        let a_p = pad::pad(input.a, input.m, input.k, mb, kb);
        let b_p = pad::pad(input.b, input.k, input.n, kb, nb);
        let c_p = pad::pad(input.c, input.m, input.n, mb, nb);
        let lits = [
            xla::Literal::vec1(&a_p).reshape(&[mb as i64, kb as i64])?,
            xla::Literal::vec1(&b_p).reshape(&[kb as i64, nb as i64])?,
            xla::Literal::vec1(&c_p).reshape(&[mb as i64, nb as i64])?,
            xla::Literal::vec1(&[input.alpha]),
            xla::Literal::vec1(&[input.beta]),
        ];
        let helper_pad = th.elapsed();

        let t0 = Instant::now();
        let padded = self.execute_tuple1(id, &lits)?;
        let kernel_time = t0.elapsed();

        // Unpad (second helper pass).
        let tu = Instant::now();
        let out = pad::unpad(&padded, nb, input.m, input.n);
        let helper_time = helper_pad + tu.elapsed();
        Ok(GemmOutput { out, helper_time, kernel_time })
    }

    fn execute_tuple1(&self, id: ArtifactId, lits: &[xla::Literal]) -> Result<Vec<f32>> {
        let name = self.manifest.name_of(id);
        let bufs = self
            .exe(id)
            .execute::<xla::Literal>(lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow!("unwrapping tuple of {name}: {e:?}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow!("converting result of {name}: {e:?}"))
    }
}

/// Reference row-major GEMM on the host — the rust-side oracle used by
/// runtime tests and failure injection (independent of JAX).  Allocates
/// the output; see [`host_gemm_into`] for the in-place variant.
pub fn host_gemm(input: &GemmInput) -> Vec<f32> {
    let mut out = vec![0f32; input.m * input.n];
    host_gemm_into(input, &mut out);
    out
}

/// Past this operation count the oracle fans out over row bands.
const PAR_THRESHOLD: usize = 1 << 20;

/// Reference GEMM into a caller-provided buffer.  Blocked i/k/j loop
/// order (streams B row-wise with a per-row f64 accumulator) and, for
/// large problems, parallelized over row bands with scoped threads — so
/// the oracle no longer dominates verification runs.  Per-element results
/// are bit-identical to the naive triple loop (same f64 summation order)
/// regardless of thread count.
pub fn host_gemm_into(input: &GemmInput, out: &mut [f32]) {
    let (m, n, k) = (input.m, input.n, input.k);
    assert_eq!(out.len(), m * n, "output buffer size mismatch");
    if m == 0 || n == 0 {
        return;
    }
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let threads = if m * n * k < PAR_THRESHOLD { 1 } else { hw.min(m).min(16) };
    if threads <= 1 {
        gemm_band(input, 0, out);
        return;
    }
    let band = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (ti, chunk) in out.chunks_mut(band * n).enumerate() {
            s.spawn(move || gemm_band(input, ti * band, chunk));
        }
    });
}

/// Compute rows `[row0, row0 + out.len()/n)` of the result into `out`.
fn gemm_band(input: &GemmInput, row0: usize, out: &mut [f32]) {
    let (n, k) = (input.n, input.k);
    let rows = out.len() / n;
    let mut acc = vec![0f64; n];
    for r in 0..rows {
        let i = row0 + r;
        acc.iter_mut().for_each(|x| *x = 0.0);
        for l in 0..k {
            let av = input.a[i * k + l] as f64;
            let brow = &input.b[l * n..(l + 1) * n];
            for (s, &bv) in acc.iter_mut().zip(brow) {
                *s += av * bv as f64;
            }
        }
        let crow = &input.c[i * n..(i + 1) * n];
        let orow = &mut out[r * n..(r + 1) * n];
        for ((o, &s), &cv) in orow.iter_mut().zip(acc.iter()).zip(crow) {
            *o = input.alpha * s as f32 + input.beta * cv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_gemm_identity() {
        // 2x2 identity times arbitrary B.
        let a = [1.0, 0.0, 0.0, 1.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let c = [0.0; 4];
        let out = host_gemm(&GemmInput {
            m: 2,
            n: 2,
            k: 2,
            a: &a,
            b: &b,
            c: &c,
            alpha: 1.0,
            beta: 0.0,
        });
        assert_eq!(out, b.to_vec());
    }

    #[test]
    fn host_gemm_alpha_beta() {
        let a = [1.0, 2.0]; // 1x2
        let b = [3.0, 4.0]; // 2x1
        let c = [10.0]; // 1x1
        let out = host_gemm(&GemmInput {
            m: 1,
            n: 1,
            k: 2,
            a: &a,
            b: &b,
            c: &c,
            alpha: 2.0,
            beta: 0.5,
        });
        assert_eq!(out, vec![2.0 * 11.0 + 5.0]);
    }

    #[test]
    fn host_gemm_parallel_bands_match_serial() {
        // Big enough to cross PAR_THRESHOLD (128*128*128 = 2^21): the
        // banded parallel path must agree bit-for-bit with a serial
        // single-band run.
        let (m, n, k) = (128usize, 128usize, 128usize);
        let mut rng = crate::util::prng::Rng::new(11);
        let gen = |rng: &mut crate::util::prng::Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
        };
        let a = gen(&mut rng, m * k);
        let b = gen(&mut rng, k * n);
        let c = gen(&mut rng, m * n);
        let input = GemmInput {
            m, n, k,
            a: &a, b: &b, c: &c,
            alpha: 1.25, beta: -0.5,
        };
        let parallel = host_gemm(&input);
        let mut serial = vec![0f32; m * n];
        gemm_band(&input, 0, &mut serial);
        assert_eq!(parallel, serial);
    }

    #[test]
    fn host_gemm_degenerate_dims() {
        // k = 0: out = beta * C only.
        let c = [2.0, 4.0];
        let out = host_gemm(&GemmInput {
            m: 1,
            n: 2,
            k: 0,
            a: &[],
            b: &[],
            c: &c,
            alpha: 3.0,
            beta: 0.5,
        });
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn batch_scratch_slot_indexing() {
        let mut batch = BatchScratch::new();
        batch.out = (0..12).map(|x| x as f32).collect(); // 3 slots of 2x2
        assert_eq!(batch.slot(0, 2, 2), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(batch.slot(2, 2, 2), &[8.0, 9.0, 10.0, 11.0]);
        // resize_only reuses the buffer when the length already matches
        // (stale content preserved — slots are fully overwritten by the
        // staging/unpad passes) and reallocates only on a length change.
        let cap = batch.out.capacity();
        resize_only(&mut batch.out, 12);
        assert_eq!(batch.out[5], 5.0);
        assert_eq!(batch.out.capacity(), cap);
        resize_only(&mut batch.out, 4);
        assert_eq!(batch.out, vec![0f32; 4]);
    }

    #[test]
    fn input_validation() {
        let a = [0f32; 4];
        let bad = GemmInput {
            m: 2,
            n: 2,
            k: 2,
            a: &a,
            b: &a,
            c: &a[..3],
            alpha: 1.0,
            beta: 0.0,
        };
        assert!(bad.validate().is_err());
    }
}
