//! The PJRT executor: loads HLO-text artifacts, compiles them once on the
//! CPU PJRT client (cached), and runs full BLAS GEMMs — the on-line hot
//! path of the adaptive library.  Python is never involved here.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Triple;

use super::manifest::{ArtifactKind, ArtifactMeta, Manifest};
use super::pad;

/// A GEMM request: row-major operands, full BLAS semantics.
#[derive(Debug, Clone)]
pub struct GemmInput<'a> {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a: &'a [f32],
    pub b: &'a [f32],
    pub c: &'a [f32],
    pub alpha: f32,
    pub beta: f32,
}

impl<'a> GemmInput<'a> {
    pub fn triple(&self) -> Triple {
        Triple::new(self.m as u32, self.n as u32, self.k as u32)
    }

    fn validate(&self) -> Result<()> {
        if self.a.len() != self.m * self.k
            || self.b.len() != self.k * self.n
            || self.c.len() != self.m * self.n
        {
            bail!(
                "operand sizes do not match ({}, {}, {}): a={}, b={}, c={}",
                self.m,
                self.n,
                self.k,
                self.a.len(),
                self.b.len(),
                self.c.len()
            );
        }
        Ok(())
    }
}

/// A GEMM result with its timing breakdown.
#[derive(Debug, Clone)]
pub struct GemmOutput {
    pub out: Vec<f32>,
    /// Host-side padding/unpadding time (the indirect "helper" cost).
    pub helper_time: Duration,
    /// PJRT execute + transfer time.
    pub kernel_time: Duration,
}

impl GemmOutput {
    pub fn total_time(&self) -> Duration {
        self.helper_time + self.kernel_time
    }

    pub fn gflops(&self, t: Triple) -> f64 {
        t.flops() / self.total_time().as_secs_f64() / 1e9
    }
}

/// Loads and executes the AOT artifact roster.
pub struct GemmRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative compile time (reported by `adaptd` diagnostics).
    pub compile_time: Duration,
}

impl GemmRuntime {
    /// Open the artifact directory (does not compile anything yet).
    pub fn open(dir: &Path) -> Result<GemmRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(GemmRuntime {
            client,
            manifest,
            cache: HashMap::new(),
            compile_time: Duration::ZERO,
        })
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.manifest.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.manifest.hlo_path(meta);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.compile_time += t0.elapsed();
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    /// Execute a GEMM on a named artifact.
    pub fn gemm(&mut self, name: &str, input: &GemmInput) -> Result<GemmOutput> {
        input.validate()?;
        self.ensure_compiled(name)?;
        let meta = self.manifest.find(name).unwrap().clone();
        // Direct artifacts with transposed operands are addressed by name
        // (the serving router only routes untransposed requests), so shape
        // eligibility here ignores the transpose flags.
        let shape_ok = match meta.kind {
            ArtifactKind::Direct { m, n, k, .. } => {
                (m, n, k) == (input.m as u32, input.n as u32, input.k as u32)
            }
            ArtifactKind::Indirect { .. } => meta.accepts(input.triple()),
        };
        if !shape_ok {
            bail!("artifact '{name}' does not accept {}", input.triple());
        }
        match meta.kind {
            ArtifactKind::Direct { .. } => self.run_direct(&meta, input),
            ArtifactKind::Indirect { mb, nb, kb } => {
                self.run_indirect(&meta, input, mb as usize, nb as usize, kb as usize)
            }
        }
    }

    fn exe(&self, name: &str) -> &xla::PjRtLoadedExecutable {
        &self.cache[name]
    }

    fn run_direct(&mut self, meta: &ArtifactMeta, input: &GemmInput) -> Result<GemmOutput> {
        let t0 = Instant::now();
        let (m, n, k) = (input.m as i64, input.n as i64, input.k as i64);
        // Transposed artifacts expect operands in their transposed layout.
        let (ta, tb) = match meta.kind {
            ArtifactKind::Direct { trans_a, trans_b, .. } => (trans_a, trans_b),
            _ => (false, false),
        };
        let a_dims: [i64; 2] = if ta { [k, m] } else { [m, k] };
        let b_dims: [i64; 2] = if tb { [n, k] } else { [k, n] };
        let lits = [
            xla::Literal::vec1(input.a).reshape(&a_dims)?,
            xla::Literal::vec1(input.b).reshape(&b_dims)?,
            xla::Literal::vec1(input.c).reshape(&[m, n])?,
            xla::Literal::vec1(&[input.alpha]),
            xla::Literal::vec1(&[input.beta]),
        ];
        let out = self.execute_tuple1(&meta.name, &lits)?;
        Ok(GemmOutput {
            out,
            helper_time: Duration::ZERO,
            kernel_time: t0.elapsed(),
        })
    }

    fn run_indirect(
        &mut self,
        meta: &ArtifactMeta,
        input: &GemmInput,
        mb: usize,
        nb: usize,
        kb: usize,
    ) -> Result<GemmOutput> {
        // Helper phase: pad operands to the bucket (the measured O(n^2)
        // cost that CLBlast pays in its pad/transpose kernels).
        let th = Instant::now();
        let a_p = pad::pad(input.a, input.m, input.k, mb, kb);
        let b_p = pad::pad(input.b, input.k, input.n, kb, nb);
        let c_p = pad::pad(input.c, input.m, input.n, mb, nb);
        let helper_pad = th.elapsed();

        let t0 = Instant::now();
        let lits = [
            xla::Literal::vec1(&a_p).reshape(&[mb as i64, kb as i64])?,
            xla::Literal::vec1(&b_p).reshape(&[kb as i64, nb as i64])?,
            xla::Literal::vec1(&c_p).reshape(&[mb as i64, nb as i64])?,
            xla::Literal::vec1(&[input.alpha]),
            xla::Literal::vec1(&[input.beta]),
        ];
        let padded = self.execute_tuple1(&meta.name, &lits)?;
        let kernel_time = t0.elapsed();

        // Unpad (second helper pass).
        let tu = Instant::now();
        let out = pad::unpad(&padded, nb, input.m, input.n);
        let helper_time = helper_pad + tu.elapsed();
        Ok(GemmOutput { out, helper_time, kernel_time })
    }

    fn execute_tuple1(&mut self, name: &str, lits: &[xla::Literal]) -> Result<Vec<f32>> {
        let bufs = self
            .exe(name)
            .execute::<xla::Literal>(lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow!("unwrapping tuple of {name}: {e:?}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow!("converting result of {name}: {e:?}"))
    }
}

/// Reference row-major GEMM on the host — the rust-side oracle used by
/// runtime tests and failure injection (independent of JAX).
pub fn host_gemm(input: &GemmInput) -> Vec<f32> {
    let (m, n, k) = (input.m, input.n, input.k);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for l in 0..k {
                acc += input.a[i * k + l] as f64 * input.b[l * n + j] as f64;
            }
            out[i * n + j] =
                input.alpha * acc as f32 + input.beta * input.c[i * n + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_gemm_identity() {
        // 2x2 identity times arbitrary B.
        let a = [1.0, 0.0, 0.0, 1.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let c = [0.0; 4];
        let out = host_gemm(&GemmInput {
            m: 2,
            n: 2,
            k: 2,
            a: &a,
            b: &b,
            c: &c,
            alpha: 1.0,
            beta: 0.0,
        });
        assert_eq!(out, b.to_vec());
    }

    #[test]
    fn host_gemm_alpha_beta() {
        let a = [1.0, 2.0]; // 1x2
        let b = [3.0, 4.0]; // 2x1
        let c = [10.0]; // 1x1
        let out = host_gemm(&GemmInput {
            m: 1,
            n: 1,
            k: 2,
            a: &a,
            b: &b,
            c: &c,
            alpha: 2.0,
            beta: 0.5,
        });
        assert_eq!(out, vec![2.0 * 11.0 + 5.0]);
    }

    #[test]
    fn input_validation() {
        let a = [0f32; 4];
        let bad = GemmInput {
            m: 2,
            n: 2,
            k: 2,
            a: &a,
            b: &a,
            c: &a[..3],
            alpha: 1.0,
            beta: 0.0,
        };
        assert!(bad.validate().is_err());
    }
}
