//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json`) and the rust runtime (which loads,
//! compiles and executes the HLO-text artifacts it describes).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{
    host_variants, DirectParams, HostParams, KernelConfig, SimdTier, Triple,
    XgemmParams,
};
use crate::util::json::Json;

/// Shape role of an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Exact logical shape; arbitrary (M,N,K) supported via in-graph pad.
    Direct { m: u32, n: u32, k: u32, trans_a: bool, trans_b: bool },
    /// Padded bucket; the host pads operands to (mb, nb, kb).
    Indirect { mb: u32, nb: u32, kb: u32 },
}

/// One AOT-compiled GEMM computation.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    pub config: KernelConfig,
    pub hlo_bytes: usize,
}

impl ArtifactMeta {
    /// Can this artifact compute the given (untransposed) triple?
    pub fn accepts(&self, t: Triple) -> bool {
        match self.kind {
            ArtifactKind::Direct { m, n, k, trans_a, trans_b } => {
                !trans_a && !trans_b && m == t.m && n == t.n && k == t.k
            }
            ArtifactKind::Indirect { mb, nb, kb } => {
                t.m <= mb && t.n <= nb && t.k <= kb
            }
        }
    }

    /// Padding waste ratio when running `t` on this artifact (1.0 = none).
    pub fn waste(&self, t: Triple) -> f64 {
        match self.kind {
            ArtifactKind::Direct { .. } => 1.0,
            ArtifactKind::Indirect { mb, nb, kb } => {
                let w = (mb as f64 * nb as f64 * kb as f64)
                    / (t.m as f64 * t.n as f64 * t.k as f64);
                // Host microkernel variants lose least-waste ties to the
                // bucket's compiled PJRT artifact: generic eligibility
                // (eligible_id, resolve fallback) keeps its pre-variant
                // behaviour, and variants are selected *deliberately* —
                // by exact config match when the policy picks one.
                if matches!(self.config, KernelConfig::HostSimd(_)) {
                    w * (1.0 + 1e-6)
                } else {
                    w
                }
            }
        }
    }
}

/// Dense artifact index, interned from the artifact name at manifest
/// load.  The on-line hot path resolves and dispatches by `ArtifactId`
/// only — no string hashing, no metadata clones.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ArtifactId(pub u32);

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub roster: String,
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    /// Name -> dense index interner (built once at parse).
    index: std::collections::HashMap<String, u32>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let mut m = Self::parse(&text, dir)?;
        m.expand_host_variants();
        Ok(m)
    }

    /// Widen the artifact space with the host SIMD microkernel roster:
    /// per distinct indirect padding bucket, one virtual artifact per
    /// [`host_variants`] point, named `h{mb}x{nb}x{kb}@{variant}`.  A
    /// config thus names (padding bucket, kernel variant, tile/unroll).
    /// Variants carry the bucket's file for bookkeeping but never compile
    /// HLO — they dispatch to `device::microkernel`.  Applied by
    /// [`Manifest::load`]; `parse` stays expansion-free so fixture-level
    /// tests see exactly what the JSON lists.
    pub fn expand_host_variants(&mut self) {
        let mut seen = std::collections::BTreeSet::new();
        let mut buckets = Vec::new();
        for a in &self.artifacts {
            if let ArtifactKind::Indirect { mb, nb, kb } = a.kind {
                if matches!(a.config, KernelConfig::HostSimd(_)) {
                    continue;
                }
                if seen.insert((mb, nb, kb)) {
                    buckets.push((mb, nb, kb, a.file.clone()));
                }
            }
        }
        for (mb, nb, kb, file) in buckets {
            for p in host_variants() {
                let name = format!("h{mb}x{nb}x{kb}@{}", p.name());
                if self.index.contains_key(&name) {
                    continue;
                }
                self.index.insert(name.clone(), self.artifacts.len() as u32);
                self.artifacts.push(ArtifactMeta {
                    name,
                    file: file.clone(),
                    kind: ArtifactKind::Indirect { mb, nb, kb },
                    config: KernelConfig::HostSimd(p),
                    hlo_bytes: 0,
                });
            }
        }
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let version = v.get("version")?.as_u32()?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let roster = v.get("roster")?.as_str()?.to_string();
        let mut artifacts = Vec::new();
        for a in v.get("artifacts")?.as_arr()? {
            artifacts.push(parse_artifact(a)?);
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        let mut index = std::collections::HashMap::with_capacity(artifacts.len());
        for (i, a) in artifacts.iter().enumerate() {
            if index.insert(a.name.clone(), i as u32).is_some() {
                bail!("duplicate artifact name '{}' in manifest", a.name);
            }
        }
        Ok(Manifest { version, roster, dir: dir.to_path_buf(), artifacts, index })
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// Resolve a name to its interned dense id (one hash, load time only;
    /// the serving path holds on to the id).
    pub fn id_of(&self, name: &str) -> Option<ArtifactId> {
        self.index.get(name).copied().map(ArtifactId)
    }

    /// Metadata by dense id (no hashing, no clone).
    pub fn meta(&self, id: ArtifactId) -> &ArtifactMeta {
        &self.artifacts[id.0 as usize]
    }

    /// Artifact name by dense id.
    pub fn name_of(&self, id: ArtifactId) -> &str {
        &self.artifacts[id.0 as usize].name
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.id_of(name).map(|id| self.meta(id))
    }

    /// Least-waste artifact able to run `t`, as a dense id.
    pub fn eligible_id(&self, t: Triple) -> Option<ArtifactId> {
        self.artifacts
            .iter()
            .enumerate()
            .filter(|(_, a)| a.accepts(t))
            .min_by(|(_, a), (_, b)| a.waste(t).partial_cmp(&b.waste(t)).unwrap())
            .map(|(i, _)| ArtifactId(i as u32))
    }

    /// Least-waste artifact implementing `cfg` for `t`, as a dense id.
    pub fn artifact_id_for_config(&self, cfg: &KernelConfig, t: Triple) -> Option<ArtifactId> {
        self.artifacts
            .iter()
            .enumerate()
            .filter(|(_, a)| a.config == *cfg && a.accepts(t))
            .min_by(|(_, a), (_, b)| a.waste(t).partial_cmp(&b.waste(t)).unwrap())
            .map(|(i, _)| ArtifactId(i as u32))
    }

    /// Artifacts able to run triple `t`, best (least padding waste) first.
    pub fn eligible(&self, t: Triple) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> =
            self.artifacts.iter().filter(|a| a.accepts(t)).collect();
        v.sort_by(|a, b| a.waste(t).partial_cmp(&b.waste(t)).unwrap());
        v
    }

    pub fn hlo_path(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.file)
    }

    /// Artifacts grouped by the kernel configuration they implement.
    pub fn config_index(&self) -> std::collections::HashMap<KernelConfig, Vec<&ArtifactMeta>> {
        let mut map: std::collections::HashMap<KernelConfig, Vec<&ArtifactMeta>> =
            std::collections::HashMap::new();
        for a in &self.artifacts {
            map.entry(a.config).or_default().push(a);
        }
        map
    }

    /// Best (least padding waste) artifact implementing `cfg` for `t`.
    pub fn artifact_for_config(&self, cfg: &KernelConfig, t: Triple) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.config == *cfg && a.accepts(t))
            .min_by(|a, b| a.waste(t).partial_cmp(&b.waste(t)).unwrap())
    }
}

fn parse_artifact(a: &Json) -> Result<ArtifactMeta> {
    let name = a.get("name")?.as_str()?.to_string();
    let file = a.get("file")?.as_str()?.to_string();
    let kernel = a.get("kernel")?.as_str()?;
    let cfg_json = a.get("config")?;
    let hlo_bytes = a.get_or("hlo_bytes", &Json::Num(0.0)).as_usize()?;
    let (kind, config) = match kernel {
        "xgemm_direct" => {
            let kind = ArtifactKind::Direct {
                m: a.get("m")?.as_u32()?,
                n: a.get("n")?.as_u32()?,
                k: a.get("k")?.as_u32()?,
                trans_a: a.get_or("trans_a", &Json::Bool(false)).as_bool()?,
                trans_b: a.get_or("trans_b", &Json::Bool(false)).as_bool()?,
            };
            // python DirectConfig -> rust DirectParams (mdimad pinned).
            let g = |k: &str| -> Result<u32> { Ok(cfg_json.get(k)?.as_u32()?) };
            let config = KernelConfig::Direct(DirectParams {
                wgd: g("wgd")?,
                mdimcd: g("mdimcd")?,
                ndimcd: g("ndimcd")?,
                mdimad: 8,
                vwmd: g("vwmd")?,
                vwnd: g("vwnd")?,
                kwid: g("kwid")?,
                pada: g("pada")?,
                padb: g("padb")?,
            });
            (kind, config)
        }
        "xgemm" => {
            let kind = ArtifactKind::Indirect {
                mb: a.get("mb")?.as_u32()?,
                nb: a.get("nb")?.as_u32()?,
                kb: a.get("kb")?.as_u32()?,
            };
            let g = |k: &str| -> Result<u32> { Ok(cfg_json.get(k)?.as_u32()?) };
            let config = KernelConfig::Xgemm(XgemmParams {
                mwg: g("mwg")?,
                nwg: g("nwg")?,
                kwg: g("kwg")?,
                mdimc: g("mdimc")?,
                ndimc: g("ndimc")?,
                mdima: 16,
                ndimb: 16,
                kwi: 2,
                vwm: g("vwm")?,
                vwn: g("vwn")?,
                strm: 0,
                strn: 0,
                sa: g("sa")?,
                sb: g("sb")?,
            });
            (kind, config)
        }
        "host_simd" => {
            let kind = ArtifactKind::Indirect {
                mb: a.get("mb")?.as_u32()?,
                nb: a.get("nb")?.as_u32()?,
                kb: a.get("kb")?.as_u32()?,
            };
            let tier_name = cfg_json.get("tier")?.as_str()?;
            let tier = SimdTier::from_name(tier_name)
                .with_context(|| format!("unknown simd tier '{tier_name}'"))?;
            let g = |k: &str| -> Result<u32> { Ok(cfg_json.get(k)?.as_u32()?) };
            let config = KernelConfig::HostSimd(HostParams {
                tier,
                mr: g("mr")?,
                nr: g("nr")?,
                ku: g("ku")?,
                packed: cfg_json.get_or("packed", &Json::Bool(false)).as_bool()?,
            });
            (kind, config)
        }
        other => bail!("unknown kernel kind '{other}' in manifest"),
    };
    Ok(ArtifactMeta { name, file, kind, config, hlo_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "version": 1, "roster": "small", "dtype": "f32",
 "artifacts": [
  {"name": "d1", "kernel": "xgemm_direct", "file": "d1.hlo.txt",
   "m": 64, "n": 64, "k": 64, "trans_a": false, "trans_b": false,
   "hlo_bytes": 10,
   "config": {"wgd": 32, "mdimcd": 8, "ndimcd": 8, "vwmd": 2, "vwnd": 2,
              "kwid": 2, "pada": 1, "padb": 1}},
  {"name": "i1", "kernel": "xgemm", "file": "i1.hlo.txt",
   "mb": 128, "nb": 128, "kb": 128, "hlo_bytes": 11,
   "config": {"mwg": 64, "nwg": 64, "kwg": 32, "mdimc": 16, "ndimc": 16,
              "vwm": 4, "vwn": 4, "sa": 1, "sb": 1}}
 ]
}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.find("d1").unwrap().hlo_bytes, 10);
        assert!(matches!(
            m.find("i1").unwrap().kind,
            ArtifactKind::Indirect { mb: 128, .. }
        ));
    }

    #[test]
    fn accepts_and_waste() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let d = m.find("d1").unwrap();
        assert!(d.accepts(Triple::new(64, 64, 64)));
        assert!(!d.accepts(Triple::new(64, 64, 63)));
        let i = m.find("i1").unwrap();
        assert!(i.accepts(Triple::new(100, 90, 110)));
        assert!(!i.accepts(Triple::new(200, 90, 110)));
        assert!(i.waste(Triple::new(128, 128, 128)) == 1.0);
        assert!(i.waste(Triple::new(64, 128, 128)) == 2.0);
    }

    #[test]
    fn eligible_sorted_by_waste() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let e = m.eligible(Triple::new(64, 64, 64));
        assert_eq!(e.len(), 2);
        assert_eq!(e[0].name, "d1"); // exact shape: waste 1.0
    }

    #[test]
    fn interned_ids_are_dense_and_stable() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let d = m.id_of("d1").unwrap();
        let i = m.id_of("i1").unwrap();
        assert_eq!((d.0, i.0), (0, 1));
        assert_eq!(m.name_of(d), "d1");
        assert_eq!(m.meta(i).name, "i1");
        assert_eq!(m.len(), 2);
        assert!(m.id_of("nope").is_none());
        // eligible_id picks the least-waste artifact; config resolution
        // by id agrees with the by-reference variant.
        assert_eq!(m.eligible_id(Triple::new(64, 64, 64)), Some(d));
        let cfg = m.meta(i).config;
        assert_eq!(
            m.artifact_id_for_config(&cfg, Triple::new(100, 100, 100)),
            Some(i)
        );
        assert_eq!(m.artifact_id_for_config(&cfg, Triple::new(200, 1, 1)), None);
    }

    #[test]
    fn expand_host_variants_widens_indirect_buckets() {
        let mut m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let before = m.len();
        m.expand_host_variants();
        let variants = host_variants();
        // One virtual artifact per variant per distinct indirect bucket;
        // the direct artifact contributes none.
        assert_eq!(m.len(), before + variants.len());
        // Base ids are untouched — variants append after.
        assert_eq!(m.id_of("d1").unwrap().0, 0);
        assert_eq!(m.id_of("i1").unwrap().0, 1);
        for p in &variants {
            let name = format!("h128x128x128@{}", p.name());
            let meta = m.find(&name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(meta.config, KernelConfig::HostSimd(*p));
            assert!(matches!(
                meta.kind,
                ArtifactKind::Indirect { mb: 128, nb: 128, kb: 128 }
            ));
            assert_eq!(meta.file, "i1.hlo.txt"); // bucket's file, for bookkeeping
            assert_eq!(meta.hlo_bytes, 0);
        }
        // Idempotent: re-expansion adds nothing.
        m.expand_host_variants();
        assert_eq!(m.len(), before + variants.len());
    }

    #[test]
    fn generic_eligibility_still_prefers_compiled_base() {
        let mut m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        m.expand_host_variants();
        let t = Triple::new(100, 100, 100);
        // The tie-break penalty keeps eligible_id on the PJRT artifact …
        assert_eq!(m.eligible_id(t), m.id_of("i1"));
        // … while exact config match resolves each variant deliberately.
        for p in host_variants() {
            let cfg = KernelConfig::HostSimd(p);
            let id = m.artifact_id_for_config(&cfg, t).unwrap();
            assert_eq!(m.meta(id).config, cfg);
        }
    }

    #[test]
    fn parses_explicit_host_simd_entry() {
        let text = r#"{
 "version": 1, "roster": "small",
 "artifacts": [
  {"name": "h1", "kernel": "host_simd", "file": "i1.hlo.txt",
   "mb": 64, "nb": 64, "kb": 64,
   "config": {"tier": "avx2", "mr": 8, "nr": 8, "ku": 4}}
 ]
}"#;
        let m = Manifest::parse(text, Path::new("/tmp")).unwrap();
        let a = m.find("h1").unwrap();
        assert!(matches!(a.kind, ArtifactKind::Indirect { mb: 64, .. }));
        match a.config {
            KernelConfig::HostSimd(p) => {
                assert_eq!(p.tier, SimdTier::Avx2Fma);
                assert_eq!((p.mr, p.nr, p.ku), (8, 8, 4));
            }
            ref other => panic!("wrong config {other:?}"),
        }
        let bad = text.replace("avx2", "neon");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_duplicate_artifact_names() {
        let dup = SAMPLE.replace("\"name\": \"i1\"", "\"name\": \"d1\"");
        assert!(Manifest::parse(&dup, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 10);
            // Every artifact's HLO file must exist.
            for a in &m.artifacts {
                assert!(m.hlo_path(a).exists(), "missing {}", a.file);
            }
        }
    }
}
