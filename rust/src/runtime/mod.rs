//! The on-line runtime: PJRT CPU client wrapper that loads the AOT HLO
//! artifacts and executes GEMMs (`executor`), the artifact manifest
//! (`manifest`), host-side pad helpers (`pad`), and the real-measurement
//! tuner backend (`PjrtBackend`).

pub mod executor;
pub mod manifest;
pub mod pad;

pub use executor::{
    host_gemm, host_gemm_into, BatchScratch, GemmInput, GemmOutput, GemmRuntime,
    GemmTimes, ScratchBuffers,
};
pub use manifest::{ArtifactId, ArtifactKind, ArtifactMeta, Manifest};

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

use crate::config::{KernelConfig, Triple};
use crate::tuner::Backend;
use crate::util::prng::Rng;

/// Real-measurement backend: the tuner's objective function measured on
/// the CPU PJRT client over the AOT'd Pallas kernel variants.  This is
/// the third "device" of the study — the one we physically have.
pub struct PjrtBackend {
    pub runtime: GemmRuntime,
    /// config -> artifact names implementing it (possibly several buckets).
    by_config: HashMap<KernelConfig, Vec<String>>,
    /// Deterministic operand cache per triple.
    data: HashMap<Triple, (Vec<f32>, Vec<f32>, Vec<f32>)>,
    /// Timed repetitions per measurement (median taken).
    pub reps: usize,
}

impl std::fmt::Debug for PjrtBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtBackend").finish_non_exhaustive()
    }
}

impl PjrtBackend {
    pub fn open(dir: &Path) -> Result<PjrtBackend> {
        let runtime = GemmRuntime::open(dir)?;
        let mut by_config: HashMap<KernelConfig, Vec<String>> = HashMap::new();
        for a in &runtime.manifest.artifacts {
            by_config.entry(a.config).or_default().push(a.name.clone());
        }
        Ok(PjrtBackend { runtime, by_config, data: HashMap::new(), reps: 3 })
    }

    /// The configurations implemented by the artifact roster.
    pub fn roster_configs(&self) -> Vec<KernelConfig> {
        let mut v: Vec<KernelConfig> = self.by_config.keys().copied().collect();
        v.sort_by_key(|c| c.name());
        v
    }

    fn operands(&mut self, t: Triple) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        self.data
            .entry(t)
            .or_insert_with(|| {
                let mut rng = Rng::new(
                    0x5EED ^ ((t.m as u64) << 40) ^ ((t.n as u64) << 20) ^ t.k as u64,
                );
                let gen = |rng: &mut Rng, len: usize| -> Vec<f32> {
                    (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
                };
                let a = gen(&mut rng, (t.m * t.k) as usize);
                let b = gen(&mut rng, (t.k * t.n) as usize);
                let c = gen(&mut rng, (t.m * t.n) as usize);
                (a, b, c)
            })
            .clone()
    }

    /// Best artifact (least padding waste) for (config, triple).
    pub fn artifact_for(&self, cfg: &KernelConfig, t: Triple) -> Option<String> {
        let names = self.by_config.get(cfg)?;
        names
            .iter()
            .filter_map(|n| {
                let meta = self.runtime.manifest.find(n)?;
                meta.accepts(t).then(|| (n.clone(), meta.waste(t)))
            })
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(n, _)| n)
    }
}

impl Backend for PjrtBackend {
    fn device_name(&self) -> String {
        "host-cpu".to_string()
    }

    fn measure(&mut self, cfg: &KernelConfig, t: Triple) -> Option<f64> {
        let artifact = self.artifact_for(cfg, t)?;
        let (a, b, c) = self.operands(t);
        let input = GemmInput {
            m: t.m as usize,
            n: t.n as usize,
            k: t.k as usize,
            a: &a,
            b: &b,
            c: &c,
            alpha: 1.0,
            beta: 0.0,
        };
        // Warmup (compilation + caches), then median of reps.
        self.runtime.gemm(&artifact, &input).ok()?;
        let mut times = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let out = self.runtime.gemm(&artifact, &input).ok()?;
            times.push(out.total_time().as_secs_f64());
        }
        times.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let median = times[times.len() / 2];
        Some(t.flops() / median / 1e9)
    }

    fn candidates(&self, t: Triple) -> Vec<KernelConfig> {
        let mut v: Vec<KernelConfig> = self
            .by_config
            .iter()
            .filter(|(cfg, _)| self.artifact_for(cfg, t).is_some())
            .map(|(cfg, _)| *cfg)
            .collect();
        v.sort_by_key(|c| c.name());
        v
    }
}
