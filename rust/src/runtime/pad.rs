//! Host-side padding helpers for the indirect (bucketed) GEMM path — the
//! measured O(n^2) cost mirroring CLBlast's pad/transpose helper kernels.

/// Zero-pad a row-major `rows x cols` matrix into `rows_to x cols_to`.
pub fn pad(src: &[f32], rows: usize, cols: usize, rows_to: usize, cols_to: usize) -> Vec<f32> {
    let mut out = Vec::new();
    pad_into(src, rows, cols, rows_to, cols_to, &mut out);
    out
}

/// [`pad`] into a caller-owned buffer: the buffer is cleared, resized to
/// `rows_to * cols_to` (reusing its capacity — the allocation-free hot
/// path at steady state) and filled exactly like `pad` would.
pub fn pad_into(
    src: &[f32],
    rows: usize,
    cols: usize,
    rows_to: usize,
    cols_to: usize,
    dst: &mut Vec<f32>,
) {
    assert_eq!(src.len(), rows * cols, "src size mismatch");
    assert!(rows_to >= rows && cols_to >= cols, "pad must grow");
    dst.clear();
    dst.resize(rows_to * cols_to, 0f32);
    copy_into(src, cols, dst, cols_to, rows);
}

/// Copy `rows` rows of width `src_cols` into a `dst_cols`-wide buffer.
#[inline]
pub fn copy_into(src: &[f32], src_cols: usize, dst: &mut [f32], dst_cols: usize, rows: usize) {
    debug_assert!(dst_cols >= src_cols);
    for r in 0..rows {
        dst[r * dst_cols..r * dst_cols + src_cols]
            .copy_from_slice(&src[r * src_cols..(r + 1) * src_cols]);
    }
}

/// [`pad`] into a caller-provided slice of exactly `rows_to * cols_to`
/// elements: the slice is zeroed and filled exactly like `pad` would.
/// The fused batch path stages each request's operands into its slot of
/// one stacked scratch region through this — per-slot content is
/// bit-identical to a standalone `pad_into` regardless of what the
/// (reused) stacked buffer held before.
pub fn pad_into_slice(
    src: &[f32],
    rows: usize,
    cols: usize,
    rows_to: usize,
    cols_to: usize,
    dst: &mut [f32],
) {
    assert_eq!(src.len(), rows * cols, "src size mismatch");
    assert!(rows_to >= rows && cols_to >= cols, "pad must grow");
    assert_eq!(dst.len(), rows_to * cols_to, "dst slot size mismatch");
    dst.fill(0f32);
    copy_into(src, cols, dst, cols_to, rows);
}

/// Slice the logical `rows x cols` region out of a padded row-major
/// `_ x padded_cols` buffer.
pub fn unpad(src: &[f32], padded_cols: usize, rows: usize, cols: usize) -> Vec<f32> {
    assert!(padded_cols >= cols);
    assert!(src.len() >= rows * padded_cols, "src too small");
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        out.extend_from_slice(&src[r * padded_cols..r * padded_cols + cols]);
    }
    out
}

/// Unpad into a caller-provided buffer (allocation-free hot path).
pub fn unpad_into(src: &[f32], padded_cols: usize, rows: usize, cols: usize, out: &mut [f32]) {
    assert!(out.len() >= rows * cols);
    for r in 0..rows {
        out[r * cols..(r + 1) * cols]
            .copy_from_slice(&src[r * padded_cols..r * padded_cols + cols]);
    }
}

/// [`unpad`] into a caller-owned `Vec`, reusing its capacity.  Unlike
/// `unpad_into` this needs no pre-sized (and thus pre-zeroed) buffer, so
/// the pooled serving path writes each output element exactly once.
pub fn unpad_into_vec(
    src: &[f32],
    padded_cols: usize,
    rows: usize,
    cols: usize,
    out: &mut Vec<f32>,
) {
    assert!(padded_cols >= cols);
    assert!(src.len() >= rows * padded_cols, "src too small");
    out.clear();
    out.reserve(rows * cols);
    for r in 0..rows {
        out.extend_from_slice(&src[r * padded_cols..r * padded_cols + cols]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_places_and_zeroes() {
        let src = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let out = pad(&src, 2, 3, 4, 5);
        assert_eq!(out.len(), 20);
        assert_eq!(&out[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(out[3], 0.0);
        assert_eq!(&out[5..8], &[4.0, 5.0, 6.0]);
        assert!(out[10..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pad_noop_dimensions() {
        let src = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(pad(&src, 2, 2, 2, 2), src.to_vec());
    }

    #[test]
    fn unpad_inverts_pad() {
        let src: Vec<f32> = (0..12).map(|x| x as f32).collect(); // 3x4
        let padded = pad(&src, 3, 4, 8, 8);
        assert_eq!(unpad(&padded, 8, 3, 4), src);
    }

    #[test]
    fn unpad_into_matches_unpad() {
        let src: Vec<f32> = (0..15).map(|x| x as f32).collect(); // 3x5
        let padded = pad(&src, 3, 5, 4, 8);
        let mut buf = vec![0f32; 15];
        unpad_into(&padded, 8, 3, 5, &mut buf);
        assert_eq!(buf, unpad(&padded, 8, 3, 5));
    }

    #[test]
    fn unpad_into_vec_matches_unpad_and_reuses_capacity() {
        let src: Vec<f32> = (0..15).map(|x| x as f32).collect(); // 3x5
        let padded = pad(&src, 3, 5, 4, 8);
        let mut buf = vec![f32::NAN; 40]; // dirty, oversized pool buffer
        unpad_into_vec(&padded, 8, 3, 5, &mut buf);
        assert_eq!(buf, unpad(&padded, 8, 3, 5));
        let cap = buf.capacity();
        unpad_into_vec(&padded, 8, 3, 5, &mut buf);
        assert_eq!(buf, src);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn pad_into_reuses_capacity_and_matches_pad() {
        let src: Vec<f32> = (0..12).map(|x| x as f32 + 0.25).collect(); // 3x4
        let mut buf = Vec::new();
        pad_into(&src, 3, 4, 8, 8, &mut buf);
        assert_eq!(buf, pad(&src, 3, 4, 8, 8));
        // Steady state: same bucket, dirty buffer, no reallocation.
        let cap = buf.capacity();
        buf.iter_mut().for_each(|x| *x = f32::NAN);
        pad_into(&src, 3, 4, 8, 8, &mut buf);
        assert_eq!(buf, pad(&src, 3, 4, 8, 8));
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn pad_into_slice_matches_pad_into_on_dirty_slots() {
        let src: Vec<f32> = (0..12).map(|x| x as f32 + 0.5).collect(); // 3x4
        let mut expect = Vec::new();
        pad_into(&src, 3, 4, 8, 8, &mut expect);
        // A dirty stacked buffer holding two slots: each slot must come
        // out bit-identical to the standalone pad regardless of the
        // stale content.
        let mut stacked = vec![f32::NAN; 2 * 64];
        for slot in 0..2 {
            pad_into_slice(&src, 3, 4, 8, 8, &mut stacked[slot * 64..(slot + 1) * 64]);
        }
        assert_eq!(&stacked[..64], expect.as_slice());
        assert_eq!(&stacked[64..], expect.as_slice());
    }

    #[test]
    #[should_panic(expected = "dst slot size mismatch")]
    fn pad_into_slice_checks_slot_size() {
        let mut dst = vec![0f32; 10];
        pad_into_slice(&[1.0, 2.0], 1, 2, 2, 2, &mut dst);
    }

    #[test]
    #[should_panic(expected = "src size mismatch")]
    fn pad_checks_input() {
        pad(&[1.0], 2, 3, 4, 4);
    }
}
