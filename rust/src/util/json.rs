//! Minimal JSON: a recursive-descent parser + writer.
//!
//! Built from scratch because the offline image carries no serde facade.
//! Parses the artifact `manifest.json`, persists datasets / tuning
//! databases / trained models, and serializes experiment results.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse(usize, String),
    Type(&'static str, &'static str),
    Missing(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse(at, msg) => {
                write!(f, "json parse error at byte {at}: {msg}")
            }
            JsonError::Type(want, got) => {
                write!(f, "json type error: expected {want}, found {got}")
            }
            JsonError::Missing(key) => write!(f, "json missing key: {key}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------------- access

    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(JsonError::Type("number", other.kind())),
        }
    }

    pub fn as_u32(&self) -> Result<u32, JsonError> {
        Ok(self.as_f64()? as u32)
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type("bool", other.kind())),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type("string", other.kind())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(JsonError::Type("array", other.kind())),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(JsonError::Type("object", other.kind())),
        }
    }

    /// `obj["key"]` with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a Json) -> &'a Json {
        self.as_obj().ok().and_then(|m| m.get(key)).unwrap_or(default)
    }

    // ----------------------------------------------------------- construction

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::Parse(p.i, "trailing data".into()));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.i, msg.to_string()))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError::Parse(start, format!("bad number '{s}': {e}")))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| {
                                        JsonError::Parse(self.i, "bad \\u".into())
                                    })?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| {
                                JsonError::Parse(self.i, "bad \\u".into())
                            })?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (self.i + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[self.i..end])
                            .map_err(|_| JsonError::Parse(self.i, "bad utf8".into()))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

// ------------------------------------------------------------------- write

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => fmt_num(*x, out),
            Json::Str(s) => escape(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"t":true,"n":null},"s":"a\"b"}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn missing_key_error() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(matches!(v.get("b"), Err(JsonError::Missing(_))));
        assert!(matches!(v.get("a").unwrap().as_str(), Err(JsonError::Type(..))));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
 "artifacts": [
  {"config": {"mwg": 64}, "file": "a.hlo.txt", "kernel": "xgemm",
   "mb": 128, "nb": 128, "kb": 128, "name": "a"}
 ],
 "dtype": "f32", "roster": "small", "version": 1
}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_u32().unwrap(), 1);
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("config").unwrap().get("mwg").unwrap().as_u32().unwrap(), 64);
    }
}
