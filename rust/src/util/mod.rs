//! Substrate utilities built from scratch for the offline image
//! (no serde / rand / csv crates available): deterministic PRNG, JSON,
//! CSV, statistics and ASCII table/chart rendering.

pub mod benchcmp;
pub mod csv;
pub mod json;
pub mod prng;
pub mod stats;
pub mod sync;
pub mod table;
