//! Tiny CSV writer/reader for experiment outputs (figures are emitted as
//! CSV series next to their ASCII rendering so they can be re-plotted).

use std::fmt::Write as _;

/// A CSV document under construction.
#[derive(Debug, Default, Clone)]
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        CsvWriter {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        writeln_row(&mut out, &self.header);
        for r in &self.rows {
            writeln_row(&mut out, r);
        }
        out
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())
    }
}

fn writeln_row(out: &mut String, cells: &[String]) {
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if c.contains(',') || c.contains('"') || c.contains('\n') {
            let escaped = c.replace('"', "\"\"");
            let _ = write!(out, "\"{escaped}\"");
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
}

/// Parse CSV text into (header, rows).  Handles quoted cells.
pub fn parse(text: &str) -> (Vec<String>, Vec<Vec<String>>) {
    let mut lines = Vec::new();
    let mut cur = Vec::new();
    let mut cell = String::new();
    let mut in_quotes = false;
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cell.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                cur.push(std::mem::take(&mut cell));
            }
            '\n' if !in_quotes => {
                cur.push(std::mem::take(&mut cell));
                lines.push(std::mem::take(&mut cur));
            }
            '\r' if !in_quotes => {}
            c => cell.push(c),
        }
    }
    if !cell.is_empty() || !cur.is_empty() {
        cur.push(cell);
        lines.push(cur);
    }
    if lines.is_empty() {
        return (Vec::new(), Vec::new());
    }
    let header = lines.remove(0);
    (header, lines)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_and_parse_roundtrip() {
        let mut w = CsvWriter::new(&["m", "n", "gflops"]);
        w.row(&["128".into(), "64".into(), "2.5".into()]);
        w.row(&["has,comma".into(), "has\"quote".into(), "x".into()]);
        let text = w.to_string();
        let (hdr, rows) = parse(&text);
        assert_eq!(hdr, vec!["m", "n", "gflops"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1][0], "has,comma");
        assert_eq!(rows[1][1], "has\"quote");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into()]);
    }

    #[test]
    fn parse_empty() {
        let (h, r) = parse("");
        assert!(h.is_empty() && r.is_empty());
    }

    #[test]
    fn row_display_formats() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row_display(&[&1.5f64, &"x"]);
        assert!(w.to_string().contains("1.5,x"));
    }
}
