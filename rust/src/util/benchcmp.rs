//! Bench-regression comparison: diff a committed baseline
//! (`BENCH_baseline.json`) against a freshly produced bench summary
//! (`BENCH_hotpath.json`, `BENCH_drift.json`) and flag regressions beyond
//! a tolerance — the core of the `adaptd bench-compare` CI gate.
//!
//! Comparable metrics (anything absent from either side is skipped —
//! **except** when both files declare the same `"bench"` family, where a
//! gated key the baseline carries but the fresh run dropped is reported
//! as a named regression: a renamed or deleted bench silently ungating
//! itself is exactly the failure this gate exists to catch.  Cross-family
//! comparisons — the merged baseline against a drift/hetero/overload/
//! chaos file — still skip.  And the comparison fails if *nothing* was
//! comparable — a silent no-op gate is worse than none):
//!
//! * `results[].median_s` by result name — regression when the fresh
//!   median is more than `tolerance` slower;
//! * `shard_scaling[].{rps,gflops}` by shard count — regression when the
//!   fresh throughput is more than `tolerance` lower;
//! * `allocs_per_request.pooled` (and the `_with_policy_handle`,
//!   `engine_pooled`, `fused_pooled`, `simd_pooled`, `simd_packed_pooled`
//!   variants) — regression on *any* increase (the zero-allocation gate:
//!   0 must stay 0);
//! * the fusion gate (`fusion[]` in `BENCH_hotpath.json`): at B=16 the
//!   fused batched path's per-request time must not be slower than B
//!   sequential pooled calls beyond `tolerance` (self-contained in the
//!   current file; occupancy and speedup are reported per batch size);
//! * the SIMD microkernel gate (`simd` in `BENCH_hotpath.json`): the
//!   best servable host variant's per-shape speedup over the scalar
//!   variant (`simd.shapes[].speedup`) and the fused batched variant
//!   path's speedup over sequential scalar
//!   (`simd.fused_speedup_vs_scalar`) must meet the committed floors
//!   (`simd.speedup_floor` / `simd.fused_speedup_floor` in the
//!   baseline, defaulting to 0.9 — even when the detected tier *is*
//!   scalar, as on the forced-fallback CI leg, the variant path must
//!   not be slower than scalar beyond noise).  When a shape row carries
//!   `packed_speedup` (packed vs unpacked best variant), it is gated
//!   against `simd.packed_speedup_floor` (default 0.9); rows without
//!   the key — pre-packing bench files, or a `ADAPTLIB_PACK=off` run —
//!   skip that gate.  The gate output also echoes the runner's
//!   top-level `simd_tier` / `pack_enabled` capability fields so a
//!   floor miss on a scalar-only or pack-off runner is explainable
//!   from the log alone;
//! * `recovered` (drift runs) — regression when the fresh run says
//!   `false`;
//! * per-device `accuracy` (hetero runs: top-level `devices[]` in
//!   `BENCH_hetero.json`, nested under `"hetero"` in the baseline) —
//!   accuracies are 0-1 fractions, so the regression test is an
//!   *absolute* drop beyond `tolerance`;
//! * overload gates (`BENCH_overload.json`): `shed_rate_1x` must be 0
//!   (a server shedding below capacity is broken admission),
//!   `depth_bounded` must be true (the queue never grew past its
//!   configured bound), and `p99_1x_ms` must stay within `tolerance` of
//!   the committed floor (`overload.p99_1x_ms` in the baseline);
//! * chaos gates (`BENCH_chaos.json`, keys `chaos_`-prefixed to stay
//!   clear of the drift `recovered` gate): `chaos_availability_min`
//!   must meet the committed floor (`chaos.availability_floor` in the
//!   baseline, default 0.99), `chaos_post_recovery_error_rate` must be
//!   0, `chaos_quarantined` / `chaos_recovered` / `chaos_bit_identical`
//!   must be true, and `chaos_hung` must be 0.
//!
//! A baseline marked `"provisional": true` (committed before real runner
//! numbers exist) reports regressions as warnings instead of failures;
//! see README.md for how to refresh it.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Outcome of one baseline/current comparison.
#[derive(Debug, Clone)]
pub struct BenchDiff {
    /// Human-readable comparison rows (one per compared metric).
    pub lines: Vec<String>,
    /// Regressions beyond tolerance (empty = gate passes).
    pub regressions: Vec<String>,
    /// Number of metrics compared.
    pub compared: usize,
    /// Baseline was marked provisional: report, don't fail.
    pub provisional: bool,
}

impl BenchDiff {
    /// Gate verdict: fail on real (non-provisional) regressions — and
    /// *always* fail when nothing was comparable: a provisional marker
    /// must not turn a structurally broken comparison into a green gate.
    pub fn passes(&self) -> bool {
        self.compared > 0 && (self.provisional || self.regressions.is_empty())
    }
}

fn num_at(v: &Json, key: &str) -> Option<f64> {
    v.get(key).ok().and_then(|j| j.as_f64().ok())
}

/// results[] -> name -> median_s
fn results_map(v: &Json) -> BTreeMap<String, f64> {
    let mut map = BTreeMap::new();
    if let Ok(arr) = v.get("results").and_then(|r| r.as_arr()) {
        for r in arr {
            if let (Ok(name), Some(med)) =
                (r.get("name").and_then(|n| n.as_str()), num_at(r, "median_s"))
            {
                map.insert(name.to_string(), med);
            }
        }
    }
    map
}

/// shard_scaling[] -> shards -> (rps, gflops)
fn scaling_map(v: &Json) -> BTreeMap<u64, (f64, f64)> {
    let mut map = BTreeMap::new();
    if let Ok(arr) = v.get("shard_scaling").and_then(|r| r.as_arr()) {
        for r in arr {
            if let (Some(s), Some(rps), Some(g)) =
                (num_at(r, "shards"), num_at(r, "rps"), num_at(r, "gflops"))
            {
                map.insert(s as u64, (rps, g));
            }
        }
    }
    map
}

/// Per-device hetero selection accuracy: device -> accuracy (None when
/// the device is listed but its accuracy is null — it served nothing).
/// Reads the top-level `devices[]` of a hetero bench file, or the
/// `hetero.devices` object a merged baseline carries; `None` overall
/// when the file has no device list at all (not a hetero comparison).
fn hetero_map(v: &Json) -> Option<BTreeMap<String, Option<f64>>> {
    let devices = v
        .get("devices")
        .or_else(|_| v.get("hetero").and_then(|h| h.get("devices")));
    let arr = devices.and_then(|d| d.as_arr()).ok()?;
    let mut map = BTreeMap::new();
    for d in arr {
        if let Ok(name) = d.get("device").and_then(|n| n.as_str()) {
            map.insert(name.to_string(), num_at(d, "accuracy"));
        }
    }
    Some(map)
}

/// Compare `current` against `baseline` with a relative `tolerance`
/// (0.15 = fail beyond 15%).
pub fn compare(baseline: &Json, current: &Json, tolerance: f64) -> BenchDiff {
    let provisional = baseline
        .get("provisional")
        .ok()
        .and_then(|p| p.as_bool().ok())
        .unwrap_or(false);
    let mut diff = BenchDiff {
        lines: Vec::new(),
        regressions: Vec::new(),
        compared: 0,
        provisional,
    };

    // When both files declare the same `"bench"` family, a gated key
    // present in the baseline but missing from the fresh run is a named
    // regression (a renamed or deleted bench must not ungate itself);
    // cross-family comparisons (the merged baseline against a drift or
    // hetero file) keep skipping.  Files without a family string are
    // never treated as same-family.
    let same_family = match (
        baseline.get("bench").and_then(|b| b.as_str()),
        current.get("bench").and_then(|b| b.as_str()),
    ) {
        (Ok(a), Ok(b)) => a == b,
        _ => false,
    };

    // Timed results: lower is better.
    let base_results = results_map(baseline);
    let cur_results = results_map(current);
    for (name, base) in &base_results {
        let Some(cur) = cur_results.get(name) else {
            if same_family {
                diff.compared += 1;
                diff.lines.push(format!("{name}: {base:.3e}s -> (missing)"));
                diff.regressions.push(format!(
                    "{name}: gated result missing from the fresh run \
                     (renamed or dropped bench, not a skip)"
                ));
            }
            continue;
        };
        diff.compared += 1;
        let ratio = cur / base;
        let delta = 100.0 * (ratio - 1.0);
        diff.lines.push(format!(
            "{name}: {base:.3e}s -> {cur:.3e}s ({delta:+.1}%)"
        ));
        if ratio > 1.0 + tolerance {
            diff.regressions.push(format!(
                "{name}: median {delta:+.1}% slower (tolerance {:.0}%)",
                tolerance * 100.0
            ));
        }
    }

    // Shard scaling: higher is better.
    let base_scaling = scaling_map(baseline);
    let cur_scaling = scaling_map(current);
    for (shards, (base_rps, base_gflops)) in &base_scaling {
        let Some((cur_rps, cur_gflops)) = cur_scaling.get(shards) else {
            if same_family {
                diff.compared += 1;
                diff.lines
                    .push(format!("shards={shards}: -> (missing)"));
                diff.regressions.push(format!(
                    "shards={shards}: scaling row missing from the fresh run"
                ));
            }
            continue;
        };
        for (metric, base, cur) in [
            ("rps", base_rps, cur_rps),
            ("gflops", base_gflops, cur_gflops),
        ] {
            diff.compared += 1;
            let delta = 100.0 * (cur / base - 1.0);
            diff.lines.push(format!(
                "shards={shards} {metric}: {base:.1} -> {cur:.1} ({delta:+.1}%)"
            ));
            if *cur < *base * (1.0 - tolerance) {
                diff.regressions.push(format!(
                    "shards={shards} {metric}: throughput {delta:+.1}% \
                     (tolerance -{:.0}%)",
                    tolerance * 100.0
                ));
            }
        }
    }

    // Hetero per-device selection accuracy: higher is better, compared
    // absolutely (accuracies are 0-1 fractions; a relative test would be
    // hypersensitive near zero).  A device the baseline gates that is
    // missing from the fresh device list — or listed with a null
    // accuracy because it served no traffic — is the worst possible
    // outcome (the router starved a whole class), not a skip.
    if let (Some(base_hetero), Some(cur_hetero)) =
        (hetero_map(baseline), hetero_map(current))
    {
        for (device, base) in &base_hetero {
            let Some(base) = *base else { continue }; // no baseline floor set
            diff.compared += 1;
            match cur_hetero.get(device).copied().flatten() {
                Some(cur) => {
                    diff.lines.push(format!(
                        "hetero {device} accuracy: {:.1}% -> {:.1}%",
                        100.0 * base,
                        100.0 * cur
                    ));
                    if cur < base - tolerance {
                        diff.regressions.push(format!(
                            "hetero {device}: selection accuracy fell \
                             {:.1}% -> {:.1}% (tolerance -{:.0} points)",
                            100.0 * base,
                            100.0 * cur,
                            tolerance * 100.0
                        ));
                    }
                }
                None => {
                    diff.lines.push(format!(
                        "hetero {device} accuracy: {:.1}% -> (no traffic)",
                        100.0 * base
                    ));
                    diff.regressions.push(format!(
                        "hetero {device}: served no traffic (device missing \
                         or starved in the fresh run)"
                    ));
                }
            }
        }
    }

    // Zero-allocation gates: any increase is a regression — the bare
    // pooled path, the pooled-behind-a-PolicyHandle path, the pooled
    // path behind the ExecutionEngine trait, the fused batched path,
    // and the wire-decode path of the network front door.
    for key in [
        "pooled",
        "pooled_with_policy_handle",
        "engine_pooled",
        "fused_pooled",
        "simd_pooled",
        "simd_packed_pooled",
        "net_decode",
    ] {
        let base = baseline
            .get("allocs_per_request")
            .ok()
            .and_then(|a| num_at(a, key));
        let cur = current
            .get("allocs_per_request")
            .ok()
            .and_then(|a| num_at(a, key));
        let Some(base) = base else { continue };
        let Some(cur) = cur else {
            if same_family {
                diff.compared += 1;
                diff.lines
                    .push(format!("allocs/request {key}: {base:.1} -> (missing)"));
                diff.regressions.push(format!(
                    "{key} allocation gate missing from the fresh run"
                ));
            }
            continue;
        };
        diff.compared += 1;
        diff.lines
            .push(format!("allocs/request {key}: {base:.1} -> {cur:.1}"));
        if cur > base + 1e-9 {
            diff.regressions.push(format!(
                "{key} path allocates again: {base:.1} -> {cur:.1} allocs/request"
            ));
        }
    }

    // Fusion gate.  Self-contained in the current file (occupancy and
    // speedup are reported for every measured batch size): at the B=16
    // gate point the fused path's per-request time must not be slower
    // than B sequential pooled calls beyond tolerance — fusion that
    // costs more than it amortizes is a regression at any baseline.
    if let Ok(arr) = current.get("fusion").and_then(|f| f.as_arr()) {
        for e in arr {
            let (Some(b), Some(fused), Some(seq)) = (
                num_at(e, "b"),
                num_at(e, "fused_per_request_s"),
                num_at(e, "seq_per_request_s"),
            ) else {
                continue;
            };
            let occupancy = num_at(e, "occupancy").unwrap_or(b);
            let speedup = if fused > 0.0 { seq / fused } else { 0.0 };
            diff.lines.push(format!(
                "fusion B={b:.0}: {fused:.3e}s/req fused vs {seq:.3e}s/req \
                 sequential ({speedup:.2}x, occupancy {occupancy:.0})"
            ));
            if (b - 16.0).abs() < 1e-9 {
                diff.compared += 1;
                if fused > seq * (1.0 + tolerance) {
                    diff.regressions.push(format!(
                        "fusion: B=16 fused path {:+.1}% slower per request than \
                         sequential (tolerance {:.0}%)",
                        100.0 * (fused / seq - 1.0),
                        tolerance * 100.0
                    ));
                }
            }
        }
    }

    // SIMD microkernel gate.  The hotpath bench reports, per probe
    // shape, the best *servable* host variant's speedup over the scalar
    // variant through `gemm_pooled` (`simd.shapes[].speedup`), plus the
    // fused batched variant path's per-request speedup over sequential
    // scalar dispatches (`simd.fused_speedup_vs_scalar`).  Floors come
    // from the baseline (`simd.speedup_floor` / `simd.fused_speedup_floor`)
    // and default to 0.9: even on a host whose best servable tier *is*
    // scalar — the forced-fallback CI leg — the variant path must not be
    // slower than the scalar variant beyond noise.
    if let Ok(simd) = current.get("simd") {
        let floor = baseline
            .get("simd")
            .ok()
            .and_then(|s| num_at(s, "speedup_floor"))
            .unwrap_or(0.9);
        let fused_floor = baseline
            .get("simd")
            .ok()
            .and_then(|s| num_at(s, "fused_speedup_floor"))
            .unwrap_or(0.9);
        let packed_floor = baseline
            .get("simd")
            .ok()
            .and_then(|s| num_at(s, "packed_speedup_floor"))
            .unwrap_or(0.9);
        let tier = simd.get("tier").and_then(|t| t.as_str()).unwrap_or("?");
        // Runtime capability context (top-level fields the hotpath bench
        // records): what the runner actually detected, so a floor miss
        // on a scalar-only or pack-off runner is explainable from the
        // gate output alone.
        let rt_tier = current
            .get("simd_tier")
            .ok()
            .and_then(|t| t.as_str().ok())
            .unwrap_or("?");
        let rt_pack = match current.get("pack_enabled").ok().map(|b| b.as_bool()) {
            Some(Ok(true)) => "on",
            Some(Ok(false)) => "off",
            _ => "?",
        };
        diff.lines.push(format!(
            "simd runtime: detected tier {rt_tier}, packing {rt_pack}"
        ));
        // Capability drift between the baseline's runner and this one is
        // the usual benign explanation for a floor miss, so echo any
        // mismatch loudly — warning lines only, never a gated
        // regression: CI legs intentionally run scalar-only and
        // pack-off runners against the committed baseline.
        let base_tier = baseline
            .get("simd_tier")
            .ok()
            .and_then(|t| t.as_str().ok())
            .unwrap_or("?");
        let base_pack = match baseline.get("pack_enabled").ok().map(|b| b.as_bool()) {
            Some(Ok(true)) => "on",
            Some(Ok(false)) => "off",
            _ => "?",
        };
        if base_tier != "?" && rt_tier != "?" && base_tier != rt_tier {
            diff.lines.push(format!(
                "WARNING: simd tier mismatch — baseline was recorded on tier \
                 {base_tier}, this runner detected {rt_tier}; speedup floors \
                 may not be comparable"
            ));
        }
        if base_pack != "?" && rt_pack != "?" && base_pack != rt_pack {
            diff.lines.push(format!(
                "WARNING: packing mismatch — baseline was recorded with \
                 packing {base_pack}, this runner has packing {rt_pack}"
            ));
        }
        if let Ok(arr) = simd.get("shapes").and_then(|s| s.as_arr()) {
            for row in arr {
                let (Ok(shape), Some(speedup)) = (
                    row.get("shape").and_then(|s| s.as_str()),
                    num_at(row, "speedup"),
                ) else {
                    continue;
                };
                diff.compared += 1;
                diff.lines.push(format!(
                    "simd {shape} (tier {tier}): best variant {speedup:.2}x \
                     scalar (floor {floor:.2}x)"
                ));
                if speedup < floor {
                    diff.regressions.push(format!(
                        "simd: best variant only {speedup:.2}x the scalar \
                         variant on {shape} (floor {floor:.2}x; runner tier \
                         {rt_tier}, packing {rt_pack})"
                    ));
                }
                // Packed-vs-unpacked floor (key-presence-conditional so
                // pre-packing bench files still compare cleanly).
                if let Some(ps) = num_at(row, "packed_speedup") {
                    diff.compared += 1;
                    diff.lines.push(format!(
                        "simd {shape}: packed variant {ps:.2}x unpacked \
                         (floor {packed_floor:.2}x)"
                    ));
                    if ps < packed_floor {
                        diff.regressions.push(format!(
                            "simd: packed variant only {ps:.2}x the unpacked \
                             variant on {shape} (floor {packed_floor:.2}x; \
                             runner tier {rt_tier}, packing {rt_pack})"
                        ));
                    }
                }
            }
        }
        if let Some(fused) = num_at(simd, "fused_speedup_vs_scalar") {
            diff.compared += 1;
            diff.lines.push(format!(
                "simd fused: {fused:.2}x sequential scalar per request \
                 (floor {fused_floor:.2}x)"
            ));
            if fused < fused_floor {
                diff.regressions.push(format!(
                    "simd: fused batched variant path only {fused:.2}x \
                     sequential scalar (floor {fused_floor:.2}x)"
                ));
            }
        }
        if let Some(fp) = num_at(simd, "fused_packed_speedup_vs_scalar") {
            diff.lines.push(format!(
                "simd fused packed: {fp:.2}x sequential scalar per request \
                 (B-repack amortized; informational)"
            ));
        }
    }

    // Overload gates.  The structural guarantees are self-contained in
    // the current file (like `recovered`): shedding at 1x offered load
    // and an exceeded queue bound are wrong at *any* baseline.
    if let Ok(rate) = current.get("shed_rate_1x").and_then(|r| r.as_f64()) {
        diff.compared += 1;
        diff.lines
            .push(format!("overload shed rate @1x: {:.2}%", rate * 100.0));
        if rate > 0.0 {
            diff.regressions.push(format!(
                "overload: shedding below capacity ({:.2}% shed at 1x offered load)",
                rate * 100.0
            ));
        }
    }
    if let Ok(bounded) = current.get("depth_bounded").and_then(|b| b.as_bool()) {
        diff.compared += 1;
        diff.lines.push(format!("overload depth bounded: {bounded}"));
        if !bounded {
            diff.regressions.push(
                "overload: peak queue depth exceeded the configured bound"
                    .to_string(),
            );
        }
    }
    // p99 at 1x against the committed floor (baseline `overload` key):
    // a latency, so lower is better and the tolerance is relative.
    let base_p99 = baseline
        .get("overload")
        .ok()
        .and_then(|o| num_at(o, "p99_1x_ms"));
    if let (Some(base), Some(cur)) = (base_p99, num_at(current, "p99_1x_ms")) {
        diff.compared += 1;
        let delta = 100.0 * (cur / base - 1.0);
        diff.lines.push(format!(
            "overload p99 @1x: {base:.2}ms -> {cur:.2}ms ({delta:+.1}%)"
        ));
        if cur > base * (1.0 + tolerance) {
            diff.regressions.push(format!(
                "overload: p99 at 1x load {delta:+.1}% above the committed floor \
                 (tolerance {:.0}%)",
                tolerance * 100.0
            ));
        }
    }
    // Informational only (timing-sensitive on shared runners): whether
    // pressure picks beat policy-only selection at the deepest overload.
    if let Ok(improved) = current
        .get("pressure_p99_improved")
        .and_then(|b| b.as_bool())
    {
        diff.lines.push(format!(
            "overload pressure-pick p99 improved at max load: {improved}"
        ));
    }
    // Network-arm gates (same shape as the in-process ones; the keys
    // exist only when the loopback arm ran, so a skipped arm skips the
    // gate instead of passing it vacuously).
    if let Ok(rate) = current.get("net_shed_rate_1x").and_then(|r| r.as_f64()) {
        diff.compared += 1;
        diff.lines
            .push(format!("overload net shed rate @1x: {:.2}%", rate * 100.0));
        if rate > 0.0 {
            diff.regressions.push(format!(
                "overload: network arm shed below capacity ({:.2}% at 1x offered load)",
                rate * 100.0
            ));
        }
    }
    if let Ok(bounded) = current.get("net_depth_bounded").and_then(|b| b.as_bool()) {
        diff.compared += 1;
        diff.lines.push(format!("overload net depth bounded: {bounded}"));
        if !bounded {
            diff.regressions.push(
                "overload: network arm exceeded the queue bound (wire bypassed \
                 bounded admission)"
                    .to_string(),
            );
        }
    }
    // Client-observed p99 at 1x over the wire (framing + decode + serve)
    // against the committed floor.
    let base_net_p99 = baseline
        .get("overload")
        .ok()
        .and_then(|o| num_at(o, "net_p99_1x_ms"));
    if let (Some(base), Some(cur)) = (base_net_p99, num_at(current, "net_p99_1x_ms")) {
        diff.compared += 1;
        let delta = 100.0 * (cur / base - 1.0);
        diff.lines.push(format!(
            "overload net p99 @1x: {base:.2}ms -> {cur:.2}ms ({delta:+.1}%)"
        ));
        if cur > base * (1.0 + tolerance) {
            diff.regressions.push(format!(
                "overload: network p99 at 1x load {delta:+.1}% above the committed \
                 floor (tolerance {:.0}%)",
                tolerance * 100.0
            ));
        }
    }

    // Chaos gates (`BENCH_chaos.json`, `chaos_`-prefixed keys so the
    // drift `recovered` gate below never collides).  The structural
    // guarantees are self-contained in the current file: a hung reply,
    // a post-recovery error, a breaker that never quarantined a dead
    // device (or never recovered a revived one), and a payload that
    // deviated from the oracle are wrong at *any* baseline.  Only the
    // availability floor consults the baseline (`chaos.availability_floor`,
    // defaulting to 0.99).
    if let Ok(avail) = current
        .get("chaos_availability_min")
        .and_then(|a| a.as_f64())
    {
        diff.compared += 1;
        let floor = baseline
            .get("chaos")
            .ok()
            .and_then(|c| num_at(c, "availability_floor"))
            .unwrap_or(0.99);
        diff.lines.push(format!(
            "chaos availability min: {:.4} (floor {floor:.4})",
            avail
        ));
        if avail < floor {
            diff.regressions.push(format!(
                "chaos: availability {avail:.4} under injected faults fell \
                 below the floor {floor:.4}"
            ));
        }
        // The remaining chaos gates only run when the headline key is
        // present, so a chaos-less bench file never trips them.
        if let Ok(rate) = current
            .get("chaos_post_recovery_error_rate")
            .and_then(|r| r.as_f64())
        {
            diff.lines
                .push(format!("chaos post-recovery error rate: {rate:.4}"));
            if rate > 0.0 {
                diff.regressions.push(format!(
                    "chaos: {:.2}% of post-recovery requests errored — the \
                     revived device must serve cleanly",
                    rate * 100.0
                ));
            }
        }
        for (key, what) in [
            ("chaos_quarantined", "breaker never quarantined the dead device"),
            ("chaos_recovered", "revived device never closed its breaker and served"),
            ("chaos_bit_identical", "served payloads deviated from the oracle"),
        ] {
            if let Ok(ok) = current.get(key).and_then(|b| b.as_bool()) {
                diff.lines.push(format!("{key}: {ok}"));
                if !ok {
                    diff.regressions.push(format!("chaos: {what}"));
                }
            }
        }
        if let Ok(hung) = current.get("chaos_hung").and_then(|h| h.as_f64()) {
            diff.lines.push(format!("chaos hung replies: {hung:.0}"));
            if hung > 0.0 {
                diff.regressions.push(format!(
                    "chaos: {hung:.0} replies never arrived — every admitted \
                     request must get a typed answer"
                ));
            }
        }
    }

    // Drift recovery: the fresh run must not report a lost recovery.
    if let Ok(rec) = current.get("recovered").and_then(|r| r.as_bool()) {
        diff.compared += 1;
        diff.lines.push(format!("drift recovered: {rec}"));
        if !rec {
            diff.regressions
                .push("drift experiment did not recover post-swap".to_string());
        }
    }

    if diff.compared == 0 {
        diff.regressions.push(
            "no comparable metrics between baseline and current — \
             refusing to pass an empty gate"
                .to_string(),
        );
    }
    diff
}

/// Load + compare two bench JSON files.
pub fn compare_files(baseline: &str, current: &str, tolerance: f64) -> Result<BenchDiff> {
    let read = |p: &str| -> Result<Json> {
        let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        Json::parse(&text).with_context(|| format!("parsing {p}"))
    };
    Ok(compare(&read(baseline)?, &read(current)?, tolerance))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bench_json(median: f64, gflops: f64, pooled: f64) -> Json {
        Json::parse(&format!(
            r#"{{"bench":"hotpath",
                 "results":[{{"name":"gemm:direct:128^3","median_s":{median}}}],
                 "shard_scaling":[{{"shards":1,"rps":100.0,"gflops":{gflops}}}],
                 "allocs_per_request":{{"allocating":60.0,"pooled":{pooled},
                                        "pooled_with_policy_handle":{pooled}}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn identical_runs_pass() {
        let base = bench_json(1e-4, 5.0, 0.0);
        let diff = compare(&base, &base, 0.15);
        assert!(diff.passes());
        assert!(diff.regressions.is_empty());
        // 1 result + 2 scaling + 2 alloc gates.
        assert_eq!(diff.compared, 5);
    }

    #[test]
    fn slower_median_beyond_tolerance_fails() {
        let base = bench_json(1e-4, 5.0, 0.0);
        let cur = bench_json(1.2e-4, 5.0, 0.0); // +20% > 15%
        let diff = compare(&base, &cur, 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("gemm:direct:128^3"));
        // Within tolerance: passes.
        let cur = bench_json(1.1e-4, 5.0, 0.0); // +10%
        assert!(compare(&base, &cur, 0.15).passes());
    }

    #[test]
    fn throughput_drop_fails_gain_passes() {
        let base = bench_json(1e-4, 5.0, 0.0);
        let cur = bench_json(1e-4, 4.0, 0.0); // -20%
        assert!(!compare(&base, &cur, 0.15).passes());
        let cur = bench_json(1e-4, 6.0, 0.0); // faster is fine
        assert!(compare(&base, &cur, 0.15).passes());
    }

    #[test]
    fn any_pooled_allocation_fails() {
        let base = bench_json(1e-4, 5.0, 0.0);
        let cur = bench_json(1e-4, 5.0, 0.5); // half an alloc per request
        let diff = compare(&base, &cur, 0.15);
        assert!(!diff.passes());
        // Both zero-alloc gates fire: bare pooled and behind the handle.
        assert_eq!(diff.regressions.len(), 2);
        assert!(diff.regressions.iter().any(|r| r.contains("policy_handle")));
    }

    #[test]
    fn provisional_baseline_reports_but_passes() {
        let mut base = bench_json(1e-4, 5.0, 0.0);
        if let Json::Obj(ref mut m) = base {
            m.insert("provisional".into(), Json::Bool(true));
        }
        let cur = bench_json(9e-4, 1.0, 0.0); // terrible, but provisional
        let diff = compare(&base, &cur, 0.15);
        assert!(diff.provisional);
        assert!(!diff.regressions.is_empty());
        assert!(diff.passes());
    }

    #[test]
    fn drift_recovered_gate() {
        let cur = Json::parse(r#"{"bench":"drift","recovered":false}"#).unwrap();
        let base = Json::parse(r#"{"bench":"drift","recovered":true}"#).unwrap();
        let diff = compare(&base, &cur, 0.15);
        assert!(!diff.passes());
        let cur = Json::parse(r#"{"bench":"drift","recovered":true}"#).unwrap();
        assert!(compare(&base, &cur, 0.15).passes());
    }

    #[test]
    fn hetero_accuracy_gate_is_absolute_and_reads_both_shapes() {
        // Baseline carries the merged form ("hetero":{"devices":[...]}),
        // the current file is a raw hetero report (top-level "devices").
        let base = Json::parse(
            r#"{"bench":"hotpath",
                "hetero":{"devices":[
                  {"device":"host-cpu","accuracy":0.8},
                  {"device":"mali-t860","accuracy":0.6}]}}"#,
        )
        .unwrap();
        let cur = |cpu: f64, mali: f64| {
            Json::parse(&format!(
                r#"{{"bench":"hetero","devices":[
                     {{"device":"host-cpu","accuracy":{cpu}}},
                     {{"device":"mali-t860","accuracy":{mali}}}]}}"#
            ))
            .unwrap()
        };
        // Within tolerance (absolute 0.15): passes.
        let diff = compare(&base, &cur(0.70, 0.55), 0.15);
        assert_eq!(diff.compared, 2);
        assert!(diff.passes(), "{:?}", diff.regressions);
        // One device falls beyond tolerance: fails and names the device.
        let diff = compare(&base, &cur(0.60, 0.58), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("host-cpu"));
        // A gated device absent from the fresh device list is a
        // regression (the router starved a whole class), not a skip —
        // and so is one listed with a null accuracy (served nothing).
        for cur_bad in [
            r#"{"bench":"hetero","devices":[{"device":"host-cpu","accuracy":0.8}]}"#,
            r#"{"bench":"hetero","devices":[
                 {"device":"host-cpu","accuracy":0.8},
                 {"device":"mali-t860","accuracy":null}]}"#,
        ] {
            let diff = compare(&base, &Json::parse(cur_bad).unwrap(), 0.15);
            assert_eq!(diff.compared, 2);
            assert!(!diff.passes());
            assert!(
                diff.regressions.iter().any(|r| r.contains("mali-t860")
                    && r.contains("no traffic")),
                "{:?}",
                diff.regressions
            );
        }
        // No device list at all on one side (e.g. a hotpath file): the
        // hetero section is skipped entirely.
        let hotpath = Json::parse(r#"{"bench":"hotpath"}"#).unwrap();
        let diff = compare(&base, &hotpath, 0.15);
        assert!(!diff.lines.iter().any(|l| l.contains("hetero")));
    }

    #[test]
    fn engine_pooled_allocation_gate() {
        let with_engine = |engine: f64| {
            Json::parse(&format!(
                r#"{{"allocs_per_request":{{"pooled":0.0,
                     "pooled_with_policy_handle":0.0,"engine_pooled":{engine}}}}}"#
            ))
            .unwrap()
        };
        let base = with_engine(0.0);
        assert!(compare(&base, &with_engine(0.0), 0.15).passes());
        let diff = compare(&base, &with_engine(1.0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions.iter().any(|r| r.contains("engine_pooled")));
    }

    #[test]
    fn fusion_gate_compares_b16_and_reports_occupancy() {
        let base = Json::parse(r#"{"bench":"hotpath"}"#).unwrap();
        let cur = |fused16: f64| {
            Json::parse(&format!(
                r#"{{"bench":"hotpath","fusion":[
                     {{"b":1,"occupancy":1,"fused_per_request_s":1.1e-4,
                       "seq_per_request_s":1.0e-4,"speedup":0.91}},
                     {{"b":16,"occupancy":16,"fused_per_request_s":{fused16},
                       "seq_per_request_s":1.0e-4,"speedup":1.3}}]}}"#
            ))
            .unwrap()
        };
        // Fused no slower than sequential at B=16: passes; every row is
        // reported with its occupancy (B=1 may legitimately be slower —
        // it is informational, not gated).
        let diff = compare(&base, &cur(0.8e-4), 0.15);
        assert_eq!(diff.compared, 1);
        assert!(diff.passes(), "{:?}", diff.regressions);
        assert!(diff.lines.iter().any(|l| l.contains("fusion B=1:")));
        assert!(diff
            .lines
            .iter()
            .any(|l| l.contains("fusion B=16:") && l.contains("occupancy 16")));
        // Within tolerance: passes.
        assert!(compare(&base, &cur(1.1e-4), 0.15).passes());
        // B=16 slower than sequential beyond tolerance: fails.
        let diff = compare(&base, &cur(1.3e-4), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("fusion"), "{:?}", diff.regressions);
        // No fusion section: nothing compared, nothing gated.
        let diff = compare(&base, &base, 0.15);
        assert!(!diff.lines.iter().any(|l| l.contains("fusion")));
    }

    #[test]
    fn fused_pooled_allocation_gate() {
        let with_fused = |fused: f64| {
            Json::parse(&format!(
                r#"{{"allocs_per_request":{{"pooled":0.0,"fused_pooled":{fused}}}}}"#
            ))
            .unwrap()
        };
        let base = with_fused(0.0);
        assert!(compare(&base, &with_fused(0.0), 0.15).passes());
        let diff = compare(&base, &with_fused(0.25), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions.iter().any(|r| r.contains("fused_pooled")));
    }

    #[test]
    fn overload_gates_shed_depth_and_p99_floor() {
        let base = Json::parse(r#"{"bench":"hotpath","overload":{"p99_1x_ms":10.0}}"#)
            .unwrap();
        let cur = |shed: f64, bounded: bool, p99: f64| {
            Json::parse(&format!(
                r#"{{"bench":"overload","shed_rate_1x":{shed},
                     "depth_bounded":{bounded},"p99_1x_ms":{p99},
                     "pressure_p99_improved":true}}"#
            ))
            .unwrap()
        };
        // Clean run: all three gates compared, none regress.
        let diff = compare(&base, &cur(0.0, true, 10.5), 0.15);
        assert_eq!(diff.compared, 3);
        assert!(diff.passes(), "{:?}", diff.regressions);
        assert!(diff
            .lines
            .iter()
            .any(|l| l.contains("pressure-pick p99 improved")));
        // Any shedding at 1x fails.
        let diff = compare(&base, &cur(0.05, true, 10.0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("shedding below capacity"));
        // An exceeded queue bound fails.
        let diff = compare(&base, &cur(0.0, false, 10.0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("queue depth"));
        // p99 past the committed floor (relative tolerance) fails.
        let diff = compare(&base, &cur(0.0, true, 12.0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("p99 at 1x"));
        // No floor in the baseline: the structural gates still compare.
        let no_floor = Json::parse(r#"{"bench":"hotpath"}"#).unwrap();
        let diff = compare(&no_floor, &cur(0.0, true, 99.0), 0.15);
        assert_eq!(diff.compared, 2);
        assert!(diff.passes(), "{:?}", diff.regressions);
    }

    #[test]
    fn net_overload_gates_shed_depth_and_p99_floor() {
        let base = Json::parse(
            r#"{"bench":"hotpath","overload":{"p99_1x_ms":10.0,"net_p99_1x_ms":12.0}}"#,
        )
        .unwrap();
        let cur = |shed: f64, bounded: bool, p99: f64| {
            Json::parse(&format!(
                r#"{{"bench":"overload","net_shed_rate_1x":{shed},
                     "net_depth_bounded":{bounded},"net_p99_1x_ms":{p99}}}"#
            ))
            .unwrap()
        };
        // Clean run: all three network gates compared, none regress.
        let diff = compare(&base, &cur(0.0, true, 12.5), 0.15);
        assert_eq!(diff.compared, 3);
        assert!(diff.passes(), "{:?}", diff.regressions);
        // Shedding over the wire at 1x fails.
        let diff = compare(&base, &cur(0.04, true, 12.0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("network arm shed"));
        // A queue bound exceeded via the wire fails.
        let diff = compare(&base, &cur(0.0, false, 12.0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("wire bypassed"));
        // Client-observed p99 past the committed floor fails.
        let diff = compare(&base, &cur(0.0, true, 15.0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("network p99 at 1x"));
        // A --no-net run (keys absent) skips the network gates instead
        // of green-lighting them.
        let skipped = Json::parse(r#"{"bench":"overload","shed_rate_1x":0.0}"#).unwrap();
        let diff = compare(&base, &skipped, 0.15);
        assert!(diff.passes(), "{:?}", diff.regressions);
        assert!(!diff.lines.iter().any(|l| l.contains("net")));
        // No committed net floor: the structural net gates still fire.
        let no_floor =
            Json::parse(r#"{"bench":"hotpath","overload":{"p99_1x_ms":10.0}}"#).unwrap();
        let diff = compare(&no_floor, &cur(0.0, true, 999.0), 0.15);
        assert_eq!(diff.compared, 2);
        assert!(diff.passes(), "{:?}", diff.regressions);
    }

    #[test]
    fn net_decode_allocation_gate() {
        let with_net = |net: f64| {
            Json::parse(&format!(
                r#"{{"bench":"hotpath",
                     "allocs_per_request":{{"pooled":0.0,"net_decode":{net}}}}}"#
            ))
            .unwrap()
        };
        let base = with_net(0.0);
        assert!(compare(&base, &with_net(0.0), 0.15).passes());
        let diff = compare(&base, &with_net(1.0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions.iter().any(|r| r.contains("net_decode")));
        // A current file that dropped the key while the same-family
        // baseline still carries it is a silent-gate regression.
        let dropped = Json::parse(
            r#"{"bench":"hotpath","allocs_per_request":{"pooled":0.0}}"#,
        )
        .unwrap();
        let diff = compare(&base, &dropped, 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions.iter().any(|r| r.contains("net_decode")));
    }

    #[test]
    fn chaos_gates_availability_recovery_and_hangs() {
        let base =
            Json::parse(r#"{"bench":"hotpath","chaos":{"availability_floor":0.995}}"#)
                .unwrap();
        let cur = |avail: f64, err: f64, rec: bool, hung: u32| {
            Json::parse(&format!(
                r#"{{"bench":"chaos","chaos_availability_min":{avail},
                     "chaos_post_recovery_error_rate":{err},
                     "chaos_quarantined":true,"chaos_recovered":{rec},
                     "chaos_bit_identical":true,"chaos_hung":{hung}}}"#
            ))
            .unwrap()
        };
        // Clean run passes; the availability gate is the one compared.
        let diff = compare(&base, &cur(1.0, 0.0, true, 0), 0.15);
        assert_eq!(diff.compared, 1);
        assert!(diff.passes(), "{:?}", diff.regressions);
        // Availability under the committed floor fails.
        let diff = compare(&base, &cur(0.99, 0.0, true, 0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("availability"));
        // Baseline without a chaos section defaults the floor to 0.99.
        let no_floor = Json::parse(r#"{"bench":"hotpath"}"#).unwrap();
        assert!(compare(&no_floor, &cur(0.992, 0.0, true, 0), 0.15).passes());
        assert!(!compare(&no_floor, &cur(0.97, 0.0, true, 0), 0.15).passes());
        // Any post-recovery error fails.
        let diff = compare(&base, &cur(1.0, 0.01, true, 0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("post-recovery"));
        // A lost recovery fails (drift's `recovered` key is absent, so
        // only the chaos gate can have fired).
        let diff = compare(&base, &cur(1.0, 0.0, false, 0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("never closed its breaker"));
        // A hung reply fails.
        let diff = compare(&base, &cur(1.0, 0.0, true, 1), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("never arrived"));
        // A chaos-less current file trips none of the chaos gates.
        let hot = Json::parse(r#"{"bench":"hotpath","shed_rate_1x":0.0}"#).unwrap();
        let diff = compare(&base, &hot, 0.15);
        assert!(diff.passes(), "{:?}", diff.regressions);
        assert!(!diff.lines.iter().any(|l| l.contains("chaos")));
    }

    #[test]
    fn missing_gated_keys_regress_within_a_family_and_skip_across() {
        let base = Json::parse(
            r#"{"bench":"hotpath",
                "results":[{"name":"gemm:direct:128^3","median_s":1e-4}],
                "shard_scaling":[{"shards":1,"rps":100.0,"gflops":5.0}],
                "allocs_per_request":{"pooled":0.0}}"#,
        )
        .unwrap();
        // Same family, every gated key dropped: three named regressions
        // (the dropped result, the dropped scaling row, the dropped
        // alloc gate), each counted as compared.
        let cur = Json::parse(
            r#"{"bench":"hotpath",
                "results":[{"name":"renamed","median_s":1e-4}]}"#,
        )
        .unwrap();
        let diff = compare(&base, &cur, 0.15);
        assert!(!diff.passes());
        assert_eq!(diff.compared, 3, "{:?}", diff.lines);
        assert!(diff
            .regressions
            .iter()
            .any(|r| r.contains("gemm:direct:128^3") && r.contains("missing")));
        assert!(diff.regressions.iter().any(|r| r.contains("shards=1")));
        assert!(diff
            .regressions
            .iter()
            .any(|r| r.contains("pooled allocation gate missing")));
        // Different family (merged baseline vs a drift file): the
        // missing keys keep skipping and the drift gate alone compares.
        let drift = Json::parse(r#"{"bench":"drift","recovered":true}"#).unwrap();
        let diff = compare(&base, &drift, 0.15);
        assert_eq!(diff.compared, 1);
        assert!(diff.passes(), "{:?}", diff.regressions);
        // A key the *baseline* lacks never regresses: extra fresh
        // results are new coverage, not a diff.
        let wider = Json::parse(
            r#"{"bench":"hotpath",
                "results":[{"name":"gemm:direct:128^3","median_s":1e-4},
                           {"name":"extra","median_s":1.0}],
                "shard_scaling":[{"shards":1,"rps":100.0,"gflops":5.0}],
                "allocs_per_request":{"pooled":0.0,"simd_pooled":0.0}}"#,
        )
        .unwrap();
        assert!(compare(&base, &wider, 0.15).passes());
    }

    #[test]
    fn simd_gate_floors_per_shape_and_fused_speedup() {
        let base = Json::parse(
            r#"{"bench":"hotpath",
                "simd":{"speedup_floor":1.5,"fused_speedup_floor":1.2}}"#,
        )
        .unwrap();
        let cur = |s128: f64, s100: f64, fused: f64| {
            Json::parse(&format!(
                r#"{{"bench":"hotpath","simd":{{
                     "tier":"avx2","variant":"h_avx2_t8x8_u4",
                     "shapes":[
                       {{"shape":"128^3(m==mb)","scalar_s":1e-3,
                         "best_s":1e-4,"speedup":{s128}}},
                       {{"shape":"100^3(padded)","scalar_s":1e-3,
                         "best_s":1e-4,"speedup":{s100}}}],
                     "fused_speedup_vs_scalar":{fused}}}}}"#
            ))
            .unwrap()
        };
        // Both shapes and the fused path above their floors: passes,
        // and all three gates count as compared.
        let diff = compare(&base, &cur(2.0, 1.8, 1.5), 0.15);
        assert_eq!(diff.compared, 3);
        assert!(diff.passes(), "{:?}", diff.regressions);
        assert!(diff.lines.iter().any(|l| l.contains("tier avx2")));
        // One shape under its floor: fails and names the shape.
        let diff = compare(&base, &cur(2.0, 1.2, 1.5), 0.15);
        assert!(!diff.passes());
        assert!(
            diff.regressions[0].contains("100^3(padded)"),
            "{:?}",
            diff.regressions
        );
        // Fused path under its floor: fails.
        let diff = compare(&base, &cur(2.0, 1.8, 1.0), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions[0].contains("fused"));
        // Baseline without a simd section: floors default to 0.9, so a
        // scalar-tier run (speedups ~1.0) passes — the forced-fallback
        // CI leg must not trip the gate.
        let no_floor = Json::parse(r#"{"bench":"hotpath"}"#).unwrap();
        assert!(compare(&no_floor, &cur(0.97, 1.0, 0.95), 0.15).passes());
        assert!(!compare(&no_floor, &cur(0.5, 1.0, 0.95), 0.15).passes());
        // A simd-less current file trips nothing.
        let diff = compare(&base, &no_floor, 0.15);
        assert!(!diff.lines.iter().any(|l| l.contains("simd")));
    }

    #[test]
    fn simd_packed_gate_is_key_conditional_and_reports_runtime() {
        let base = Json::parse(
            r#"{"bench":"hotpath",
                "simd":{"speedup_floor":1.5,"fused_speedup_floor":1.2,
                        "packed_speedup_floor":1.0}}"#,
        )
        .unwrap();
        let cur = |packed: f64| {
            Json::parse(&format!(
                r#"{{"bench":"hotpath","simd_tier":"avx2","pack_enabled":true,
                     "simd":{{
                     "tier":"avx2","variant":"h_avx2_t8x8_u4",
                     "packed_variant":"h_avx2_t8x8_u4_p",
                     "shapes":[
                       {{"shape":"128^3(m==mb)","scalar_s":1e-3,
                         "best_s":1e-4,"speedup":2.0,
                         "best_packed_s":5e-5,"packed_speedup":{packed}}}],
                     "fused_speedup_vs_scalar":1.5,
                     "fused_packed_speedup_vs_scalar":1.6}}}}"#
            ))
            .unwrap()
        };
        // Packed above its floor: the packed row counts as compared and
        // the runner's capability fields are echoed in the gate output.
        let diff = compare(&base, &cur(1.3), 0.15);
        assert_eq!(diff.compared, 3); // speedup + packed_speedup + fused
        assert!(diff.passes(), "{:?}", diff.regressions);
        assert!(diff
            .lines
            .iter()
            .any(|l| l.contains("detected tier avx2, packing on")));
        assert!(diff.lines.iter().any(|l| l.contains("packed variant 1.30x")));
        // Packed under the floor: fails, naming the shape and echoing
        // the runner capabilities so a miss on an unusual runner is
        // explainable from the log alone.
        let diff = compare(&base, &cur(0.8), 0.15);
        assert!(!diff.passes());
        assert!(diff.regressions.iter().any(|r| r.contains("packed")
            && r.contains("128^3(m==mb)")
            && r.contains("packing on")));
        // A current file without packed keys (a pre-packing bench file,
        // or a pack-off leg) never trips the packed floor — only the
        // unconditional gates count.
        let unpacked = Json::parse(
            r#"{"bench":"hotpath","simd_tier":"avx2","pack_enabled":false,
                "simd":{"tier":"avx2","variant":"h_avx2_t8x8_u4",
                "shapes":[{"shape":"128^3(m==mb)","speedup":2.0}],
                "fused_speedup_vs_scalar":1.5}}"#,
        )
        .unwrap();
        let diff = compare(&base, &unpacked, 0.15);
        assert_eq!(diff.compared, 2);
        assert!(diff.passes(), "{:?}", diff.regressions);
        assert!(diff.lines.iter().any(|l| l.contains("packing off")));
    }

    #[test]
    fn capability_mismatch_warns_without_gating() {
        // Baseline recorded on an avx2 runner with packing on; current
        // run detected sse2 with packing off — both mismatches are
        // echoed as warning lines but never become regressions.
        let base = Json::parse(
            r#"{"bench":"hotpath","simd_tier":"avx2","pack_enabled":true,
                "simd":{"speedup_floor":0.5,"fused_speedup_floor":0.5}}"#,
        )
        .unwrap();
        let cur = Json::parse(
            r#"{"bench":"hotpath","simd_tier":"sse2","pack_enabled":false,
                "simd":{"tier":"sse2","variant":"h_sse2_t4x4_u2",
                "shapes":[{"shape":"128^3(m==mb)","speedup":1.1}],
                "fused_speedup_vs_scalar":1.0}}"#,
        )
        .unwrap();
        let diff = compare(&base, &cur, 0.15);
        assert!(diff.passes(), "{:?}", diff.regressions);
        assert!(diff
            .lines
            .iter()
            .any(|l| l.contains("WARNING: simd tier mismatch")
                && l.contains("avx2")
                && l.contains("sse2")));
        assert!(diff
            .lines
            .iter()
            .any(|l| l.contains("WARNING: packing mismatch")));
        // Matching capabilities (or a baseline without the fields — the
        // provisional/pre-simd case): no warnings.
        let same = compare(&cur, &cur, 0.15);
        assert!(!same.lines.iter().any(|l| l.contains("WARNING")));
        let old_base = Json::parse(
            r#"{"bench":"hotpath","simd":{"speedup_floor":0.5,
                "fused_speedup_floor":0.5}}"#,
        )
        .unwrap();
        let diff = compare(&old_base, &cur, 0.15);
        assert!(!diff.lines.iter().any(|l| l.contains("mismatch")));
    }

    #[test]
    fn disjoint_files_refuse_to_pass() {
        let a = Json::parse(r#"{"results":[{"name":"x","median_s":1.0}]}"#).unwrap();
        let b = Json::parse(r#"{"results":[{"name":"y","median_s":1.0}]}"#).unwrap();
        let diff = compare(&a, &b, 0.15);
        assert!(!diff.passes());
        assert_eq!(diff.compared, 0);
        // A provisional marker must not rescue an empty comparison.
        let a = Json::parse(
            r#"{"provisional":true,"results":[{"name":"x","median_s":1.0}]}"#,
        )
        .unwrap();
        let diff = compare(&a, &b, 0.15);
        assert!(diff.provisional);
        assert!(!diff.passes());
    }
}
