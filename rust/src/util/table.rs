//! ASCII table and chart rendering — every paper table/figure is
//! regenerated as an ASCII artifact (plus CSV) so `adaptd exp ...` output
//! is directly comparable with the paper.

/// Render a boxed ASCII table.
pub fn render(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncols, "row width mismatch in table '{title}'");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let total: usize = widths.iter().sum::<usize>() + 3 * ncols + 1;
    out.push_str(&format!("{title}\n"));
    out.push_str(&"=".repeat(total.min(120)));
    out.push('\n');
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            line.push_str(&format!(" {c:>w$} |", w = w));
        }
        line.push('\n');
        line
    };
    let header_cells: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&header_cells, &widths));
    out.push_str(&format!(
        "|{}\n",
        widths
            .iter()
            .map(|w| format!("{}-|", "-".repeat(w + 2)))
            .collect::<String>()
            .trim_end_matches('|')
    ));
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// Render a horizontal ASCII bar chart: one labelled bar per (label, value).
pub fn bar_chart(title: &str, series: &[(String, f64)], width: usize) -> String {
    let mut out = format!("{title}\n");
    let max = series.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = series.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    for (label, v) in series {
        let frac = if max > 0.0 { (v / max).clamp(0.0, 1.0) } else { 0.0 };
        let bars = (frac * width as f64).round() as usize;
        out.push_str(&format!(
            "{label:<label_w$} |{} {v:.3}\n",
            "#".repeat(bars),
        ));
    }
    out
}

/// Render multiple aligned series as grouped lines (for figure 6/7-style
/// per-triple GFLOPS comparisons): each x-label gets one row per series.
pub fn grouped_chart(
    title: &str,
    x_labels: &[String],
    series: &[(&str, Vec<f64>)],
    width: usize,
) -> String {
    let mut out = format!("{title}\n");
    let max = series
        .iter()
        .flat_map(|(_, v)| v.iter().copied())
        .fold(f64::MIN, f64::max);
    let name_w = series.iter().map(|(n, _)| n.len()).max().unwrap_or(0);
    for (i, x) in x_labels.iter().enumerate() {
        out.push_str(&format!("{x}\n"));
        for (name, vals) in series {
            let v = vals.get(i).copied().unwrap_or(0.0);
            let frac = if max > 0.0 { (v / max).clamp(0.0, 1.0) } else { 0.0 };
            let bars = (frac * width as f64).round() as usize;
            out.push_str(&format!(
                "  {name:<name_w$} |{} {v:.2}\n",
                "#".repeat(bars),
            ));
        }
    }
    out
}

/// Format a float with fixed decimals, trimming to a compact cell.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let s = render(
            "T",
            &["name", "v"],
            &[
                vec!["a".into(), "1".into()],
                vec!["longer".into(), "22".into()],
            ],
        );
        assert!(s.contains("| longer | 22 |"));
        assert!(s.contains("|      a |  1 |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_checks_width() {
        render("T", &["a", "b"], &[vec!["1".into()]]);
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let s = bar_chart(
            "B",
            &[("x".into(), 1.0), ("y".into(), 2.0)],
            10,
        );
        let lines: Vec<&str> = s.lines().collect();
        let count = |l: &str| l.matches('#').count();
        assert_eq!(count(lines[1]), 5);
        assert_eq!(count(lines[2]), 10);
    }

    #[test]
    fn grouped_chart_has_all_series() {
        let s = grouped_chart(
            "G",
            &["(1,1,1)".into()],
            &[("model", vec![2.0]), ("default", vec![1.0])],
            8,
        );
        assert!(s.contains("model") && s.contains("default"));
    }
}
