//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Every stochastic step in the framework (train/test splits, random
//! search, simulator measurement noise) draws from this generator so runs
//! are exactly reproducible from a seed — a requirement for regenerating
//! the paper's tables bit-for-bit across machines.

/// splitmix64: used to expand a single u64 seed into the xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Stateless deterministic hash → `[0, 1)` noise; used by the device
/// simulator so "measurement noise" is a pure function of
/// (device, config, triple) and tuning results are reproducible.
pub fn hash_noise(parts: &[u64]) -> f64 {
    let mut h: u64 = 0x51_7C_C1_B7_27_22_0A_95;
    for &p in parts {
        h ^= p;
        h = h.wrapping_mul(0x100_0000_01B3);
        h ^= h >> 33;
    }
    // splitmix64 finalizer for full avalanche on short inputs.
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^= h >> 31;
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle did nothing");
    }

    #[test]
    fn hash_noise_deterministic_and_spread() {
        assert_eq!(hash_noise(&[1, 2, 3]), hash_noise(&[1, 2, 3]));
        assert_ne!(hash_noise(&[1, 2, 3]), hash_noise(&[1, 2, 4]));
        let xs: Vec<f64> = (0..1000).map(|i| hash_noise(&[i])).collect();
        assert!(xs.iter().all(|x| (0.0..1.0).contains(x)));
        let mean = xs.iter().sum::<f64>() / 1000.0;
        assert!((mean - 0.5).abs() < 0.05);
    }
}
