//! Synchronization facade for the lock-free serving path.
//!
//! Every concurrency primitive used by the coordinator's lock-free
//! structures (`PolicyHandle` epoch swap, `CircuitBreaker` CAS machine,
//! admission reserve/rollback, depth gauges, fault-plan flags) is
//! imported from this module instead of `std::sync` directly.  In a
//! normal build the re-exports below are zero-cost aliases for the std
//! types — no wrapper, no indirection, nothing to optimize away.
//!
//! Under `--features model-check` the same names resolve to the modeled
//! primitives in [`crate::testing::interleave`]: each atomic operation
//! and mutex acquisition becomes a scheduling point for a deterministic
//! exhaustive-interleaving scheduler (DFS over thread schedules with
//! bounded preemptions and seeded replay).  `rust/tests/model_check.rs`
//! uses that mode to verify the serving-path invariants across *every*
//! interleaving within the preemption bound, instead of the handful a
//! stress test happens to hit.
//!
//! Memory-ordering note: the modeled atomics execute all operations
//! sequentially consistent, so the model checker explores thread
//! interleavings but not weak-memory reorderings.  `Ordering` arguments
//! are accepted and ignored in that mode; ThreadSanitizer in CI covers
//! the ordering-annotation side.

#[cfg(not(feature = "model-check"))]
pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};
#[cfg(not(feature = "model-check"))]
pub use std::sync::{Mutex, MutexGuard};

#[cfg(feature = "model-check")]
pub use crate::testing::interleave::{
    AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Mutex, MutexGuard,
};

// Ordering is always the std enum: the passthrough build forwards it
// verbatim and the modeled build accepts-and-ignores it (see above).
pub use std::sync::atomic::Ordering;

/// Capacity-bounded reservation gauge backing per-class admission.
///
/// The admission fast path must refuse work without taking a lock: a
/// reservation is a single `fetch_add`, and an over-capacity result is
/// rolled back with a `fetch_sub` before the caller observes it.  The
/// invariant the model checker holds this type to (`model_check.rs`,
/// invariant 3) is that `outstanding` never exceeds `capacity` *after*
/// a completed `try_reserve`, and that every refused reservation rolls
/// its increment back — transient overshoot mid-call is inherent to the
/// reserve/rollback protocol and is bounded by the number of racing
/// callers.
#[derive(Debug)]
pub struct AdmissionGauge {
    outstanding: AtomicUsize,
    capacity: usize,
}

impl AdmissionGauge {
    pub fn new(capacity: usize) -> Self {
        AdmissionGauge { outstanding: AtomicUsize::new(0), capacity }
    }

    /// Queue bound this gauge admits up to.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current reservation count (may transiently overshoot `capacity`
    /// while a racing `try_reserve` rolls back).
    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::Acquire)
    }

    /// Whether the gauge is at (or beyond) its bound right now.
    pub fn is_full(&self) -> bool {
        self.outstanding() >= self.capacity
    }

    /// Reserve one slot.  Returns the pre-reservation depth on success;
    /// `None` (after rolling the increment back) when the gauge is at
    /// capacity.
    pub fn try_reserve(&self) -> Option<usize> {
        let prev = self.outstanding.fetch_add(1, Ordering::AcqRel);
        if prev >= self.capacity {
            self.outstanding.fetch_sub(1, Ordering::AcqRel);
            return None;
        }
        Some(prev)
    }

    /// Release one previously reserved slot.
    pub fn release(&self) {
        self.outstanding.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn gauge_reserves_up_to_capacity() {
        let g = AdmissionGauge::new(2);
        assert_eq!(g.try_reserve(), Some(0));
        assert_eq!(g.try_reserve(), Some(1));
        assert!(g.is_full());
        assert_eq!(g.try_reserve(), None);
        assert_eq!(g.outstanding(), 2, "refusal must roll back");
        g.release();
        assert_eq!(g.try_reserve(), Some(1));
    }

    #[test]
    fn gauge_zero_capacity_refuses_everything() {
        let g = AdmissionGauge::new(0);
        assert!(g.is_full());
        assert_eq!(g.try_reserve(), None);
        assert_eq!(g.outstanding(), 0);
    }

    #[test]
    fn gauge_concurrent_reservations_respect_bound() {
        let g = Arc::new(AdmissionGauge::new(8));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let g = Arc::clone(&g);
            handles.push(std::thread::spawn(move || {
                let mut held = 0usize;
                for _ in 0..1000 {
                    if g.try_reserve().is_some() {
                        assert!(g.outstanding() <= 8 + 4, "beyond transient bound");
                        held += 1;
                        if held > 1 {
                            g.release();
                            held -= 1;
                        }
                    }
                }
                for _ in 0..held {
                    g.release();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(g.outstanding(), 0);
    }
}
