//! Statistics helpers for the benchmark harness and the metrics module:
//! summary statistics, percentiles, confidence intervals.

/// Summary statistics of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    /// All-zero summary of an empty sample — for aggregations that must
    /// stay total when nothing was measured (e.g. an idle server shard).
    pub fn empty() -> Summary {
        Summary {
            n: 0,
            mean: 0.0,
            stddev: 0.0,
            min: 0.0,
            max: 0.0,
            median: 0.0,
            p05: 0.0,
            p95: 0.0,
        }
    }

    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        // total_cmp: a NaN-bearing sample must never panic the sort (the
        // old partial_cmp(..).unwrap() did); NaNs order after +inf, so
        // they surface in `max` instead of crashing stat collection.
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p05: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }

    /// Half-width of the ~95% CI on the mean (normal approximation).
    pub fn ci95_half(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        1.96 * self.stddev / (self.n as f64).sqrt()
    }
}

/// Linear-interpolation percentile over a pre-sorted slice, `p` in
/// [0,100].  An empty sample has no percentile: returns NaN (documented,
/// like [`mean`]) instead of the old `assert!` panic, so aggregation
/// paths stay total.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted sample.  NaN-total: NaN inputs sort last
/// (`f64::total_cmp`) rather than panicking the comparator, and an empty
/// sample returns NaN.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    percentile_sorted(&sorted, p)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Median-absolute-deviation outlier filter: keeps values within
/// `k` MADs of the median (criterion-style robust filtering).
pub fn filter_outliers(xs: &[f64], k: f64) -> Vec<f64> {
    if xs.len() < 4 {
        return xs.to_vec();
    }
    let med = percentile(xs, 50.0);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    let mad = percentile(&devs, 50.0);
    if mad == 0.0 {
        return xs.to_vec();
    }
    xs.iter().copied().filter(|x| (x - med).abs() <= k * mad).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_zeroed() {
        let s = Summary::empty();
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.max, 0.0);
        assert_eq!(s.ci95_half(), 0.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.ci95_half(), 0.0);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 0.5]);
        assert!((g - 1.0).abs() < 1e-12);
    }

    #[test]
    fn outlier_filter_removes_spike() {
        let mut xs = vec![10.0; 20];
        xs.push(1000.0);
        // Perturb so MAD > 0.
        for (i, x) in xs.iter_mut().enumerate() {
            *x += (i as f64) * 0.01;
        }
        let kept = filter_outliers(&xs, 5.0);
        assert!(kept.len() >= 19 && !kept.contains(&1000.2));
    }

    #[test]
    fn mean_empty_is_nan() {
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // Regression: the old partial_cmp(..).unwrap() comparator
        // panicked on any NaN in the sample.  total_cmp orders NaN last,
        // so finite percentiles stay meaningful and nothing crashes.
        let xs = [10.0, f64::NAN, 20.0, 30.0];
        let p0 = percentile(&xs, 0.0);
        assert_eq!(p0, 10.0);
        assert!(percentile(&xs, 100.0).is_nan(), "NaN sorts last");
        // A NaN-bearing Summary is computed, not a panic.
        let s = Summary::of(&xs);
        assert_eq!(s.min, 10.0);
        assert!(s.max.is_nan());
    }

    #[test]
    fn percentile_of_empty_is_nan_not_panic() {
        assert!(percentile_sorted(&[], 50.0).is_nan());
        assert!(percentile(&[], 99.0).is_nan());
    }
}
