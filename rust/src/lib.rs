//! # adaptlib — model-driven adaptive GEMM library
//!
//! A production-shaped reproduction of *"A model-driven approach for a new
//! generation of adaptive libraries"* (Cianfriglia, Vella, Nugteren,
//! Lokhmotov, Fursin — 2018): an adaptive BLAS-GEMM library that selects the
//! best kernel + tuning configuration per input `(M, N, K)` with a trained
//! decision tree, code-generated into the library as an if-then-else
//! selector.
//!
//! The stack has three layers (see `DESIGN.md`):
//!
//! * **L1** — parametric Pallas GEMM kernels (`python/compile/kernels/`),
//!   AOT-lowered to HLO text artifacts;
//! * **L2** — JAX GEMM graphs per (kernel, config, shape) (`python/compile/`);
//! * **L3** — this crate: the whole off-line framework (search-space model,
//!   device performance simulator, CLTune-equivalent tuner, dataset
//!   generators, CART decision-tree trainer, code generator, metrics) plus
//!   the on-line adaptive library (PJRT runtime, model-driven dispatcher,
//!   batching request coordinator).
//!
//! Python never runs on the request path: artifacts are produced once by
//! `make artifacts`, after which the `adaptd` binary is self-contained.

// Every `unsafe` operation must be explicit even inside `unsafe fn`
// (the SIMD microkernels carry per-block `// SAFETY:` contracts that
// `adaptd lint` enforces), and every public type must be debuggable.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(missing_debug_implementations)]

pub mod analysis;
pub mod cli;
pub mod codegen;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod device;
pub mod dtree;
pub mod engine;
pub mod experiments;
pub mod harness;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod testing;
pub mod tuner;
pub mod util;

pub use config::{
    DirectParams, HostParams, KernelConfig, KernelKind, SimdTier, Triple, XgemmParams,
};
pub use dataset::{Dataset, DatasetKind};
pub use device::{DeviceId, DeviceProfile};
pub use engine::{
    EngineSpec, ExecutionEngine, FaultInjector, FaultKind, FaultPlan, RuntimeEngine, SimEngine,
};
pub use dtree::DecisionTree;
pub use metrics::ModelScores;
