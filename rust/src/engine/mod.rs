//! Execution engines: the device-abstraction seam between the coordinator
//! and whatever actually computes a GEMM.
//!
//! The paper's central claim is that one adaptive library must select
//! *different* kernels on different architectures (3x on Pascal, 2.5x on
//! Mali).  Serving-side, that requires the coordinator to speak to more
//! than one device — so execution hides behind [`ExecutionEngine`]:
//!
//! * [`RuntimeEngine`] — the real path: wraps [`GemmRuntime`] (CPU PJRT
//!   client + AOT artifacts).  A pure delegation layer with **zero
//!   behavior change**: the pooled path stays allocation-free and
//!   bit-identical to calling `gemm_pooled` directly.
//! * [`SimEngine`] — makes the paper's P100 / Mali-T860 first-class
//!   *serveable* devices: results are computed with the host reference
//!   kernel (so correctness is exact), while the reported [`GemmTimes`]
//!   charge the wall-time of the analytical device model in
//!   [`device::sim`] — the same model the offline tuner measures against,
//!   so online telemetry and offline oracles agree by construction.
//!
//! Engines are built *on the shard thread that owns them* (PJRT handles
//! never cross threads), so the coordinator passes a cloneable
//! [`EngineSpec`] to each shard instead of a live engine.

pub mod fault;

pub use fault::{FaultInjector, FaultKind, FaultPlan, FaultSpec};

use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::config::{KernelConfig, Triple};
use crate::device::{microkernel, sim, DeviceId, DeviceProfile};
use crate::runtime::{
    host_gemm_into, ArtifactId, BatchScratch, GemmInput, GemmRuntime, GemmTimes,
    Manifest, ScratchBuffers,
};

/// A device-class execution backend for the serving coordinator.
///
/// The contract mirrors the pooled hot path: selection resolves a policy
/// config to a dense [`ArtifactId`] ([`resolve`](Self::resolve)), shadow
/// runs pre-compile outside the measurement
/// ([`ensure_ready`](Self::ensure_ready)), and execution lands the result
/// in caller-held [`ScratchBuffers`] with zero steady-state allocations
/// ([`execute_pooled`](Self::execute_pooled)).
pub trait ExecutionEngine {
    /// The device class this engine executes on.
    fn device(&self) -> DeviceId;

    /// The artifact/config roster this engine serves from.
    fn manifest(&self) -> &Manifest;

    /// Device-level legality of an artifact beyond shape eligibility
    /// (e.g. a config whose work-group exceeds the device's limit).
    fn is_servable(&self, id: ArtifactId) -> bool;

    /// Prepare an artifact for execution (compile on the real path; no-op
    /// for the analytical engines).  Shadow execution calls this outside
    /// its measurement, like the served path does.
    fn ensure_ready(&mut self, id: ArtifactId) -> Result<()>;

    /// Execute into the caller's scratch pool (result in `scratch.out`),
    /// reporting the §5.4-attributed timing.  The real serving path
    /// ([`RuntimeEngine`]) performs zero steady-state heap allocations
    /// through this method — the `hotpath` bench gates that through the
    /// trait; [`SimEngine`] trades that for exactness (the host
    /// reference kernel allocates its accumulator).
    fn execute_pooled(
        &mut self,
        id: ArtifactId,
        input: &GemmInput,
        scratch: &mut ScratchBuffers,
    ) -> Result<GemmTimes>;

    /// Execute a *fused batch* of same-`(artifact, m, n, k)` requests
    /// into `batch`: stacked slot-major results in `batch.out`, per-slot
    /// §5.4 timings in `batch.times` (fusion amortization excluded, so
    /// telemetry sampled from a fused slot stays comparable to un-fused
    /// oracle measurements), and the per-dispatch cost the fusion
    /// avoided in `batch.saved`.
    ///
    /// The default is the sequential fallback — `execute_pooled` per
    /// slot through `batch.seq` — so any engine is correct without
    /// opting in.  [`RuntimeEngine`] overrides with the native
    /// [`GemmRuntime::gemm_batch_pooled`] stacked-staging path
    /// (bit-identical per slot, zero steady-state allocations);
    /// [`SimEngine`] keeps the exact sequential results but charges the
    /// modeled per-dispatch saving to `batch.saved`
    /// ([`sim::dispatch_overhead_secs`] for every slot after the first).
    fn execute_batch_pooled(
        &mut self,
        id: ArtifactId,
        inputs: &[GemmInput],
        batch: &mut BatchScratch,
    ) -> Result<()> {
        sequential_batch(self, id, inputs, batch)
    }

    /// The modeled-cheapest servable artifact accepting `t` on
    /// `profile` ([`sim::modeled_secs`]), with its modeled seconds —
    /// the candidate scan behind the coordinator's overload *pressure
    /// pick* (swap a queue-pressured request's selection for the
    /// cheapest artifact within a slowdown bound).  Allocation-free:
    /// one pass over the small immutable manifest, pure arithmetic per
    /// candidate.
    fn modeled_cheapest(
        &self,
        profile: &DeviceProfile,
        t: Triple,
    ) -> Option<(ArtifactId, f64)> {
        let m = self.manifest();
        let mut best: Option<(ArtifactId, f64)> = None;
        for id in (0..m.len() as u32).map(ArtifactId) {
            if !self.is_servable(id) || !m.meta(id).accepts(t) {
                continue;
            }
            let Some(secs) = sim::modeled_secs(profile, &m.meta(id).config, t) else {
                continue;
            };
            if best.is_none_or(|(_, b)| secs < b) {
                best = Some((id, secs));
            }
        }
        best
    }

    /// Resolve a policy-selected config to the least-waste *servable*
    /// artifact for `t`, falling back to any servable artifact accepting
    /// `t` (least waste) when the config has none — the dispatcher's
    /// selection → artifact step, now device-legality-aware.
    /// Allocation-free: two passes over the small immutable manifest.
    fn resolve(&self, cfg: &KernelConfig, t: Triple) -> Option<ArtifactId> {
        let m = self.manifest();
        m.artifact_id_for_config(cfg, t)
            .filter(|id| self.is_servable(*id))
            .or_else(|| {
                (0..m.len() as u32)
                    .map(ArtifactId)
                    .filter(|id| self.is_servable(*id) && m.meta(*id).accepts(t))
                    .min_by(|a, b| {
                        m.meta(*a)
                            .waste(t)
                            .partial_cmp(&m.meta(*b).waste(t))
                            .unwrap()
                    })
            })
    }
}

/// The sequential fused-batch fallback: `execute_pooled` per slot
/// through `batch.seq`, slot results stacked into `batch.out`.  Shared
/// by the trait default and engines that only override the timing
/// attribution ([`SimEngine`]).  `batch.saved` is left at zero — a
/// sequential execution amortizes nothing.
pub fn sequential_batch<E: ExecutionEngine + ?Sized>(
    engine: &mut E,
    id: ArtifactId,
    inputs: &[GemmInput],
    batch: &mut BatchScratch,
) -> Result<()> {
    batch.out.clear();
    batch.times.clear();
    batch.saved = Duration::ZERO;
    let Some(first) = inputs.first() else { return Ok(()) };
    let t = first.triple();
    for input in inputs {
        if input.triple() != t {
            bail!("fused batch mixes triples: {} vs {t}", input.triple());
        }
    }
    for input in inputs {
        let times = engine.execute_pooled(id, input, &mut batch.seq)?;
        batch.out.extend_from_slice(&batch.seq.out);
        batch.times.push(times);
    }
    Ok(())
}

/// The real execution path: the CPU PJRT runtime over the AOT artifacts,
/// behind the engine trait.  Every method delegates; the pooled path is
/// bit-identical to `GemmRuntime::gemm_pooled` and allocation-free at
/// steady state (the `hotpath` bench gates this through the trait).
pub struct RuntimeEngine {
    runtime: GemmRuntime,
}

impl std::fmt::Debug for RuntimeEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RuntimeEngine").finish_non_exhaustive()
    }
}

impl RuntimeEngine {
    pub fn open(dir: &Path) -> Result<RuntimeEngine> {
        Ok(RuntimeEngine { runtime: GemmRuntime::open(dir)? })
    }

    /// The wrapped runtime (diagnostics: compile time, cache stats).
    pub fn runtime(&self) -> &GemmRuntime {
        &self.runtime
    }
}

impl ExecutionEngine for RuntimeEngine {
    fn device(&self) -> DeviceId {
        DeviceId::HostCpu
    }

    fn manifest(&self) -> &Manifest {
        &self.runtime.manifest
    }

    fn is_servable(&self, id: ArtifactId) -> bool {
        // Every PJRT roster artifact was AOT-compiled for this host; a
        // host microkernel variant additionally requires its instruction
        // tier to be at or below what runtime feature detection found,
        // and — for packed variants — the packed path not to be forced
        // off (`ADAPTLIB_PACK=off`).  Both gates are OnceLock-cached:
        // this runs per request on the zero-alloc hot path.
        if (id.0 as usize) >= self.runtime.manifest.len() {
            return false;
        }
        match self.runtime.manifest.meta(id).config {
            KernelConfig::HostSimd(p) => {
                microkernel::tier_supported(p.tier)
                    && (!p.packed || microkernel::pack_enabled())
            }
            KernelConfig::Xgemm(_) | KernelConfig::Direct(_) => true,
        }
    }

    fn ensure_ready(&mut self, id: ArtifactId) -> Result<()> {
        self.runtime.ensure_compiled_id(id)
    }

    fn execute_pooled(
        &mut self,
        id: ArtifactId,
        input: &GemmInput,
        scratch: &mut ScratchBuffers,
    ) -> Result<GemmTimes> {
        self.runtime.gemm_pooled(id, input, scratch)
    }

    fn execute_batch_pooled(
        &mut self,
        id: ArtifactId,
        inputs: &[GemmInput],
        batch: &mut BatchScratch,
    ) -> Result<()> {
        self.runtime.gemm_batch_pooled(id, inputs, batch)
    }
}

/// Analytical device engine: serves a [`DeviceProfile`] (P100 / Mali) by
/// computing the *result* with the host reference kernel — so served
/// outputs are exact — while charging the *time* of the analytical model
/// (`device::sim`), the substitute for the OpenCL hardware we do not have.
/// Telemetry sampled from this engine therefore carries the same timing
/// landscape the offline tuner sweeps, and per-device adaptation
/// converges against the same oracle.
pub struct SimEngine {
    profile: DeviceProfile,
    manifest: Manifest,
    /// Device legality per artifact, precomputed at open.
    servable: Vec<bool>,
}

impl std::fmt::Debug for SimEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SimEngine").finish_non_exhaustive()
    }
}

impl SimEngine {
    pub fn open(dir: &Path, device: DeviceId) -> Result<SimEngine> {
        Ok(SimEngine::new(DeviceProfile::get(device), Manifest::load(dir)?))
    }

    /// Build from already-loaded parts (tests, tools).
    pub fn new(profile: DeviceProfile, manifest: Manifest) -> SimEngine {
        let servable = manifest
            .artifacts
            .iter()
            .map(|a| profile.is_legal(&a.config))
            .collect();
        SimEngine { profile, manifest, servable }
    }

    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }
}

impl ExecutionEngine for SimEngine {
    fn device(&self) -> DeviceId {
        self.profile.id
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn is_servable(&self, id: ArtifactId) -> bool {
        self.servable.get(id.0 as usize).copied().unwrap_or(false)
    }

    fn ensure_ready(&mut self, id: ArtifactId) -> Result<()> {
        if (id.0 as usize) >= self.manifest.len() {
            bail!(
                "artifact id {} out of range for this roster ({} artifacts)",
                id.0,
                self.manifest.len()
            );
        }
        Ok(())
    }

    fn execute_pooled(
        &mut self,
        id: ArtifactId,
        input: &GemmInput,
        scratch: &mut ScratchBuffers,
    ) -> Result<GemmTimes> {
        input.validate()?;
        self.ensure_ready(id)?;
        let meta = self.manifest.meta(id);
        let t = input.triple();
        if !meta.accepts(t) {
            bail!("artifact '{}' does not accept {t}", meta.name);
        }
        if !self.is_servable(id) {
            bail!(
                "config {} is illegal on {} (work-group/local-memory limits)",
                meta.config.name(),
                self.profile.id
            );
        }
        // Modeled wall-time of the device running this config on this
        // triple; the model already folds the helper passes and launch
        // overhead in, so everything lands in kernel_time.
        let secs = sim::modeled_secs(&self.profile, &meta.config, t)
            .ok_or_else(|| anyhow!("config not measurable on {}", self.profile.id))?;
        // Exact result via the host reference kernel.  The output buffer
        // reuses its capacity at steady state; the kernel itself keeps a
        // per-call f64 accumulator (and fans out over row bands for big
        // problems), so unlike the real engine this path is *not*
        // allocation-free — exactness over the zero-alloc contract.
        scratch.out.clear();
        scratch.out.resize(input.m * input.n, 0.0);
        host_gemm_into(input, &mut scratch.out);
        Ok(GemmTimes {
            helper_time: Duration::ZERO,
            kernel_time: Duration::from_secs_f64(secs),
        })
    }

    /// Exact sequential results; per-slot times stay the *unamortized*
    /// modeled wall-time (so telemetry and per-device oracles keep
    /// agreeing per request), while the fusion's modeled benefit — the
    /// per-dispatch launch/helper-launch cost every slot after the
    /// first shares with the first — is charged to `batch.saved`.
    fn execute_batch_pooled(
        &mut self,
        id: ArtifactId,
        inputs: &[GemmInput],
        batch: &mut BatchScratch,
    ) -> Result<()> {
        sequential_batch(self, id, inputs, batch)?;
        if inputs.len() > 1 {
            let overhead =
                sim::dispatch_overhead_secs(&self.profile, &self.manifest.meta(id).config);
            batch.saved =
                Duration::from_secs_f64(overhead * (inputs.len() - 1) as f64);
        }
        Ok(())
    }
}

/// How to build an engine — `Clone + Send`, so the coordinator can hand
/// one to each shard thread and let the shard construct its engine
/// locally (PJRT clients are created on, and never leave, their thread).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineSpec {
    /// The real CPU PJRT runtime.
    Runtime,
    /// Analytical engine for a simulated device profile.
    Sim(DeviceId),
}

impl EngineSpec {
    /// The natural engine for a device class: the host CPU is the one
    /// device we physically have; everything else is simulated.
    pub fn for_device(device: DeviceId) -> EngineSpec {
        match device {
            DeviceId::HostCpu => EngineSpec::Runtime,
            other => EngineSpec::Sim(other),
        }
    }

    pub fn device(&self) -> DeviceId {
        match self {
            EngineSpec::Runtime => DeviceId::HostCpu,
            EngineSpec::Sim(d) => *d,
        }
    }

    /// Build the engine (call on the owning shard thread).
    pub fn build(&self, artifacts: &Path) -> Result<Box<dyn ExecutionEngine>> {
        Ok(match self {
            EngineSpec::Runtime => Box::new(RuntimeEngine::open(artifacts)?),
            EngineSpec::Sim(d) => Box::new(SimEngine::open(artifacts, *d)?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::sample_manifest;

    fn sim(device: DeviceId) -> SimEngine {
        SimEngine::new(DeviceProfile::get(device), sample_manifest())
    }

    #[test]
    fn sim_engine_serves_exact_host_results_and_charges_modeled_time() {
        let mut eng = sim(DeviceId::NvidiaP100);
        let (m, n, k) = (64usize, 64usize, 64usize);
        let a = vec![0.5f32; m * k];
        let b = vec![1.0f32; k * n];
        let c = vec![0.0f32; m * n];
        let input = GemmInput { m, n, k, a: &a, b: &b, c: &c, alpha: 1.0, beta: 0.0 };
        let id = eng.manifest().id_of("d1").unwrap();
        let mut scratch = ScratchBuffers::new();
        let times = eng.execute_pooled(id, &input, &mut scratch).unwrap();
        assert_eq!(scratch.out.len(), m * n);
        assert!((scratch.out[0] - 32.0).abs() < 1e-4, "{}", scratch.out[0]);
        // The charged time is the analytical model's, exactly.
        let cfg = eng.manifest().meta(id).config;
        let expect = sim::modeled_secs(eng.profile(), &cfg, input.triple()).unwrap();
        let got = times.total_time().as_secs_f64();
        assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
    }

    #[test]
    fn sim_engine_rejects_illegal_config_on_device() {
        // i2's 32x32 work-group (1024) exceeds Mali's 256 limit.
        let mut eng = sim(DeviceId::MaliT860);
        let id = eng.manifest().id_of("i2").unwrap();
        assert!(!eng.is_servable(id));
        let a = vec![0.0f32; 4];
        let input = GemmInput {
            m: 2, n: 2, k: 2,
            a: &a, b: &a, c: &a,
            alpha: 1.0, beta: 0.0,
        };
        let mut scratch = ScratchBuffers::new();
        let err = eng.execute_pooled(id, &input, &mut scratch);
        assert!(err.is_err());
        // On the P100 the same artifact is fine.
        assert!(sim(DeviceId::NvidiaP100).is_servable(id));
    }

    #[test]
    fn resolve_falls_back_to_device_legal_artifacts() {
        let eng = sim(DeviceId::MaliT860);
        let t = Triple::new(200, 200, 200);
        // The policy asks for i2's config (illegal on Mali); the only
        // artifact accepting 200^3 is i2, so resolution must fail rather
        // than hand the device an illegal artifact.
        let cfg = eng.manifest().find("i2").unwrap().config;
        assert_eq!(eng.resolve(&cfg, t), None);
        // In-bucket shape: falls back to the legal 128-bucket artifact.
        let t = Triple::new(100, 100, 100);
        let id = eng.resolve(&cfg, t).unwrap();
        assert_eq!(eng.manifest().name_of(id), "i1");
        // On the P100, the same request resolves to the asked config.
        let p100 = sim(DeviceId::NvidiaP100);
        let id = p100.resolve(&cfg, Triple::new(200, 200, 200)).unwrap();
        assert_eq!(p100.manifest().name_of(id), "i2");
    }

    #[test]
    fn modeled_cheapest_is_the_servable_argmin() {
        let eng = sim(DeviceId::NvidiaP100);
        let profile = DeviceProfile::nvidia_p100();
        let t = Triple::new(64, 64, 64); // every artifact accepts it
        let (best, best_secs) = eng.modeled_cheapest(&profile, t).unwrap();
        // Exhaustive check: nothing servable models faster.
        for a in &eng.manifest().artifacts {
            if let Some(secs) = sim::modeled_secs(&profile, &a.config, t) {
                assert!(best_secs <= secs, "{} beats the returned pick", a.name);
            }
        }
        assert!(eng.is_servable(best));
        // On the Mali the 1024-thread i2 is not servable: even when it
        // is the only artifact accepting 200^3, it must not be picked.
        let mali = sim(DeviceId::MaliT860);
        let mali_profile = DeviceProfile::mali_t860();
        assert_eq!(mali.modeled_cheapest(&mali_profile, Triple::new(200, 200, 200)), None);
        // In-bucket shapes pick among the legal subset only.
        let (id, _) = mali
            .modeled_cheapest(&mali_profile, Triple::new(100, 100, 100))
            .unwrap();
        assert!(mali.is_servable(id));
    }

    #[test]
    fn host_variants_never_servable_on_sim_devices() {
        let mut m = sample_manifest();
        m.expand_host_variants();
        for dev in [DeviceId::NvidiaP100, DeviceId::MaliT860] {
            let eng = SimEngine::new(DeviceProfile::get(dev), m.clone());
            let mut saw_variant = false;
            for (i, a) in eng.manifest().artifacts.iter().enumerate() {
                if matches!(a.config, KernelConfig::HostSimd(_)) {
                    saw_variant = true;
                    assert!(
                        !eng.is_servable(ArtifactId(i as u32)),
                        "{} servable on {dev}",
                        a.name
                    );
                }
            }
            assert!(saw_variant, "expansion added no variants");
            // A policy asking for a variant config on a sim device falls
            // back to a device-legal artifact instead of failing.
            let p = crate::config::host_variants()[0];
            let t = Triple::new(100, 100, 100);
            let id = eng.resolve(&KernelConfig::HostSimd(p), t).unwrap();
            assert!(!matches!(
                eng.manifest().meta(id).config,
                KernelConfig::HostSimd(_)
            ));
        }
    }

    #[test]
    fn sim_batch_is_bit_identical_with_unamortized_times_and_modeled_saving() {
        let mut eng = sim(DeviceId::NvidiaP100);
        let id = eng.manifest().id_of("i1").unwrap();
        let (m, n, k) = (100usize, 100usize, 100usize);
        let mut rng = crate::util::prng::Rng::new(0xF05E);
        let gen = |rng: &mut crate::util::prng::Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.f32() - 0.5).collect()
        };
        let operands: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..3)
            .map(|_| (gen(&mut rng, m * k), gen(&mut rng, k * n), gen(&mut rng, m * n)))
            .collect();
        let inputs: Vec<GemmInput> = operands
            .iter()
            .map(|(a, b, c)| GemmInput {
                m, n, k,
                a, b, c,
                alpha: 1.25, beta: -0.5,
            })
            .collect();
        // Sequential reference.
        let mut scratch = ScratchBuffers::new();
        let mut solo_out = Vec::new();
        let mut solo_times = Vec::new();
        for input in &inputs {
            solo_times.push(eng.execute_pooled(id, input, &mut scratch).unwrap());
            solo_out.push(scratch.out.clone());
        }
        // Fused: exact per-slot results, unamortized per-slot times.
        let mut batch = BatchScratch::new();
        eng.execute_batch_pooled(id, &inputs, &mut batch).unwrap();
        assert_eq!(batch.times.len(), 3);
        for (slot, (out, times)) in solo_out.iter().zip(&solo_times).enumerate() {
            assert_eq!(batch.slot(slot, m, n), out.as_slice(), "slot {slot}");
            assert_eq!(batch.times[slot].total_time(), times.total_time());
        }
        // The modeled per-dispatch saving: slots 1..3 share the first
        // slot's launch + helper-pass launches.
        let cfg = eng.manifest().meta(id).config;
        let overhead = sim::dispatch_overhead_secs(eng.profile(), &cfg);
        let expect = Duration::from_secs_f64(2.0 * overhead);
        assert_eq!(batch.saved, expect);
        // A single-slot "batch" amortizes nothing.
        eng.execute_batch_pooled(id, &inputs[..1], &mut batch).unwrap();
        assert_eq!(batch.saved, Duration::ZERO);
        assert_eq!(batch.slot(0, m, n), solo_out[0].as_slice());
        // Mixed triples are a caller bug and fail loudly.
        let small_a = vec![0.5f32; 64 * 64];
        let mixed = vec![
            inputs[0].clone(),
            GemmInput {
                m: 64, n: 64, k: 64,
                a: &small_a, b: &small_a, c: &small_a,
                alpha: 1.0, beta: 0.0,
            },
        ];
        let err = eng.execute_batch_pooled(id, &mixed, &mut batch).unwrap_err();
        assert!(err.to_string().contains("mixes triples"), "{err}");
        // An empty batch is a no-op.
        eng.execute_batch_pooled(id, &[], &mut batch).unwrap();
        assert!(batch.out.is_empty() && batch.times.is_empty());
    }

    #[test]
    fn engine_spec_maps_devices() {
        assert_eq!(EngineSpec::for_device(DeviceId::HostCpu), EngineSpec::Runtime);
        assert_eq!(
            EngineSpec::for_device(DeviceId::MaliT860),
            EngineSpec::Sim(DeviceId::MaliT860)
        );
        for d in DeviceId::all() {
            assert_eq!(EngineSpec::for_device(d).device(), d);
        }
    }

    #[test]
    fn sim_engine_validates_operands_and_shape() {
        let mut eng = sim(DeviceId::NvidiaP100);
        let id = eng.manifest().id_of("d1").unwrap();
        let a = vec![0.0f32; 3];
        let bad = GemmInput {
            m: 2, n: 2, k: 2,
            a: &a, b: &a, c: &a,
            alpha: 1.0, beta: 0.0,
        };
        let mut scratch = ScratchBuffers::new();
        assert!(eng.execute_pooled(id, &bad, &mut scratch).is_err());
        // Exact-shape direct artifact rejects other triples.
        let a = vec![0.0f32; 9];
        let wrong = GemmInput {
            m: 3, n: 3, k: 3,
            a: &a, b: &a, c: &a,
            alpha: 1.0, beta: 0.0,
        };
        assert!(eng.execute_pooled(id, &wrong, &mut scratch).is_err());
    }
}
