//! Deterministic fault injection at the engine seam.
//!
//! [`FaultInjector`] wraps any [`ExecutionEngine`] and applies a seeded
//! [`FaultPlan`] to every dispatch: per-triple transient error rates,
//! sticky fail-after-N scripts, latency spikes, and an external
//! kill/revive switch.  Decisions are a pure function of the plan seed
//! and a *shared* execution counter (one [`PlanState`] per plan, shared
//! across every clone handed to the class's shards), so a scenario
//! replays identically regardless of how requests interleave across
//! shard threads — the chaos experiment and the breaker/failover tests
//! exercise every failure mode below without real broken hardware.
//!
//! Injected failures surface as ordinary `Err` values from
//! `execute_pooled` / `execute_batch_pooled` (message prefixed with
//! `"injected fault"`), indistinguishable from a real device fault to
//! the coordinator — which is the point.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::Triple;
use crate::device::DeviceId;
use crate::runtime::{ArtifactId, BatchScratch, GemmInput, GemmTimes, Manifest, ScratchBuffers};
use crate::util::prng::splitmix64;
use crate::util::sync::{AtomicBool, AtomicU64, Ordering};

use super::ExecutionEngine;

/// One failure mode a [`FaultSpec`] can inject.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Each matching dispatch fails independently with probability
    /// `rate` (deterministic given the plan seed and the shared
    /// dispatch index).
    Transient { rate: f64 },
    /// The device dies for good after `after` matching dispatches: the
    /// plan's sticky switch flips and *every* subsequent dispatch fails
    /// until [`FaultPlan::revive`].
    StickyAfter { after: u64 },
    /// Each matching dispatch is slowed by `extra` with probability
    /// `rate` — the result is still correct, only the reported kernel
    /// time degrades (first slot of a fused dispatch carries the
    /// stall).
    LatencySpike { rate: f64, extra: Duration },
}

/// A failure mode scoped to a triple (`None` = every shape on the
/// device).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub triple: Option<Triple>,
    pub kind: FaultKind,
}

/// State shared by every clone of one plan: the dispatch counter that
/// makes transient decisions deterministic fleet-wide, and the sticky
/// down switch.
#[derive(Debug, Default)]
struct PlanState {
    dispatches: AtomicU64,
    down: AtomicBool,
}

/// A seeded, cloneable fault script for one device class.  Clones share
/// state: killing the plan kills every shard wrapping it.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    specs: Arc<Vec<FaultSpec>>,
    state: Arc<PlanState>,
}

/// What the plan decided for one dispatch.
enum Verdict {
    Pass,
    Delay(Duration),
    Fail(&'static str),
}

impl FaultPlan {
    /// A plan with no scripted faults — useful as a pure kill switch.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, specs: Arc::new(Vec::new()), state: Arc::default() }
    }

    /// Add a scripted fault (builder-style).
    pub fn with_fault(mut self, triple: Option<Triple>, kind: FaultKind) -> FaultPlan {
        Arc::make_mut(&mut self.specs).push(FaultSpec { triple, kind });
        self
    }

    /// Flip the sticky switch: every dispatch fails from now on.
    pub fn kill_now(&self) {
        self.state.down.store(true, Ordering::Release);
    }

    /// Clear the sticky switch (the device "comes back").
    pub fn revive(&self) {
        self.state.down.store(false, Ordering::Release);
    }

    pub fn is_down(&self) -> bool {
        self.state.down.load(Ordering::Acquire)
    }

    /// Matching dispatches observed across every clone.
    pub fn dispatches(&self) -> u64 {
        // RELAXED: monotonic dispatch counter; assertions only compare
        // totals after the fleet has quiesced.
        self.state.dispatches.load(Ordering::Relaxed)
    }

    /// Deterministic uniform draw in `[0, 1)` for dispatch `n` of spec
    /// `salt`.
    fn roll(&self, n: u64, salt: u64) -> f64 {
        let mut s = self
            .seed
            .wrapping_add(n.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(salt.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    fn decide(&self, t: Triple) -> Verdict {
        // RELAXED: the ticket only needs to be unique per dispatch, not
        // ordered against the `down` flag read below (which is Acquire).
        let n = self.state.dispatches.fetch_add(1, Ordering::Relaxed);
        if self.state.down.load(Ordering::Acquire) {
            return Verdict::Fail("sticky fault: device is down");
        }
        for (i, spec) in self.specs.iter().enumerate() {
            if spec.triple.is_some_and(|st| st != t) {
                continue;
            }
            match spec.kind {
                FaultKind::Transient { rate } => {
                    if self.roll(n, i as u64) < rate {
                        return Verdict::Fail("transient fault");
                    }
                }
                FaultKind::StickyAfter { after } => {
                    if n >= after {
                        self.state.down.store(true, Ordering::Release);
                        return Verdict::Fail("sticky fault: device is down");
                    }
                }
                FaultKind::LatencySpike { rate, extra } => {
                    if self.roll(n, i as u64) < rate {
                        return Verdict::Delay(extra);
                    }
                }
            }
        }
        Verdict::Pass
    }
}

/// An [`ExecutionEngine`] decorator that injects the plan's faults into
/// the execute path; everything else delegates untouched.
pub struct FaultInjector {
    inner: Box<dyn ExecutionEngine>,
    plan: FaultPlan,
    injected: u64,
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultInjector").finish_non_exhaustive()
    }
}

impl FaultInjector {
    pub fn new(inner: Box<dyn ExecutionEngine>, plan: FaultPlan) -> FaultInjector {
        FaultInjector { inner, plan, injected: 0 }
    }

    /// Failures this injector has delivered (this clone only).
    pub fn injected(&self) -> u64 {
        self.injected
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

impl ExecutionEngine for FaultInjector {
    fn device(&self) -> DeviceId {
        self.inner.device()
    }

    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn is_servable(&self, id: ArtifactId) -> bool {
        self.inner.is_servable(id)
    }

    fn ensure_ready(&mut self, id: ArtifactId) -> Result<()> {
        self.inner.ensure_ready(id)
    }

    fn execute_pooled(
        &mut self,
        id: ArtifactId,
        input: &GemmInput,
        scratch: &mut ScratchBuffers,
    ) -> Result<GemmTimes> {
        match self.plan.decide(input.triple()) {
            Verdict::Pass => self.inner.execute_pooled(id, input, scratch),
            Verdict::Delay(extra) => {
                let mut times = self.inner.execute_pooled(id, input, scratch)?;
                times.kernel_time += extra;
                Ok(times)
            }
            Verdict::Fail(msg) => {
                self.injected += 1;
                bail!("injected fault on {}: {msg}", self.inner.device())
            }
        }
    }

    fn execute_batch_pooled(
        &mut self,
        id: ArtifactId,
        inputs: &[GemmInput],
        batch: &mut BatchScratch,
    ) -> Result<()> {
        // One verdict per *dispatch* (the fused batch fails or stalls as
        // a unit, like a real device would).
        let triple = inputs.first().map_or(Triple::new(0, 0, 0), GemmInput::triple);
        match self.plan.decide(triple) {
            Verdict::Pass => self.inner.execute_batch_pooled(id, inputs, batch),
            Verdict::Delay(extra) => {
                self.inner.execute_batch_pooled(id, inputs, batch)?;
                if let Some(t) = batch.times.first_mut() {
                    t.kernel_time += extra;
                }
                Ok(())
            }
            Verdict::Fail(msg) => {
                self.injected += 1;
                bail!("injected fault on {}: {msg}", self.inner.device())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceProfile;
    use crate::engine::SimEngine;
    use crate::testing::sample_manifest;

    fn sim() -> Box<dyn ExecutionEngine> {
        Box::new(SimEngine::new(DeviceProfile::get(DeviceId::NvidiaP100), sample_manifest()))
    }

    fn input_64(a: &[f32], b: &[f32], c: &[f32]) -> GemmInput<'_> {
        GemmInput { m: 64, n: 64, k: 64, a, b, c, alpha: 1.0, beta: 0.0 }
    }

    fn resolve_64(engine: &dyn ExecutionEngine) -> ArtifactId {
        let t = Triple::new(64, 64, 64);
        let m = engine.manifest();
        (0..m.len() as u32)
            .map(ArtifactId)
            .find(|&id| engine.is_servable(id) && m.meta(id).accepts(t))
            .expect("sample manifest serves 64^3")
    }

    #[test]
    fn transient_rate_is_deterministic_and_roughly_calibrated() {
        let plan = FaultPlan::new(7).with_fault(None, FaultKind::Transient { rate: 0.3 });
        let mut eng = FaultInjector::new(sim(), plan.clone());
        let id = resolve_64(&eng);
        let (a, b, c) = (vec![1.0f32; 64 * 64], vec![1.0f32; 64 * 64], vec![0.0f32; 64 * 64]);
        let mut scratch = ScratchBuffers::new();
        let mut failures = Vec::new();
        for i in 0..200 {
            let r = eng.execute_pooled(id, &input_64(&a, &b, &c), &mut scratch);
            if r.is_err() {
                failures.push(i);
            }
        }
        let rate = failures.len() as f64 / 200.0;
        assert!((0.15..=0.45).contains(&rate), "rate {rate} far from 0.3");
        assert_eq!(eng.injected() as usize, failures.len());

        // Same seed, fresh state: identical failure schedule.
        let plan2 = FaultPlan::new(7).with_fault(None, FaultKind::Transient { rate: 0.3 });
        let mut eng2 = FaultInjector::new(sim(), plan2);
        let mut failures2 = Vec::new();
        for i in 0..200 {
            if eng2.execute_pooled(id, &input_64(&a, &b, &c), &mut scratch).is_err() {
                failures2.push(i);
            }
        }
        assert_eq!(failures, failures2);
    }

    #[test]
    fn sticky_after_n_kills_every_clone_and_revive_restores() {
        let plan = FaultPlan::new(1).with_fault(None, FaultKind::StickyAfter { after: 3 });
        let mut eng = FaultInjector::new(sim(), plan.clone());
        let mut twin = FaultInjector::new(sim(), plan.clone());
        let id = resolve_64(&eng);
        let (a, b, c) = (vec![1.0f32; 64 * 64], vec![1.0f32; 64 * 64], vec![0.0f32; 64 * 64]);
        let mut scratch = ScratchBuffers::new();
        for _ in 0..3 {
            eng.execute_pooled(id, &input_64(&a, &b, &c), &mut scratch).unwrap();
        }
        assert!(eng.execute_pooled(id, &input_64(&a, &b, &c), &mut scratch).is_err());
        assert!(plan.is_down());
        // The twin shares the sticky switch even though it never failed
        // itself.
        let err = twin.execute_pooled(id, &input_64(&a, &b, &c), &mut scratch).unwrap_err();
        assert!(format!("{err:#}").contains("injected fault"), "err: {err:#}");
        plan.revive();
        eng.execute_pooled(id, &input_64(&a, &b, &c), &mut scratch).unwrap();
    }

    #[test]
    fn kill_now_fails_batches_and_latency_spike_keeps_results() {
        let plan = FaultPlan::new(9);
        let mut eng = FaultInjector::new(sim(), plan.clone());
        let id = resolve_64(&eng);
        let (a, b, c) = (vec![2.0f32; 64 * 64], vec![1.0f32; 64 * 64], vec![0.0f32; 64 * 64]);
        let inputs = [input_64(&a, &b, &c), input_64(&a, &b, &c)];
        let mut batch = BatchScratch::new();
        eng.execute_batch_pooled(id, &inputs, &mut batch).unwrap();
        plan.kill_now();
        assert!(eng.execute_batch_pooled(id, &inputs, &mut batch).is_err());
        plan.revive();

        // A guaranteed latency spike slows the report, not the math.
        let spike = FaultPlan::new(2).with_fault(
            Some(Triple::new(64, 64, 64)),
            FaultKind::LatencySpike { rate: 1.1, extra: Duration::from_millis(5) },
        );
        let mut slow = FaultInjector::new(sim(), spike);
        let mut scratch = ScratchBuffers::new();
        let times = slow.execute_pooled(id, &input_64(&a, &b, &c), &mut scratch).unwrap();
        assert!(times.kernel_time >= Duration::from_millis(5));
        for &v in &scratch.out {
            assert_eq!(v, 2.0 * 64.0);
        }
    }
}
