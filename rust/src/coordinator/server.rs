//! The adaptive GEMM server — the on-line coordinator.
//!
//! Topology (see ARCHITECTURE.md): client threads submit [`GemmRequest`]s
//! through a [`ServerHandle`], which routes them round-robin across N
//! dispatcher *shards*.  Each shard is one worker thread that exclusively
//! owns a `GemmRuntime` (its own PJRT client and compile cache — PJRT
//! handles never cross threads) plus a [`ScratchBuffers`] pool, shares the
//! read-only [`SelectPolicy`], and runs the per-artifact dynamic batcher:
//! the pending window is resolved to dense [`ArtifactId`]s and grouped by
//! id (consecutive executions of one executable amortize instruction/data
//! cache misses and avoid executable switching).  Requests execute on the
//! pooled, allocation-free runtime path; responses flow back over
//! per-request channels.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::Triple;
use crate::runtime::{ArtifactId, GemmInput, GemmRuntime, ScratchBuffers};

use super::metrics::{RequestRecord, ServeStats};
use super::policy::SelectPolicy;

/// An owned GEMM request.
#[derive(Debug, Clone)]
pub struct GemmRequest {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub c: Vec<f32>,
    pub alpha: f32,
    pub beta: f32,
}

impl GemmRequest {
    pub fn triple(&self) -> Triple {
        Triple::new(self.m as u32, self.n as u32, self.k as u32)
    }
}

/// A served response.
#[derive(Debug)]
pub struct GemmResponse {
    pub out: Result<Vec<f32>>,
    pub artifact: String,
    pub queue: Duration,
    pub service: Duration,
}

/// Server tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Max requests coalesced into one dispatch window.
    pub max_batch: usize,
    /// How long a shard waits to fill a window.
    pub batch_window: Duration,
    /// Dispatcher shards, each exclusively owning a runtime + compile
    /// cache.  Requests are routed round-robin across shards.
    pub shards: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 32,
            batch_window: Duration::from_micros(200),
            shards: 1,
        }
    }
}

impl ServerConfig {
    /// Default configuration at a given shard count.
    pub fn with_shards(shards: usize) -> ServerConfig {
        ServerConfig { shards, ..ServerConfig::default() }
    }
}

struct Envelope {
    req: GemmRequest,
    submitted: Instant,
    reply: mpsc::Sender<GemmResponse>,
}

/// Handle for submitting work.  Clones share the round-robin cursor, so
/// traffic from any number of client threads spreads across all shards.
#[derive(Clone)]
pub struct ServerHandle {
    txs: Arc<Vec<mpsc::Sender<Envelope>>>,
    next: Arc<AtomicUsize>,
}

impl ServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, req: GemmRequest) -> mpsc::Receiver<GemmResponse> {
        let (reply, rx) = mpsc::channel();
        let shard = self.next.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        let _ = self.txs[shard].send(Envelope {
            req,
            submitted: Instant::now(),
            reply,
        });
        rx
    }

    /// Submit and wait.
    pub fn call(&self, req: GemmRequest) -> Result<GemmResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server shut down before responding"))
    }

    /// Number of dispatcher shards behind this handle.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }
}

/// The running server.
pub struct GemmServer {
    handle: Option<ServerHandle>,
    workers: Vec<JoinHandle<Vec<RequestRecord>>>,
    started: Instant,
}

impl GemmServer {
    /// Start the server with `cfg.shards` dispatcher shards.  Each PJRT
    /// runtime is *created on its shard's thread* (PJRT handles are not
    /// `Send`); startup errors are reported synchronously through a
    /// ready-channel once every shard has checked in.
    pub fn start(
        artifacts: &Path,
        policy: Box<dyn SelectPolicy>,
        cfg: ServerConfig,
    ) -> Result<GemmServer> {
        let policy: Arc<dyn SelectPolicy> = Arc::from(policy);
        let n_shards = cfg.shards.max(1);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut txs = Vec::with_capacity(n_shards);
        let mut workers = Vec::with_capacity(n_shards);
        for shard in 0..n_shards {
            let (tx, rx) = mpsc::channel::<Envelope>();
            txs.push(tx);
            let dir = artifacts.to_path_buf();
            let policy = Arc::clone(&policy);
            let ready_tx = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(shard, dir, policy, cfg, rx, ready_tx)
            }));
        }
        drop(ready_tx);
        let handle = ServerHandle {
            txs: Arc::new(txs),
            next: Arc::new(AtomicUsize::new(0)),
        };
        let mut failures = Vec::new();
        for _ in 0..n_shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => failures.push(msg),
                Err(_) => failures.push("server thread died during startup".to_string()),
            }
        }
        if !failures.is_empty() {
            // Drop the senders so healthy shards exit, then reap.
            drop(handle);
            for w in workers {
                let _ = w.join();
            }
            return Err(anyhow!("server startup failed: {}", failures.join("; ")));
        }
        Ok(GemmServer {
            handle: Some(handle),
            workers,
            started: Instant::now(),
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.as_ref().expect("server running").clone()
    }

    /// Shut down and collect serving statistics (None if nothing served).
    pub fn shutdown(mut self) -> Option<ServeStats> {
        let wall = self.started.elapsed();
        // Drop our sender references so each shard's recv() errors out
        // once all client handles are gone.
        self.handle = None;
        let mut records = Vec::new();
        for w in self.workers.drain(..) {
            if let Ok(mut r) = w.join() {
                records.append(&mut r);
            }
        }
        if records.is_empty() {
            None
        } else {
            Some(ServeStats::from_records(&records, wall))
        }
    }
}

/// One dispatcher shard: batches, selects, executes on the pooled path.
fn worker_loop(
    shard: usize,
    dir: PathBuf,
    policy: Arc<dyn SelectPolicy>,
    cfg: ServerConfig,
    rx: mpsc::Receiver<Envelope>,
    ready_tx: mpsc::Sender<Result<(), String>>,
) -> Vec<RequestRecord> {
    let mut runtime = match GemmRuntime::open(&dir) {
        Ok(r) => {
            let _ = ready_tx.send(Ok(()));
            r
        }
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return Vec::new();
        }
    };
    drop(ready_tx);
    let mut scratch = ScratchBuffers::new();
    // Records keep the dense id while serving; names are resolved once at
    // shard exit so the hot path does not allocate per-request Strings
    // beyond the response boundary.
    let mut raw_records: Vec<(ArtifactId, Duration, Duration, f64)> = Vec::new();
    let mut window: Vec<Envelope> = Vec::with_capacity(cfg.max_batch);
    loop {
        // Block for the first request of a window.
        match rx.recv() {
            Err(_) => break, // all senders dropped: shutdown
            Ok(env) => window.push(env),
        }
        // Fill the window for up to `batch_window`.
        let deadline = Instant::now() + cfg.batch_window;
        while window.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(env) => window.push(env),
                Err(_) => break,
            }
        }
        // Resolve each request to a dense artifact id, then group the
        // window by id (stable sort keeps FIFO order within a group) —
        // the dynamic batcher, with no string keys on the hot path.
        let mut resolved: Vec<(Option<ArtifactId>, Envelope)> = window
            .drain(..)
            .map(|env| {
                let t = env.req.triple();
                let cfg_sel = policy.select(t);
                let id = runtime
                    .manifest
                    .artifact_id_for_config(&cfg_sel, t)
                    // Fallback: any artifact accepting t (least waste).
                    .or_else(|| runtime.manifest.eligible_id(t));
                (id, env)
            })
            .collect();
        resolved.sort_by_key(|(id, _)| *id);

        for (id, env) in resolved {
            let queue = env.submitted.elapsed();
            let t0 = Instant::now();
            let result = match id {
                None => Err(anyhow!("no artifact accepts {}", env.req.triple())),
                Some(id) => {
                    let input = GemmInput {
                        m: env.req.m,
                        n: env.req.n,
                        k: env.req.k,
                        a: &env.req.a,
                        b: &env.req.b,
                        c: &env.req.c,
                        alpha: env.req.alpha,
                        beta: env.req.beta,
                    };
                    runtime
                        .gemm_pooled(id, &input, &mut scratch)
                        // The response must outlive the scratch pool: the
                        // copy-out is the one boundary allocation.
                        .map(|_times| scratch.out.clone())
                }
            };
            let service = t0.elapsed();
            let artifact = match id {
                Some(id) => runtime.manifest.name_of(id).to_string(),
                None => String::new(),
            };
            if let (true, Some(id)) = (result.is_ok(), id) {
                raw_records.push((id, queue, service, env.req.triple().flops()));
            }
            let _ = env.reply.send(GemmResponse {
                out: result,
                artifact,
                queue,
                service,
            });
        }
    }
    raw_records
        .into_iter()
        .map(|(id, queue, service, flops)| RequestRecord {
            artifact: runtime.manifest.name_of(id).to_string(),
            shard,
            queue,
            service,
            flops,
        })
        .collect()
}
