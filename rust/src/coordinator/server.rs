//! The adaptive GEMM server — the on-line coordinator, now a
//! *heterogeneous fleet* with **bounded admission**.
//!
//! Topology (see ARCHITECTURE.md): client threads submit [`GemmRequest`]s
//! through a [`ServerHandle`], whose device-aware router picks a device
//! class per request (policy-predicted service time on each class, scaled
//! by that class's queue depth, skipping classes at their queue bound)
//! and then round-robins across the class's dispatcher *shards*.  Each
//! shard is one worker thread pinned to a device class: it exclusively
//! owns an [`ExecutionEngine`] built from the class's [`EngineSpec`] (the
//! real PJRT runtime for the host CPU, analytical engines for the
//! simulated devices — engines are created on the shard's thread, PJRT
//! handles never cross threads) plus a [`ScratchBuffers`] pool, shares
//! its *class's* [`PolicyHandle`] and [`TelemetryRing`] (never another
//! class's — per-device telemetry must not cross-contaminate), and runs
//! the per-artifact dynamic batcher.  Requests execute on the pooled,
//! allocation-free engine path; responses flow back over per-request
//! channels carrying the serving device, the routed device, the policy
//! epoch, the fused-batch size and a typed [`RequestOutcome`].
//!
//! **Shape-bucketed request fusion**: after deadline filtering and
//! policy selection, the window-resolve step groups envelopes by
//! `(ArtifactId, m, n, k)` and fuses each run into a single batched
//! execution of up to [`ServerConfig::max_fuse`] members
//! ([`ExecutionEngine::execute_batch_pooled`]) — the per-dispatch cost
//! the §5 cost model charges once per launch is paid once per *batch*,
//! so under same-shape traffic the hot path's cost per request drops
//! below one dispatch.  Expired envelopes are dropped before grouping
//! (they never inflate a batch or its occupancy stats), a failed fused
//! dispatch answers every member with a typed per-request error, and
//! telemetry keeps *per-request* service times (per-slot attribution,
//! fusion amortization excluded) so the adaptation loop and oracles are
//! unaffected by batch luck.
//!
//! Overload handling (the serving path under sustained pressure):
//!
//! * **Bounded admission** — every device class has a queue bound
//!   ([`ServerConfig::queue_capacity`], overridable per class via
//!   [`DeviceClass::with_queue_capacity`]).  [`ServerHandle::try_submit`]
//!   returns an explicit [`Admission::Shed`] once every candidate class
//!   is full instead of enqueueing forever; [`ServerHandle::submit`] is
//!   the blocking variant that waits for a slot.  Admission is two
//!   atomic ops on the submit path — no locks, no allocations.
//! * **Deadlines** — a request may carry a deadline
//!   ([`ServerHandle::try_submit_with_deadline`]); shards drop
//!   already-expired envelopes at window-resolve time and answer them
//!   with a typed [`RequestOutcome::Expired`] overload error instead of
//!   spending service time on a reply nobody wants.
//! * **Pressure picks** — when an envelope has queued longer than
//!   [`ServerConfig::pressure_threshold`], the shard swaps the policy's
//!   selection for the modeled-cheapest servable artifact whenever the
//!   policy pick is more than [`ServerConfig::pressure_slowdown`] slower
//!   than it ([`sim::modeled_secs`]) — system state feeds back into the
//!   paper's model-driven selection under load.
//! * **Graceful drain** — [`GemmServer::shutdown_now`] answers every
//!   still-queued envelope with a typed shutdown error instead of
//!   silently dropping reply channels.
//!
//! Failure domains (see ARCHITECTURE.md): every device class carries a
//! lock-free [`CircuitBreaker`].  Execute-time failures feed it (one
//! mark per failed *dispatch*); once it trips, the router treats the
//! `Open` class like a full one and admission answers with a typed
//! [`Admission::Quarantined`] when no servable sibling exists.  After
//! the cooldown, `HalfOpen` admits a budgeted handful of *probe*
//! requests whose outcomes alone decide between re-opening and closing.
//! A transient execute failure is retried: fused members re-execute
//! individually on the same engine first (one poisoned batch member
//! can't fail the whole batch twice), then the envelope *fails over* to
//! the modeled-cheapest healthy sibling class — bounded by
//! [`ServerConfig::retry_budget`] and only when the remaining deadline
//! affords the sibling's modeled service time.  Retried/failed-over
//! requests are stamped on [`GemmResponse`] and excluded from the
//! telemetry tap, so injected faults never poison the trainer.

use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::config::Triple;
use crate::device::{sim, DeviceId, DeviceProfile};
use crate::engine::{EngineSpec, ExecutionEngine, FaultInjector, FaultPlan};
use crate::runtime::{ArtifactId, BatchScratch, GemmInput, Manifest, ScratchBuffers};
use crate::util::sync::{AdmissionGauge, AtomicBool, AtomicU64, AtomicUsize, Ordering};

use super::adapt::{TelemetryRecord, TelemetryRing};
use super::breaker::{BreakerAdmit, BreakerConfig, CircuitBreaker};
use super::metrics::{
    occupancy_bucket, RequestOutcome, RequestRecord, ServeStats, OCCUPANCY_BUCKETS,
};
use super::policy::{CachedPolicy, PolicyHandle, SelectPolicy};

/// An owned GEMM request.
#[derive(Debug, Clone)]
pub struct GemmRequest {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub c: Vec<f32>,
    pub alpha: f32,
    pub beta: f32,
}

impl GemmRequest {
    pub fn triple(&self) -> Triple {
        Triple::new(self.m as u32, self.n as u32, self.k as u32)
    }

    /// Validate at submission: dimensions must fit the `u32` triple (the
    /// old `m as u32` cast silently truncated oversized dimensions, so
    /// the server resolved — and served — a *wrong* triple) and operand
    /// lengths must match `m·k` / `k·n` / `m·n`.  Every submit path
    /// rejects invalid requests with a typed error instead of executing.
    pub fn validate(&self) -> std::result::Result<(), String> {
        for (dim, name) in [(self.m, "m"), (self.n, "n"), (self.k, "k")] {
            if dim > u32::MAX as usize {
                return Err(format!(
                    "dimension {name}={dim} exceeds the u32 triple limit"
                ));
            }
        }
        if self.a.len() != self.m * self.k
            || self.b.len() != self.k * self.n
            || self.c.len() != self.m * self.n
        {
            return Err(format!(
                "operand lengths do not match ({}, {}, {}): a={} (want {}), \
                 b={} (want {}), c={} (want {})",
                self.m,
                self.n,
                self.k,
                self.a.len(),
                self.m * self.k,
                self.b.len(),
                self.k * self.n,
                self.c.len(),
                self.m * self.n,
            ));
        }
        Ok(())
    }
}

/// A served response.
#[derive(Debug)]
pub struct GemmResponse {
    pub out: Result<Vec<f32>>,
    pub artifact: String,
    /// Name of the kernel configuration the serving artifact implements
    /// (e.g. a host microkernel variant like `h_avx2_t8x8_u4`, or an
    /// xgemm/direct config name) — the variant identity of the dispatch,
    /// without a manifest lookup.  Empty when no artifact served the
    /// request (shed, expired, drained).
    pub kernel: String,
    /// Time spent not executing this request: window wait plus — for
    /// fused members — batch peers' slots.  `queue + service` is the
    /// exact submit-to-reply interval.
    pub queue: Duration,
    /// This request's own share of the dispatch: its per-slot execute +
    /// pad/unpad time plus an equal share of the batch residual
    /// (compile, staging overhead).
    pub service: Duration,
    /// Policy epoch the request was resolved under (bumped by every
    /// adaptation hot-swap of *this device's* policy; 0 until the first
    /// swap).  Epochs are per device class — a swap on one device never
    /// moves another's.
    pub epoch: u64,
    /// Device class of the shard that served the request (stamped by the
    /// worker from its pinned class).
    pub device: DeviceId,
    /// Device class the router chose at submit time (stamped by the
    /// handle).  Equals `device` unless the request *failed over* to a
    /// sibling class mid-serve (`failover` is set) — the two independent
    /// stamps exist so routing bugs are detectable, and the router
    /// property test pins them equal under racing submitters in a
    /// healthy fleet.
    pub routed: DeviceId,
    /// Serving shard (fleet-global index; `usize::MAX` for responses
    /// synthesized on the submit path, which never reached a shard).
    pub shard: usize,
    /// Typed outcome — the machine-checkable counterpart of `out`
    /// (`Ok` iff `out` is `Ok`).
    pub outcome: RequestOutcome,
    /// The shard overrode the policy's selection with the pressure pick.
    pub pressure_pick: bool,
    /// Size of the fused batch this request was dispatched in: 1 = the
    /// request executed alone, >= 2 = it shared one batched dispatch
    /// with same-`(artifact, m, n, k)` window neighbours, 0 = it never
    /// reached a dispatch (expired, drained, shed-synthetic, or failed
    /// before execution).  On an errored fused dispatch every member
    /// reports the batch size it died in.
    pub fused_batch_size: usize,
    /// Execute-time retries this request consumed (same-engine
    /// individual re-executions of fused members plus cross-class
    /// failover hops).  0 on the fast path.
    pub retries: u32,
    /// The request was answered by a different device class than the
    /// router chose (`device != routed`): a sibling served it after its
    /// original class failed.
    pub failover: bool,
}

/// Outcome of a non-blocking submission attempt.
#[derive(Debug)]
pub enum Admission {
    /// Admitted: the response will arrive on this receiver.
    Enqueued(mpsc::Receiver<GemmResponse>),
    /// Refused — every candidate class was at its queue bound.  The
    /// request is handed back so callers can retry without a clone;
    /// `device`/`outstanding`/`capacity` describe the least-loaded class
    /// at refusal time (the one a retry would land on).
    Shed {
        req: GemmRequest,
        device: DeviceId,
        outstanding: usize,
        capacity: usize,
    },
    /// Malformed request (dimension overflow / operand length mismatch);
    /// never admitted, never counted as shed.
    Rejected { reason: String },
    /// Refused because every candidate class's circuit breaker is open
    /// (the fleet is quarantined, not merely full).  `device` is the
    /// class a retry-after-cooldown would probe first.
    Quarantined { req: GemmRequest, device: DeviceId },
}

/// Server tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Max requests coalesced into one dispatch window.
    pub max_batch: usize,
    /// Max same-`(artifact, m, n, k)` requests *fused* into one batched
    /// execution inside a window (`1` disables fusion — every request
    /// dispatches alone, the pre-fusion behaviour).  Fusion amortizes
    /// the per-dispatch cost the §5 cost model charges once per launch
    /// across every same-shape request a window holds.
    pub max_fuse: usize,
    /// How long a shard waits to fill a window.
    pub batch_window: Duration,
    /// Dispatcher shards for the homogeneous [`GemmServer::start`] path
    /// (heterogeneous fleets size each class via [`DeviceClass::shards`]).
    pub shards: usize,
    /// Fraction of successfully served requests sampled into the
    /// telemetry ring (0.0 disables the tap entirely).
    pub telemetry_fraction: f64,
    /// Shadow-execution budget: fraction of *sampled* requests that also
    /// execute one alternative eligible artifact (off the response path,
    /// after the reply is sent) so the trainer can compare configs on
    /// live traffic.
    pub shadow_fraction: f64,
    /// Telemetry ring capacity (oldest records drop under pressure).
    pub telemetry_capacity: usize,
    /// Per-class queue bound: max outstanding (admitted, unanswered)
    /// requests a device class holds before `try_submit` sheds.
    /// Overridable per class via [`DeviceClass::with_queue_capacity`].
    pub queue_capacity: usize,
    /// Queue time beyond which a shard resolves an envelope through the
    /// pressure pick instead of trusting the policy's selection alone.
    /// `Duration::MAX` (the default) disables pressure picks.
    pub pressure_threshold: Duration,
    /// Modeled-slowdown bound of the pressure pick: the policy's choice
    /// stands unless it is more than this factor slower than the
    /// modeled-cheapest servable artifact (values below 1.0 clamp up).
    pub pressure_slowdown: f64,
    /// Execute-failure retry budget per request: how many re-executions
    /// (same-engine individual retries of fused members + cross-class
    /// failover hops, combined) one envelope may consume.  0 disables
    /// retry/failover — a failed dispatch answers with the error
    /// directly.
    pub retry_budget: u32,
    /// Per-device-class circuit-breaker thresholds (overridable per
    /// class via [`DeviceClass::with_breaker`]).
    pub breaker: BreakerConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 32,
            max_fuse: 16,
            batch_window: Duration::from_micros(200),
            shards: 1,
            telemetry_fraction: 0.0,
            shadow_fraction: 0.0,
            telemetry_capacity: 4096,
            queue_capacity: 1024,
            pressure_threshold: Duration::MAX,
            pressure_slowdown: 1.25,
            retry_budget: 2,
            breaker: BreakerConfig::default(),
        }
    }
}

impl ServerConfig {
    /// Default configuration at a given shard count.
    pub fn with_shards(shards: usize) -> ServerConfig {
        ServerConfig { shards, ..ServerConfig::default() }
    }

    /// Sharded configuration with the telemetry tap and shadow budget
    /// enabled — what the adaptation loop serves under.
    pub fn adaptive(shards: usize, telemetry_fraction: f64, shadow_fraction: f64) -> ServerConfig {
        ServerConfig {
            shards,
            telemetry_fraction,
            shadow_fraction,
            ..ServerConfig::default()
        }
    }

    /// Validate at server start: zero shards, a zero-sized batch window
    /// or a zero queue bound are configuration bugs, rejected loudly
    /// instead of silently "fixed"; the sampling fractions are *rates*
    /// and are clamped into [0, 1], and the pressure slowdown bound is a
    /// *factor* clamped to >= 1.0 (out-of-range values have an obvious
    /// intent).
    pub fn validated(self) -> Result<ServerConfig> {
        ensure!(self.shards > 0, "ServerConfig.shards must be > 0");
        ensure!(self.max_batch > 0, "ServerConfig.max_batch must be > 0");
        ensure!(self.max_fuse > 0, "ServerConfig.max_fuse must be > 0 (1 disables fusion)");
        ensure!(
            self.queue_capacity > 0,
            "ServerConfig.queue_capacity must be > 0"
        );
        let pressure_slowdown = if self.pressure_slowdown.is_nan() {
            1.0
        } else {
            self.pressure_slowdown.max(1.0)
        };
        Ok(ServerConfig {
            telemetry_fraction: self.telemetry_fraction.clamp(0.0, 1.0),
            shadow_fraction: self.shadow_fraction.clamp(0.0, 1.0),
            pressure_slowdown,
            ..self
        })
    }
}

/// One device class of a heterogeneous fleet: a device, its shard count,
/// and the class's *own* selection policy (installed into a per-class
/// [`PolicyHandle`], so per-device adaptation retrains and hot-swaps each
/// class independently).
pub struct DeviceClass {
    pub device: DeviceId,
    pub shards: usize,
    pub policy: Box<dyn SelectPolicy>,
    /// Per-class queue bound override (falls back to
    /// [`ServerConfig::queue_capacity`] when `None`).
    pub queue_capacity: Option<usize>,
    /// Deterministic fault script injected between this class's shards
    /// and their engines ([`FaultInjector`]) — chaos experiments and
    /// failure tests; `None` (the default) serves faithfully.
    pub fault_plan: Option<FaultPlan>,
    /// Per-class breaker override (falls back to
    /// [`ServerConfig::breaker`] when `None`).
    pub breaker: Option<BreakerConfig>,
}

impl std::fmt::Debug for DeviceClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceClass").finish_non_exhaustive()
    }
}

impl DeviceClass {
    pub fn new(device: DeviceId, shards: usize, policy: Box<dyn SelectPolicy>) -> DeviceClass {
        DeviceClass {
            device,
            shards,
            policy,
            queue_capacity: None,
            fault_plan: None,
            breaker: None,
        }
    }

    /// Override the class's queue bound (validated at fleet start).
    pub fn with_queue_capacity(mut self, capacity: usize) -> DeviceClass {
        self.queue_capacity = Some(capacity);
        self
    }

    /// Inject a fault plan into this class's engines.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> DeviceClass {
        self.fault_plan = Some(plan);
        self
    }

    /// Override this class's breaker thresholds.
    pub fn with_breaker(mut self, breaker: BreakerConfig) -> DeviceClass {
        self.breaker = Some(breaker);
        self
    }
}

/// When the class policy picks a config the device model cannot run at
/// all, the router charges this pessimistic service time — the class is
/// effectively avoided unless every other queue is badly backed up.
const ROUTE_FALLBACK_SECS: f64 = 1.0;

/// Blocking submits back off this long between admission attempts while
/// every candidate queue is full.
const ADMISSION_BACKOFF: Duration = Duration::from_micros(50);

/// Blocking submits give up (with a typed error response) after waiting
/// this long for a queue slot — the escape hatch when the fleet is
/// wedged or shutting down underneath a blocked client.
const ADMISSION_PATIENCE: Duration = Duration::from_secs(10);

/// Admission/selection counters of one device class, maintained outside
/// the shard records: sheds happen on the submit path (the request never
/// reaches a worker) and pressure picks/peak depth/fused dispatches are
/// cheapest to track where they occur.  Merged into [`ServeStats`] at
/// shutdown.
#[derive(Default)]
struct ClassCounters {
    shed: AtomicU64,
    pressure_picks: AtomicU64,
    peak_depth: AtomicUsize,
    /// Successful dispatches (fused batches, size-1 included).
    dispatches: AtomicU64,
    /// Requests served in batches of size >= 2.
    fused_requests: AtomicU64,
    /// Modeled per-dispatch nanoseconds fusion avoided (sim engines).
    fused_saved_ns: AtomicU64,
    /// Dispatches by fused-batch-size bucket — the per-device occupancy
    /// histogram ([`occupancy_bucket`]).
    occupancy: [AtomicU64; OCCUPANCY_BUCKETS],
    /// Typed quarantine refusals on the submit path (breaker open, no
    /// servable sibling).
    quarantined: AtomicU64,
    /// Execute-failure re-executions consumed (individual retries of
    /// fused members + failover hops).
    retries: AtomicU64,
    /// Envelopes handed to a sibling class after this class failed them.
    failovers: AtomicU64,
    /// Shadow executions that errored — ledgered here, never folded into
    /// the telemetry ring (satellite of the failure-domain work: a
    /// faulty engine must not corrupt the trainer's labeled data).
    shadow_errors: AtomicU64,
}

impl ClassCounters {
    /// Record one successful fused dispatch of `batch` requests.
    fn record_dispatch(&self, batch: usize, saved: Duration) {
        // RELAXED: shard-local stats counters, merged only after the
        // worker quiesces; no ordering with the serving path needed.
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        if batch >= 2 {
            self.fused_requests.fetch_add(batch as u64, Ordering::Relaxed);
        }
        // RELAXED: same stats ledger as above.
        self.fused_saved_ns
            .fetch_add(saved.as_nanos() as u64, Ordering::Relaxed);
        self.occupancy[occupancy_bucket(batch)].fetch_add(1, Ordering::Relaxed);
    }
}

/// Router-side state of one device class.
struct ClassState {
    device: DeviceId,
    profile: DeviceProfile,
    /// The class's policy slot (shared with its shards and its
    /// adaptation loop): the router predicts with the *live* policy.
    policy: Arc<PolicyHandle>,
    /// Router-local cache of the class policy, brought up to date with
    /// one atomic epoch check per use ([`PolicyHandle::refresh`]) — so
    /// routing shares no lock with the adaptation hot-swap path except
    /// in the instant after a swap, and never clones the policy `Arc`
    /// per submit the way `snapshot()` would.
    cached: Mutex<CachedPolicy>,
    txs: Vec<mpsc::Sender<Envelope>>,
    /// Per-shard depth gauges: outstanding (submitted, not yet replied)
    /// requests.  Incremented by the handle at submit, decremented by the
    /// shard after the reply is sent.
    depths: Vec<Arc<AtomicUsize>>,
    /// Class-wide admission gauge: the capacity-bounded reservation
    /// counter (reserve, roll back on refusal), shared with the shards
    /// and the failover table.
    admission: Arc<AdmissionGauge>,
    counters: Arc<ClassCounters>,
    /// Round-robin cursor within the class.
    next: AtomicUsize,
    /// The class's circuit breaker (shared with its shards and the
    /// failover table).
    breaker: Arc<CircuitBreaker>,
}

impl ClassState {
    fn depth(&self) -> usize {
        // RELAXED: advisory load-balancing read; gauges move under live
        // traffic, staleness only skews routing, never correctness.
        self.depths.iter().map(|d| d.load(Ordering::Relaxed)).sum()
    }

    fn is_full(&self) -> bool {
        self.admission.is_full()
    }

    /// Predicted completion time of serving `t` on this class now: the
    /// analytical model's service time for the class policy's selection,
    /// scaled by how many requests are already queued per shard.  The
    /// depth term is both the load balancer and the tie-break — two
    /// classes with similar predicted service times split traffic by
    /// queue pressure.
    fn predicted_wait(&self, t: Triple) -> f64 {
        let cfg = {
            let mut cached = self.cached.lock().unwrap_or_else(|e| e.into_inner());
            self.policy.refresh(&mut cached);
            cached.select(t)
        };
        let secs =
            sim::modeled_secs(&self.profile, &cfg, t).unwrap_or(ROUTE_FALLBACK_SECS);
        secs * (1.0 + self.depth() as f64 / self.txs.len() as f64)
    }
}

struct Envelope {
    req: GemmRequest,
    submitted: Instant,
    /// Drop (with a typed expired reply) instead of serving once this
    /// instant has passed — checked at window-resolve time.
    deadline: Option<Instant>,
    reply: mpsc::Sender<GemmResponse>,
    /// Device class the router chose (echoed into the response).
    routed: DeviceId,
    /// Re-executions consumed so far (see [`ServerConfig::retry_budget`]).
    retries: u32,
    /// The envelope was handed to a sibling class by failover.
    failover: bool,
    /// Admitted as a HalfOpen breaker probe: the serving shard must
    /// settle exactly one probe token with the outcome.
    probe: bool,
}

/// Why `try_admit` refused (the request is handed back either way).
enum AdmitRefusal {
    /// The class is at its queue bound.
    Full(GemmRequest),
    /// The class's circuit breaker rejected the admission.
    Quarantined(GemmRequest),
}

/// Handle for submitting work.  Clones share the per-class round-robin
/// cursors and depth gauges, so traffic from any number of client threads
/// spreads across the fleet consistently.
#[derive(Clone)]
pub struct ServerHandle {
    classes: Arc<Vec<ClassState>>,
}

impl std::fmt::Debug for ServerHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerHandle").finish_non_exhaustive()
    }
}

impl ServerHandle {
    /// Best (lowest predicted-wait) class not yet in `tried`; classes at
    /// their queue bound — or quarantined by their breaker — are skipped
    /// when `skip_full`, so a saturated or failing class spills to a
    /// servable sibling before anything is rejected.
    fn best_class(&self, t: Triple, tried: u64, skip_full: bool) -> Option<usize> {
        let mut best = None;
        let mut best_score = f64::INFINITY;
        for (i, class) in self.classes.iter().enumerate() {
            if tried & (1u64 << i) != 0
                || (skip_full && (class.is_full() || class.breaker.would_reject()))
            {
                continue;
            }
            let score = class.predicted_wait(t);
            if score < best_score {
                best_score = score;
                best = Some(i);
            }
        }
        best
    }

    fn least_loaded(&self) -> usize {
        let mut best = 0usize;
        let mut best_load = usize::MAX;
        for (i, class) in self.classes.iter().enumerate() {
            let load = class.admission.outstanding();
            if load < best_load {
                best_load = load;
                best = i;
            }
        }
        best
    }

    /// The device the router would choose for `t` right now (advisory:
    /// depth gauges move under live traffic).
    pub fn route_preview(&self, t: Triple) -> DeviceId {
        if self.classes.len() == 1 {
            return self.classes[0].device;
        }
        let i = self
            .best_class(t, 0, true)
            .or_else(|| self.best_class(t, 0, false))
            .unwrap_or(0);
        self.classes[i].device
    }

    /// Reserve a queue slot on `class` and enqueue, or hand the request
    /// back when the class is at its bound or its breaker refuses.  The
    /// healthy-path reservation is the breaker's single atomic load plus
    /// two atomics (`fetch_add` + rollback on refusal) — admission adds
    /// no locks and no allocations to the submit path.  A `HalfOpen`
    /// breaker admits the request as a *probe*: the serving shard
    /// settles the probe token with the execute outcome.
    // LINT: hot-path — admission fast path; two atomics, no locks, and
    // the only allocation is the caller's reply channel.
    fn try_admit(
        &self,
        class: &ClassState,
        req: GemmRequest,
        deadline: Option<Instant>,
    ) -> std::result::Result<mpsc::Receiver<GemmResponse>, AdmitRefusal> {
        let probe = match class.breaker.admit() {
            BreakerAdmit::Serve => false,
            BreakerAdmit::Probe => true,
            BreakerAdmit::Reject => return Err(AdmitRefusal::Quarantined(req)),
        };
        let release = |on: bool| {
            if on {
                class.breaker.release_probe();
            }
        };
        let Some(prev) = class.admission.try_reserve() else {
            release(probe);
            return Err(AdmitRefusal::Full(req));
        };
        // RELAXED: watermark, round-robin cursor, and advisory shard
        // gauge; the admission bound itself is held by the gauge's
        // AcqRel reservation above.
        class.counters.peak_depth.fetch_max(prev + 1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let shard = class.next.fetch_add(1, Ordering::Relaxed) % class.txs.len();
        class.depths[shard].fetch_add(1, Ordering::Relaxed);
        let sent = class.txs[shard].send(Envelope {
            req,
            submitted: Instant::now(),
            deadline,
            reply,
            routed: class.device,
            retries: 0,
            failover: false,
            probe,
        });
        if sent.is_err() {
            // Shard gone (shutdown): roll the gauges back so the router
            // does not see a phantom queue.  The returned receiver's
            // sender is dropped, so the caller observes the usual
            // server-shut-down recv error.
            // RELAXED: advisory shard gauge rollback (see above).
            class.depths[shard].fetch_sub(1, Ordering::Relaxed);
            class.admission.release();
            release(probe);
        }
        Ok(rx)
    }

    fn shed(&self, class_idx: usize, req: GemmRequest, count: bool) -> Admission {
        let class = &self.classes[class_idx];
        if count {
            // RELAXED: stats counter; merged after shutdown.
            class.counters.shed.fetch_add(1, Ordering::Relaxed);
        }
        Admission::Shed {
            req,
            device: class.device,
            outstanding: class.admission.outstanding(),
            capacity: class.admission.capacity(),
        }
    }

    /// Typed quarantine refusal (counted like a shed, in its own
    /// ledger).
    fn quarantine(&self, class_idx: usize, req: GemmRequest, count: bool) -> Admission {
        let class = &self.classes[class_idx];
        if count {
            // RELAXED: stats counter; merged after shutdown.
            class.counters.quarantined.fetch_add(1, Ordering::Relaxed);
        }
        Admission::Quarantined { req, device: class.device }
    }

    /// One routed admission pass: try classes in predicted-wait order,
    /// skipping full/quarantined ones; refuse only when every class is
    /// at its bound (`Shed`) or breaker-rejected (`Quarantined` — every
    /// class refused and at least the least-loaded one by quarantine).
    fn try_submit_inner(
        &self,
        mut req: GemmRequest,
        deadline: Option<Instant>,
        count_shed: bool,
    ) -> Admission {
        if self.classes.len() == 1 {
            return match self.try_admit(&self.classes[0], req, deadline) {
                Ok(rx) => Admission::Enqueued(rx),
                Err(AdmitRefusal::Full(req)) => self.shed(0, req, count_shed),
                Err(AdmitRefusal::Quarantined(req)) => self.quarantine(0, req, count_shed),
            };
        }
        let t = req.triple();
        let mut tried = 0u64;
        while let Some(i) = self.best_class(t, tried, true) {
            match self.try_admit(&self.classes[i], req, deadline) {
                Ok(rx) => return Admission::Enqueued(rx),
                // Lost an admission race: the class filled (or tripped)
                // between the scoring pass and the reservation.  Try the
                // next-best.
                Err(AdmitRefusal::Full(r)) | Err(AdmitRefusal::Quarantined(r)) => {
                    req = r;
                    tried |= 1u64 << i;
                }
            }
        }
        // Nothing admitted.  When every class is breaker-rejected the
        // refusal is a quarantine (the fleet is failing, not full);
        // otherwise it is the usual capacity shed.
        if self.classes.iter().all(|c| c.breaker.would_reject()) {
            return self.quarantine(self.least_loaded(), req, count_shed);
        }
        self.shed(self.least_loaded(), req, count_shed)
    }

    /// Non-blocking submit: validates the request, routes it, and either
    /// enqueues or returns a typed [`Admission::Shed`] when every
    /// candidate class is at its queue bound.
    pub fn try_submit(&self, req: GemmRequest) -> Admission {
        if let Err(reason) = req.validate() {
            return Admission::Rejected { reason };
        }
        self.try_submit_inner(req, None, true)
    }

    /// Non-blocking submit with a deadline: the envelope is dropped (and
    /// answered with a typed expired error) if it is still queued when
    /// `deadline` passes.
    pub fn try_submit_with_deadline(
        &self,
        req: GemmRequest,
        deadline: Instant,
    ) -> Admission {
        if let Err(reason) = req.validate() {
            return Admission::Rejected { reason };
        }
        self.try_submit_inner(req, Some(deadline), true)
    }

    /// Non-blocking submit *pinned* to a device class (router bypassed,
    /// queue bound still enforced).  `None` if the fleet has no such
    /// class.
    pub fn try_submit_to(&self, device: DeviceId, req: GemmRequest) -> Option<Admission> {
        let idx = self.classes.iter().position(|c| c.device == device)?;
        if let Err(reason) = req.validate() {
            return Some(Admission::Rejected { reason });
        }
        Some(match self.try_admit(&self.classes[idx], req, None) {
            Ok(rx) => Admission::Enqueued(rx),
            Err(AdmitRefusal::Full(req)) => self.shed(idx, req, true),
            Err(AdmitRefusal::Quarantined(req)) => self.quarantine(idx, req, true),
        })
    }

    /// A response synthesized on the submit path (invalid request,
    /// admission starvation): the receiver carries one typed error
    /// response instead of a dropped sender.  `device` is the class the
    /// failure concerns (the fleet's first class when none was chosen —
    /// validation failures happen before routing).
    fn synthetic_error(
        &self,
        device: DeviceId,
        outcome: RequestOutcome,
        message: String,
    ) -> mpsc::Receiver<GemmResponse> {
        let (tx, rx) = mpsc::channel();
        let _ = tx.send(GemmResponse {
            out: Err(anyhow!("{message}")),
            artifact: String::new(),
            kernel: String::new(),
            queue: Duration::ZERO,
            service: Duration::ZERO,
            epoch: 0,
            device,
            routed: device,
            shard: usize::MAX,
            outcome,
            pressure_pick: false,
            fused_batch_size: 0,
            retries: 0,
            failover: false,
        });
        rx
    }

    fn submit_blocking(
        &self,
        req: GemmRequest,
        deadline: Option<Instant>,
    ) -> mpsc::Receiver<GemmResponse> {
        if let Err(reason) = req.validate() {
            return self.synthetic_error(
                self.classes[0].device,
                RequestOutcome::Error,
                format!("invalid request: {reason}"),
            );
        }
        let give_up = Instant::now() + ADMISSION_PATIENCE;
        let mut req = req;
        loop {
            match self.try_submit_inner(req, deadline, false) {
                Admission::Enqueued(rx) => return rx,
                Admission::Rejected { reason } => {
                    return self.synthetic_error(
                        self.classes[0].device,
                        RequestOutcome::Error,
                        format!("invalid request: {reason}"),
                    );
                }
                Admission::Shed { req: r, device, outstanding, capacity } => {
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        // The wait for a queue slot consumed the
                        // deadline: a capacity refusal, counted as shed.
                        if let Some(c) =
                            self.classes.iter().find(|c| c.device == device)
                        {
                            // RELAXED: stats counter; merged after shutdown.
                            c.counters.shed.fetch_add(1, Ordering::Relaxed);
                        }
                        return self.synthetic_error(
                            device,
                            RequestOutcome::Expired,
                            format!(
                                "deadline expired awaiting admission on {device} \
                                 ({outstanding}/{capacity} outstanding)"
                            ),
                        );
                    }
                    if Instant::now() >= give_up {
                        return self.synthetic_error(
                            device,
                            RequestOutcome::Error,
                            format!(
                                "admission starved for {}s on {device} \
                                 ({outstanding}/{capacity} outstanding)",
                                ADMISSION_PATIENCE.as_secs()
                            ),
                        );
                    }
                    req = r;
                    std::thread::sleep(ADMISSION_BACKOFF);
                }
                Admission::Quarantined { req: r, device } => {
                    // Every candidate breaker is open: wait out the
                    // cooldown (the next pass probes) under the same
                    // deadline/patience bounds as a capacity wait.
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        return self.synthetic_error(
                            device,
                            RequestOutcome::Expired,
                            format!(
                                "deadline expired awaiting admission on {device} \
                                 (device quarantined)"
                            ),
                        );
                    }
                    if Instant::now() >= give_up {
                        return self.synthetic_error(
                            device,
                            RequestOutcome::Quarantined,
                            format!(
                                "no servable device class: every breaker open for \
                                 {}s (nearest: {device})",
                                ADMISSION_PATIENCE.as_secs()
                            ),
                        );
                    }
                    req = r;
                    std::thread::sleep(ADMISSION_BACKOFF);
                }
            }
        }
    }

    /// Submit a request; returns the channel the response arrives on.
    /// Blocks (bounded by an internal patience timeout) while every
    /// candidate class is at its queue bound — use [`try_submit`]
    /// (Self::try_submit) for explicit load-shedding.
    pub fn submit(&self, req: GemmRequest) -> mpsc::Receiver<GemmResponse> {
        self.submit_blocking(req, None)
    }

    /// Blocking submit with a deadline (see
    /// [`try_submit_with_deadline`](Self::try_submit_with_deadline)).
    pub fn submit_with_deadline(
        &self,
        req: GemmRequest,
        deadline: Instant,
    ) -> mpsc::Receiver<GemmResponse> {
        self.submit_blocking(req, Some(deadline))
    }

    /// Submit a request *pinned* to a device class, bypassing the router
    /// (still round-robined within the class, depth gauges and the queue
    /// bound maintained — blocks while the class is full, so pinned
    /// coverage traffic completes under overload instead of being shed).
    /// Coverage/diagnostic traffic: the hetero experiment scores every
    /// device's policy on identical pinned sweeps, so a device the
    /// router would rarely pick still gets measured (and its adaptation
    /// loop still gets telemetry).  `None` if the fleet has no such
    /// class.
    pub fn submit_to(
        &self,
        device: DeviceId,
        req: GemmRequest,
    ) -> Option<mpsc::Receiver<GemmResponse>> {
        let idx = self.classes.iter().position(|c| c.device == device)?;
        if let Err(reason) = req.validate() {
            return Some(self.synthetic_error(
                device,
                RequestOutcome::Error,
                format!("invalid request: {reason}"),
            ));
        }
        let give_up = Instant::now() + ADMISSION_PATIENCE;
        let mut req = req;
        loop {
            match self.try_admit(&self.classes[idx], req, None) {
                Ok(rx) => return Some(rx),
                // Full and quarantined both wait: pinned traffic is
                // diagnostic coverage, and waiting out an open breaker's
                // cooldown means the retry is admitted as the HalfOpen
                // probe that can close it.
                Err(AdmitRefusal::Full(r)) | Err(AdmitRefusal::Quarantined(r)) => {
                    if Instant::now() >= give_up {
                        let class = &self.classes[idx];
                        let (outcome, detail) = if class.breaker.would_reject() {
                            (RequestOutcome::Quarantined, "breaker open")
                        } else {
                            (RequestOutcome::Error, "queue full")
                        };
                        return Some(self.synthetic_error(
                            device,
                            outcome,
                            format!(
                                "admission starved for {}s pinned to {device} \
                                 ({detail}; {}/{} outstanding)",
                                ADMISSION_PATIENCE.as_secs(),
                                class.admission.outstanding(),
                                class.admission.capacity()
                            ),
                        ));
                    }
                    req = r;
                    std::thread::sleep(ADMISSION_BACKOFF);
                }
            }
        }
    }

    /// Submit and wait.
    pub fn call(&self, req: GemmRequest) -> Result<GemmResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server shut down before responding"))
    }

    /// Total dispatcher shards across every device class.
    pub fn shards(&self) -> usize {
        self.classes.iter().map(|c| c.txs.len()).sum()
    }

    /// Device classes behind this handle, in class order.
    pub fn devices(&self) -> Vec<DeviceId> {
        self.classes.iter().map(|c| c.device).collect()
    }

    /// Outstanding (admitted, unanswered) requests on a device class.
    pub fn outstanding(&self, device: DeviceId) -> Option<usize> {
        self.classes
            .iter()
            .find(|c| c.device == device)
            .map(|c| c.admission.outstanding())
    }

    /// The queue bound a device class admits up to.
    pub fn queue_capacity(&self, device: DeviceId) -> Option<usize> {
        self.classes
            .iter()
            .find(|c| c.device == device)
            .map(|c| c.admission.capacity())
    }

    /// Reset every class's peak-depth watermark.  Experiment harnesses
    /// warm compile caches through the serving path (legitimately
    /// filling queues), then measure the bounded-depth guarantee from a
    /// clean watermark.
    pub fn reset_peak_depth(&self) {
        for class in self.classes.iter() {
            // RELAXED: watermark reset between experiment phases; racing
            // admissions re-establish it immediately.
            class.counters.peak_depth.store(0, Ordering::Relaxed);
        }
    }
}

/// One failover destination: enough of a sibling class's state for a
/// worker to reserve a slot and forward an envelope.
struct FailoverTarget {
    device: DeviceId,
    profile: DeviceProfile,
    txs: Vec<mpsc::Sender<Envelope>>,
    depths: Vec<Arc<AtomicUsize>>,
    admission: Arc<AdmissionGauge>,
    breaker: Arc<CircuitBreaker>,
    counters: Arc<ClassCounters>,
}

/// The fleet's failover table, shared by every shard.  It holds cloned
/// envelope senders, so [`GemmServer::finish`] MUST clear it before
/// joining the workers — a populated table would keep every shard's
/// `recv()` alive and deadlock shutdown.  Workers send while holding the
/// lock (never cloning a sender out), so clearing the table is a hard
/// barrier: after `clear` returns, no forward is in flight.
#[derive(Default)]
struct FailoverTable {
    classes: Mutex<Vec<FailoverTarget>>,
}

impl FailoverTable {
    fn clear(&self) {
        self.classes.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }
}

/// Per-class coordination state the server keeps after startup.
struct ClassInfo {
    device: DeviceId,
    policy: Arc<PolicyHandle>,
    telemetry: Arc<TelemetryRing>,
    counters: Arc<ClassCounters>,
    breaker: Arc<CircuitBreaker>,
}

/// The running server.
pub struct GemmServer {
    handle: Option<ServerHandle>,
    workers: Vec<JoinHandle<Vec<RequestRecord>>>,
    started: Instant,
    classes: Vec<ClassInfo>,
    /// Drain flag: once set, shards answer queued envelopes with a typed
    /// shutdown error instead of serving them.
    stop: Arc<AtomicBool>,
    /// Failover destinations shared with every shard; cleared (before
    /// join) at shutdown so worker channels can close.
    failover: Arc<FailoverTable>,
}

impl std::fmt::Debug for GemmServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GemmServer").finish_non_exhaustive()
    }
}

impl GemmServer {
    /// Start a homogeneous (host-CPU-only) server with `cfg.shards`
    /// dispatcher shards — the classic single-device path, now one
    /// degenerate fleet.  The policy is installed into a fresh
    /// epoch-counted [`PolicyHandle`] ([`policy_handle`]
    /// (Self::policy_handle)); the adaptation loop hot-swaps retrained
    /// policies through it while the server runs.
    pub fn start(
        artifacts: &Path,
        policy: Box<dyn SelectPolicy>,
        cfg: ServerConfig,
    ) -> Result<GemmServer> {
        let cfg = cfg.validated()?;
        let classes = vec![DeviceClass::new(DeviceId::HostCpu, cfg.shards, policy)];
        Self::start_fleet(artifacts, classes, cfg)
    }

    /// Start a heterogeneous fleet: one engine-backed shard group per
    /// device class, each with its own policy slot and telemetry ring.
    /// Engines are created on their shards' threads; startup errors are
    /// reported synchronously through a ready-channel once every shard
    /// has checked in (all-or-nothing).
    pub fn start_fleet(
        artifacts: &Path,
        classes: Vec<DeviceClass>,
        cfg: ServerConfig,
    ) -> Result<GemmServer> {
        let cfg = cfg.validated()?;
        ensure!(!classes.is_empty(), "fleet needs at least one device class");
        ensure!(
            classes.len() <= 64,
            "fleet supports at most 64 device classes"
        );
        for (i, c) in classes.iter().enumerate() {
            ensure!(c.shards > 0, "device class {} needs shards > 0", c.device);
            ensure!(
                c.queue_capacity.is_none_or(|cap| cap > 0),
                "device class {} needs queue_capacity > 0",
                c.device
            );
            ensure!(
                classes[..i].iter().all(|p| p.device != c.device),
                "device class {} listed twice",
                c.device
            );
        }
        let n_workers: usize = classes.iter().map(|c| c.shards).sum();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let stop = Arc::new(AtomicBool::new(false));
        let failover = Arc::new(FailoverTable::default());
        let mut states = Vec::with_capacity(classes.len());
        let mut infos = Vec::with_capacity(classes.len());
        let mut workers = Vec::with_capacity(n_workers);
        let mut shard = 0usize; // fleet-global shard index
        for class in classes {
            let spec = EngineSpec::for_device(class.device);
            let capacity = class.queue_capacity.unwrap_or(cfg.queue_capacity);
            let policy = Arc::new(PolicyHandle::new(Arc::from(class.policy)));
            let telemetry = Arc::new(TelemetryRing::new(cfg.telemetry_capacity));
            let admission = Arc::new(AdmissionGauge::new(capacity));
            let counters = Arc::new(ClassCounters::default());
            let breaker =
                Arc::new(CircuitBreaker::new(class.breaker.unwrap_or(cfg.breaker)));
            let mut txs = Vec::with_capacity(class.shards);
            let mut depths = Vec::with_capacity(class.shards);
            for _ in 0..class.shards {
                let (tx, rx) = mpsc::channel::<Envelope>();
                let depth = Arc::new(AtomicUsize::new(0));
                txs.push(tx);
                depths.push(Arc::clone(&depth));
                let ctx = ShardCtx {
                    shard,
                    spec,
                    dir: artifacts.to_path_buf(),
                    policy: Arc::clone(&policy),
                    telemetry: Arc::clone(&telemetry),
                    depth,
                    admission: Arc::clone(&admission),
                    counters: Arc::clone(&counters),
                    stop: Arc::clone(&stop),
                    breaker: Arc::clone(&breaker),
                    failover: Arc::clone(&failover),
                    fault_plan: class.fault_plan.clone(),
                    cfg,
                };
                let ready_tx = ready_tx.clone();
                workers.push(std::thread::spawn(move || worker_loop(ctx, rx, ready_tx)));
                shard += 1;
            }
            states.push(ClassState {
                device: class.device,
                profile: DeviceProfile::get(class.device),
                policy: Arc::clone(&policy),
                cached: Mutex::new(policy.snapshot()),
                txs,
                depths,
                admission,
                counters: Arc::clone(&counters),
                next: AtomicUsize::new(0),
                breaker: Arc::clone(&breaker),
            });
            infos.push(ClassInfo {
                device: class.device,
                policy,
                telemetry,
                counters,
                breaker,
            });
        }
        drop(ready_tx);
        // Populate the failover table from the assembled classes (each
        // target includes enough state to reserve + forward; workers skip
        // their own device when scanning).
        {
            let mut table = failover.classes.lock().unwrap_or_else(|e| e.into_inner());
            *table = states
                .iter()
                .map(|s| FailoverTarget {
                    device: s.device,
                    profile: s.profile.clone(),
                    txs: s.txs.clone(),
                    depths: s.depths.clone(),
                    admission: Arc::clone(&s.admission),
                    breaker: Arc::clone(&s.breaker),
                    counters: Arc::clone(&s.counters),
                })
                .collect();
        }
        let handle = ServerHandle { classes: Arc::new(states) };
        let mut failures = Vec::new();
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => failures.push(msg),
                Err(_) => failures.push("server thread died during startup".to_string()),
            }
        }
        if !failures.is_empty() {
            // Drop every envelope sender (the handle's and the failover
            // table's) so healthy shards exit, then reap.
            failover.clear();
            drop(handle);
            for w in workers {
                let _ = w.join();
            }
            return Err(anyhow!("server startup failed: {}", failures.join("; ")));
        }
        Ok(GemmServer {
            handle: Some(handle),
            workers,
            started: Instant::now(),
            classes: infos,
            stop,
            failover,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.as_ref().expect("server running").clone()
    }

    /// Device classes of this fleet, in class order.
    pub fn devices(&self) -> Vec<DeviceId> {
        self.classes.iter().map(|c| c.device).collect()
    }

    /// The epoch-counted policy slot of the *first* device class — the
    /// whole fleet for homogeneous servers.  Swap a retrained policy in
    /// via [`PolicyHandle::swap`]; the class's shards pick it up at their
    /// next window boundary.
    pub fn policy_handle(&self) -> Arc<PolicyHandle> {
        Arc::clone(&self.classes[0].policy)
    }

    /// The telemetry ring of the first device class (empty unless
    /// `cfg.telemetry_fraction > 0`).
    pub fn telemetry(&self) -> Arc<TelemetryRing> {
        Arc::clone(&self.classes[0].telemetry)
    }

    /// A specific device class's policy slot.
    pub fn policy_handle_for(&self, device: DeviceId) -> Option<Arc<PolicyHandle>> {
        self.classes
            .iter()
            .find(|c| c.device == device)
            .map(|c| Arc::clone(&c.policy))
    }

    /// A specific device class's telemetry ring.  Shards only ever push
    /// to their own class's ring, so per-device training data never
    /// cross-contaminates.
    pub fn telemetry_for(&self, device: DeviceId) -> Option<Arc<TelemetryRing>> {
        self.classes
            .iter()
            .find(|c| c.device == device)
            .map(|c| Arc::clone(&c.telemetry))
    }

    /// A specific device class's circuit breaker (observability: chaos
    /// harnesses poll quarantine/recovery transitions through this).
    pub fn breaker_for(&self, device: DeviceId) -> Option<Arc<CircuitBreaker>> {
        self.classes
            .iter()
            .find(|c| c.device == device)
            .map(|c| Arc::clone(&c.breaker))
    }

    /// Shut down and collect serving statistics (None if nothing was
    /// served or shed).  Queued envelopes are served out before the
    /// shards exit; use [`shutdown_now`](Self::shutdown_now) to answer
    /// them with a shutdown error instead.
    pub fn shutdown(self) -> Option<ServeStats> {
        self.finish()
    }

    /// Graceful *drain* shutdown: raise the stop flag first, so shards
    /// answer every still-queued envelope with a typed
    /// [`RequestOutcome::Drained`] shutdown error instead of spending
    /// service time on it — no reply channel is ever silently dropped,
    /// and shutdown latency is bounded by the in-flight window.
    pub fn shutdown_now(self) -> Option<ServeStats> {
        self.stop.store(true, Ordering::Release);
        self.finish()
    }

    fn finish(mut self) -> Option<ServeStats> {
        let wall = self.started.elapsed();
        // Clear the failover table FIRST: it holds cloned envelope
        // senders, and a worker's recv() only errors out once every
        // sender is gone.  Clearing takes the table lock, so no forward
        // is mid-send when it returns.
        self.failover.clear();
        // Then drop our sender references so each shard's recv() errors
        // out once all client handles are gone.
        self.handle = None;
        let mut records = Vec::new();
        for w in self.workers.drain(..) {
            if let Ok(mut r) = w.join() {
                records.append(&mut r);
            }
        }
        let total_shed: u64 = self
            .classes
            .iter()
            .map(|c| {
                // RELAXED: read after the workers are joined.
                c.counters.shed.load(Ordering::Relaxed)
                    + c.counters.quarantined.load(Ordering::Relaxed)
            })
            .sum();
        if records.is_empty() && total_shed == 0 {
            return None;
        }
        let mut stats = ServeStats::from_records(&records, wall);
        for c in &self.classes {
            // RELAXED: all counter reads below happen after the worker
            // threads are joined; there is nothing left to race with.
            stats.record_admission(
                c.device,
                c.counters.shed.load(Ordering::Relaxed),
                c.counters.pressure_picks.load(Ordering::Relaxed),
                c.counters.peak_depth.load(Ordering::Relaxed),
            );
            let mut hist = [0u64; OCCUPANCY_BUCKETS];
            for (h, bucket) in hist.iter_mut().zip(&c.counters.occupancy) {
                // RELAXED: post-join read (see above).
                *h = bucket.load(Ordering::Relaxed);
            }
            stats.record_fusion(
                c.device,
                // RELAXED: post-join reads (see above).
                c.counters.dispatches.load(Ordering::Relaxed),
                c.counters.fused_requests.load(Ordering::Relaxed),
                Duration::from_nanos(c.counters.fused_saved_ns.load(Ordering::Relaxed)),
                hist,
            );
            stats.record_resilience(
                c.device,
                // RELAXED: post-join reads (see above).
                c.counters.quarantined.load(Ordering::Relaxed),
                c.counters.retries.load(Ordering::Relaxed),
                c.counters.failovers.load(Ordering::Relaxed),
                c.counters.shadow_errors.load(Ordering::Relaxed),
                [c.breaker.opens(), c.breaker.half_opens(), c.breaker.closes()],
            );
        }
        Some(stats)
    }
}

/// Everything a dispatcher shard needs, bundled for the thread spawn.
struct ShardCtx {
    shard: usize,
    spec: EngineSpec,
    dir: PathBuf,
    policy: Arc<PolicyHandle>,
    telemetry: Arc<TelemetryRing>,
    depth: Arc<AtomicUsize>,
    admission: Arc<AdmissionGauge>,
    counters: Arc<ClassCounters>,
    stop: Arc<AtomicBool>,
    breaker: Arc<CircuitBreaker>,
    failover: Arc<FailoverTable>,
    fault_plan: Option<FaultPlan>,
    cfg: ServerConfig,
}

/// Deterministic fraction sampler: accumulate the fraction per event and
/// fire on whole-number crossings (no RNG, no state beyond one f64).
struct FractionSampler {
    fraction: f64,
    acc: f64,
}

impl FractionSampler {
    fn new(fraction: f64) -> FractionSampler {
        FractionSampler { fraction: fraction.clamp(0.0, 1.0), acc: 0.0 }
    }

    fn fire(&mut self) -> bool {
        if self.fraction <= 0.0 {
            return false;
        }
        self.acc += self.fraction;
        if self.acc >= 1.0 {
            self.acc -= 1.0;
            true
        } else {
            false
        }
    }
}

/// How a window envelope resolves before execution.
enum EnvAction {
    Serve { pressure_pick: bool },
    Expire,
}

/// Per-request record kept while serving, with the dense id; names
/// resolve once at shard exit.
struct RawRecord {
    id: Option<ArtifactId>,
    queue: Duration,
    service: Duration,
    flops: f64,
    outcome: RequestOutcome,
    /// Fused-batch size the request executed in (0 = never executed).
    fused: usize,
}

/// One dispatcher shard: batches, selects (with deadline and pressure
/// awareness), executes on its device engine's pooled path, and feeds
/// its class's telemetry tap.
fn worker_loop(
    ctx: ShardCtx,
    rx: mpsc::Receiver<Envelope>,
    ready_tx: mpsc::Sender<Result<(), String>>,
) -> Vec<RequestRecord> {
    let ShardCtx {
        shard,
        spec,
        dir,
        policy,
        telemetry,
        depth,
        admission,
        counters,
        stop,
        breaker,
        failover,
        fault_plan,
        cfg,
    } = ctx;
    let device = spec.device();
    let profile = DeviceProfile::get(device);
    let mut engine: Box<dyn ExecutionEngine> = match spec.build(&dir) {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{device}: {e:#}")));
            return Vec::new();
        }
    };
    if let Some(plan) = fault_plan {
        // The chaos seam: scripted faults sit between the shard and its
        // engine, indistinguishable from real device failures.
        engine = Box::new(FaultInjector::new(engine, plan));
    }
    drop(ready_tx);
    let mut scratch = ScratchBuffers::new();
    let mut batch = BatchScratch::new();
    // Reusable fused-run staging: (pressure_pick, envelope) members of
    // the chunk currently being dispatched.  Hoisted so steady-state
    // windows reuse its capacity.  Per-member latency accounting at
    // reply time: `queue = submit-to-reply elapsed - service`, so
    // `queue + service` is exactly the client-observed latency (waiting
    // for fused batch peers counts as queueing, and the dispatch wall
    // is never double-counted).
    let mut chunk: Vec<(bool, Envelope)> = Vec::new();
    // Shard-local policy snapshot, refreshed once per window: every
    // request inside a window is resolved under exactly one policy
    // epoch, so a concurrent hot-swap can never mix configurations
    // within a request (or a window).
    let mut cached: CachedPolicy = policy.snapshot();
    let mut tele_sampler = FractionSampler::new(cfg.telemetry_fraction);
    let mut shadow_sampler = FractionSampler::new(cfg.shadow_fraction);
    // Rotates through the alternative artifacts so repeated shadow runs
    // on one triple eventually cover every candidate.
    let mut shadow_rotation = shard; // offset per shard for coverage
    // Records keep the dense id while serving; names are resolved once at
    // shard exit so the hot path does not allocate per-request Strings
    // beyond the response boundary.
    let mut raw_records: Vec<RawRecord> = Vec::new();
    let mut window: Vec<Envelope> = Vec::with_capacity(cfg.max_batch);
    loop {
        // Block for the first request of a window.
        match rx.recv() {
            Err(_) => break, // all senders dropped: shutdown
            Ok(env) => window.push(env),
        }
        // Fill the window for up to `batch_window` (skipped while
        // draining: stop-flagged shards answer as fast as possible).
        if !stop.load(Ordering::Acquire) {
            let deadline = Instant::now() + cfg.batch_window;
            while window.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(env) => window.push(env),
                    Err(_) => break,
                }
            }
        }
        // Window boundary: pick up a hot-swapped policy if one was
        // published.  One atomic load when nothing changed.
        policy.refresh(&mut cached);
        if stop.load(Ordering::Acquire) {
            // Graceful drain: answer every queued envelope with a typed
            // shutdown error instead of serving it.
            for env in window.drain(..) {
                answer_unserved(
                    env,
                    RequestOutcome::Drained,
                    cached.epoch,
                    device,
                    shard,
                    &depth,
                    &admission,
                    &breaker,
                    &mut raw_records,
                    None,
                );
            }
            continue;
        }
        // Resolve each request to a dense artifact id, then group the
        // window by (id, triple) (stable sort keeps FIFO order within a
        // group) — the dynamic batcher, with no string keys on the hot
        // path.  Already-expired envelopes are dropped here, *before*
        // fusion grouping — an expired envelope never inflates a fused
        // batch or its occupancy stats; envelopes that queued past the
        // pressure threshold resolve through the pressure pick.
        let now = Instant::now();
        let mut resolved: Vec<(Option<ArtifactId>, EnvAction, Envelope)> = window
            .drain(..)
            .map(|env| {
                if env.deadline.is_some_and(|d| now >= d) {
                    return (None, EnvAction::Expire, env);
                }
                let t = env.req.triple();
                let cfg_sel = cached.select(t);
                let id = engine.resolve(&cfg_sel, t);
                let pressured = now.saturating_duration_since(env.submitted)
                    >= cfg.pressure_threshold;
                if pressured {
                    let (picked, swapped) = pressure_resolve(
                        &*engine,
                        &profile,
                        id,
                        t,
                        cfg.pressure_slowdown,
                    );
                    if swapped {
                        // RELAXED: stats counter; merged after shutdown.
                        counters.pressure_picks.fetch_add(1, Ordering::Relaxed);
                    }
                    (picked, EnvAction::Serve { pressure_pick: swapped }, env)
                } else {
                    (id, EnvAction::Serve { pressure_pick: false }, env)
                }
            })
            .collect();
        resolved.sort_by_key(|(id, _, env)| (*id, env.req.triple()));

        // Walk the sorted window and *fuse* maximal same-(artifact,
        // triple) runs into batched dispatches of up to `max_fuse`
        // members — a mixed-triple window splits into one fused batch
        // per distinct (id, triple) run.  Expired and unservable
        // envelopes were never part of a run and answer individually.
        let mut queue_iter = resolved.into_iter().peekable();
        while let Some((id, action, env)) = queue_iter.next() {
            let EnvAction::Serve { pressure_pick } = action else {
                answer_unserved(
                    env,
                    RequestOutcome::Expired,
                    cached.epoch,
                    device,
                    shard,
                    &depth,
                    &admission,
                    &breaker,
                    &mut raw_records,
                    None,
                );
                continue;
            };
            let Some(id) = id else {
                // No artifact accepts the triple: a per-request typed
                // error, never grouped into a batch.
                let message =
                    format!("no artifact accepts {} on {device}", env.req.triple());
                // A selection gap is not device ill-health: the breaker
                // never hears about it (probe tokens are just returned).
                answer_unserved(
                    env,
                    RequestOutcome::Error,
                    cached.epoch,
                    device,
                    shard,
                    &depth,
                    &admission,
                    &breaker,
                    &mut raw_records,
                    Some(message),
                );
                continue;
            };
            let t = env.req.triple();
            chunk.clear();
            chunk.push((pressure_pick, env));
            while chunk.len() < cfg.max_fuse {
                let same_run = matches!(
                    queue_iter.peek(),
                    Some((Some(next_id), EnvAction::Serve { .. }, next_env))
                        if *next_id == id && next_env.req.triple() == t
                );
                if !same_run {
                    break;
                }
                let Some((_, EnvAction::Serve { pressure_pick }, env)) =
                    queue_iter.next()
                else {
                    unreachable!("peek said the run continues");
                };
                chunk.push((pressure_pick, env));
            }

            // Execute the fused run: size 1 goes through the classic
            // pooled path (identical to the pre-fusion server), size
            // >= 2 through the engine's batched surface.
            let fuse = chunk.len();
            let mn = (t.m as usize) * (t.n as usize);
            let t0 = Instant::now();
            let exec_err: Option<anyhow::Error> = if fuse == 1 {
                let input = gemm_input(&chunk[0].1.req);
                match engine.execute_pooled(id, &input, &mut scratch) {
                    Ok(times) => {
                        batch.times.clear();
                        batch.times.push(times);
                        batch.saved = Duration::ZERO;
                        None
                    }
                    Err(e) => Some(e),
                }
            } else {
                let inputs: Vec<GemmInput> =
                    chunk.iter().map(|(_, env)| gemm_input(&env.req)).collect();
                match engine.execute_batch_pooled(id, &inputs, &mut batch) {
                    // Contract check: a typed per-member error beats an
                    // index panic that would kill the shard thread if an
                    // engine ever under-fills the batch.
                    Ok(()) if batch.times.len() == fuse
                        && batch.out.len() == fuse * mn => None,
                    Ok(()) => Some(anyhow!(
                        "engine returned {} slot timings / {} output elements \
                         for a fused batch of {fuse} ({} expected)",
                        batch.times.len(),
                        batch.out.len(),
                        fuse * mn
                    )),
                    Err(e) => Some(e),
                }
            };
            let wall = t0.elapsed();

            if let Some(e) = exec_err {
                // One breaker mark per failed *dispatch* — a single
                // poisoned batch must not trip the consecutive-failure
                // rule on its own.
                breaker.record_failure();
                let emsg = format!("{e:#}");
                for (pressure_pick, mut env) in chunk.drain(..) {
                    let mut message = if fuse == 1 {
                        emsg.clone()
                    } else {
                        format!("fused batch of {fuse} failed on {device}: {emsg}")
                    };
                    // (a) Fused members retry *individually* on the same
                    // engine first: one poisoned member can't fail its
                    // batch peers twice.
                    if fuse > 1
                        && env.retries < cfg.retry_budget
                        && !stop.load(Ordering::Acquire)
                    {
                        env.retries += 1;
                        // RELAXED: stats counter; merged after shutdown.
                        counters.retries.fetch_add(1, Ordering::Relaxed);
                        let input = gemm_input(&env.req);
                        match engine.execute_pooled(id, &input, &mut scratch) {
                            Ok(times) => {
                                if env.probe {
                                    breaker.record_probe(true);
                                } else {
                                    breaker.record_success();
                                }
                                counters.record_dispatch(1, Duration::ZERO);
                                let service = times.total_time();
                                let queue =
                                    env.submitted.elapsed().saturating_sub(service);
                                raw_records.push(RawRecord {
                                    id: Some(id),
                                    queue,
                                    service,
                                    flops: t.flops(),
                                    outcome: RequestOutcome::Ok,
                                    fused: 1,
                                });
                                let _ = env.reply.send(GemmResponse {
                                    out: Ok(scratch.out.clone()),
                                    artifact: engine
                                        .manifest()
                                        .name_of(id)
                                        .to_string(),
                                    kernel: engine
                                        .manifest()
                                        .meta(id)
                                        .config
                                        .name(),
                                    queue,
                                    service,
                                    epoch: cached.epoch,
                                    device,
                                    routed: env.routed,
                                    shard,
                                    outcome: RequestOutcome::Ok,
                                    pressure_pick,
                                    fused_batch_size: 1,
                                    retries: env.retries,
                                    failover: env.failover,
                                });
                                // RELAXED: advisory shard gauge; the
                                // admission gauge release is the bound.
                                depth.fetch_sub(1, Ordering::Relaxed);
                                admission.release();
                                // Retried requests never feed telemetry:
                                // a flaky engine must not label trainer
                                // data through its own failures.
                                continue;
                            }
                            Err(e2) => {
                                breaker.record_failure();
                                message = format!(
                                    "{message}; individual retry failed: {e2:#}"
                                );
                            }
                        }
                    }
                    // (b) Fail over to the modeled-cheapest healthy
                    // sibling class, deadline allowing.  On success the
                    // sibling owns the reply channel (exactly one
                    // response either way).
                    env = match try_failover(
                        &failover,
                        env,
                        device,
                        engine.manifest(),
                        &breaker,
                        &counters,
                        &depth,
                        &admission,
                        cfg.retry_budget,
                        &stop,
                    ) {
                        Ok(()) => continue,
                        Err(env) => env,
                    };
                    // (c) Out of options: typed per-request error — no
                    // reply channel is ever dropped.  Nothing executed,
                    // so the batch never enters the occupancy ledger
                    // (records carry fused = 0); responses still report
                    // the batch size they died in.
                    if env.probe {
                        breaker.record_probe(false);
                        env.probe = false;
                    }
                    // queue + service == full submit-to-reply latency.
                    let queue = env.submitted.elapsed().saturating_sub(wall);
                    raw_records.push(RawRecord {
                        id: Some(id),
                        queue,
                        service: wall,
                        flops: 0.0,
                        outcome: RequestOutcome::Error,
                        fused: 0,
                    });
                    let _ = env.reply.send(GemmResponse {
                        out: Err(anyhow!("{message}")),
                        artifact: engine.manifest().name_of(id).to_string(),
                        kernel: engine.manifest().meta(id).config.name(),
                        queue,
                        service: wall,
                        epoch: cached.epoch,
                        device,
                        routed: env.routed,
                        shard,
                        outcome: RequestOutcome::Error,
                        pressure_pick,
                        fused_batch_size: fuse,
                        retries: env.retries,
                        failover: env.failover,
                    });
                    // RELAXED: advisory shard gauge; the admission
                    // gauge release is the bound.
                    depth.fetch_sub(1, Ordering::Relaxed);
                    admission.release();
                }
                continue;
            }

            counters.record_dispatch(fuse, batch.saved);
            // Wall time the per-slot attribution does not cover (compile
            // on a cold artifact, batch staging overhead): spread evenly
            // so member services sum back to the dispatch wall, exactly
            // like the single-request path where service == wall.
            let attributed: Duration =
                batch.times.iter().map(|gt| gt.total_time()).sum();
            let residual = wall.saturating_sub(attributed) / fuse as u32;
            for (slot, (pressure_pick, env)) in chunk.drain(..).enumerate() {
                let times = batch.times[slot];
                let service = times.total_time() + residual;
                // Client-observed latency splits into service (this
                // request's per-slot share) and queue (everything else:
                // window wait, batch peers' slots) — their sum is the
                // exact submit-to-reply interval, like the pre-fusion
                // path.
                let queue = env.submitted.elapsed().saturating_sub(service);
                // The response must outlive the scratch pools: the
                // copy-out is the one boundary allocation.
                let out_vec = if fuse == 1 {
                    scratch.out.clone()
                } else {
                    batch.out[slot * mn..(slot + 1) * mn].to_vec()
                };
                raw_records.push(RawRecord {
                    id: Some(id),
                    queue,
                    service,
                    flops: t.flops(),
                    outcome: RequestOutcome::Ok,
                    fused: fuse,
                });
                let _ = env.reply.send(GemmResponse {
                    out: Ok(out_vec),
                    artifact: engine.manifest().name_of(id).to_string(),
                    kernel: engine.manifest().meta(id).config.name(),
                    queue,
                    service,
                    epoch: cached.epoch,
                    device,
                    routed: env.routed,
                    shard,
                    outcome: RequestOutcome::Ok,
                    pressure_pick,
                    fused_batch_size: fuse,
                    retries: env.retries,
                    failover: env.failover,
                });
                if env.probe {
                    breaker.record_probe(true);
                } else {
                    breaker.record_success();
                }
                // The request is answered: release its depth-gauge slots
                // so the router and the admission bound see the real
                // backlog.
                // RELAXED: advisory shard gauge; the admission gauge
                // release is the bound.
                depth.fetch_sub(1, Ordering::Relaxed);
                admission.release();
                // Telemetry tap — after the reply, entirely off the
                // response path.  `times` excludes compile *and* the
                // fusion amortization (per-slot attribution), so the
                // sample stays comparable to the shadow measurement and
                // to un-fused oracle runs.  Requests that arrived here
                // through retry/failover are excluded: their service
                // numbers carry another class's failure story and would
                // mislabel trainer data.
                if env.retries == 0 && tele_sampler.fire() {
                    let shadow = if shadow_sampler.fire() {
                        match shadow_execute(
                            &mut *engine,
                            &mut scratch,
                            id,
                            &env.req,
                            &mut shadow_rotation,
                        ) {
                            Ok(s) => s,
                            Err(_) => {
                                // Shadow failures live in their own
                                // ledger: they never feed the breaker or
                                // the trainer.
                                // RELAXED: stats counter.
                                counters.shadow_errors.fetch_add(1, Ordering::Relaxed);
                                None
                            }
                        }
                    } else {
                        None
                    };
                    telemetry.push(TelemetryRecord {
                        triple: t,
                        served: engine.manifest().meta(id).config,
                        service_secs: times.total_time().as_secs_f64(),
                        fused: fuse,
                        shadow,
                        epoch: cached.epoch,
                        device,
                        shard,
                    });
                }
            }
        }
    }
    raw_records
        .into_iter()
        .map(|raw| RequestRecord {
            artifact: raw
                .id
                .map(|id| engine.manifest().name_of(id).to_string())
                .unwrap_or_default(),
            device,
            shard,
            queue: raw.queue,
            service: raw.service,
            flops: raw.flops,
            outcome: raw.outcome,
            fused: raw.fused,
        })
        .collect()
}

/// Answer an envelope without executing it (graceful drain / deadline
/// expiry / no eligible artifact): typed error reply, depth gauges
/// released, outcome recorded.  `message` overrides the outcome-derived
/// default error text.
#[allow(clippy::too_many_arguments)]
fn answer_unserved(
    env: Envelope,
    outcome: RequestOutcome,
    epoch: u64,
    device: DeviceId,
    shard: usize,
    depth: &AtomicUsize,
    admission: &AdmissionGauge,
    breaker: &CircuitBreaker,
    raw: &mut Vec<RawRecord>,
    message: Option<String>,
) {
    // The request never reached the engine, so a probe token proves
    // nothing about device health either way: release it unjudged so
    // the half-open budget is not leaked.
    if env.probe {
        breaker.release_probe();
    }
    let queue = env.submitted.elapsed();
    raw.push(RawRecord {
        id: None,
        queue,
        service: Duration::ZERO,
        flops: 0.0,
        outcome,
        fused: 0,
    });
    let message = message.unwrap_or_else(|| match outcome {
        RequestOutcome::Expired => format!(
            "overload: deadline expired after {:.3}ms queued on {device}",
            queue.as_secs_f64() * 1e3
        ),
        RequestOutcome::Ok
        | RequestOutcome::Error
        | RequestOutcome::Drained
        | RequestOutcome::Quarantined => {
            format!("server shutting down; request drained unserved on {device}")
        }
    });
    let _ = env.reply.send(GemmResponse {
        out: Err(anyhow!("{message}")),
        artifact: String::new(),
        kernel: String::new(),
        queue,
        service: Duration::ZERO,
        epoch,
        device,
        routed: env.routed,
        shard,
        outcome,
        pressure_pick: false,
        fused_batch_size: 0,
        retries: env.retries,
        failover: env.failover,
    });
    // RELAXED: advisory shard gauge; the admission gauge release is the
    // bound.
    depth.fetch_sub(1, Ordering::Relaxed);
    admission.release();
}

fn gemm_input(req: &GemmRequest) -> GemmInput<'_> {
    GemmInput {
        m: req.m,
        n: req.n,
        k: req.k,
        a: &req.a,
        b: &req.b,
        c: &req.c,
        alpha: req.alpha,
        beta: req.beta,
    }
}

/// Modeled-cheapest servable artifact for `t` on an *arbitrary* device
/// profile — the failover analogue of [`ExecutionEngine::modeled_cheapest`],
/// which can only price its own device.  One pass over the small
/// immutable manifest; legality is re-checked against the target profile
/// because sibling classes differ in register/LDS budgets.
fn cheapest_modeled_for(
    manifest: &Manifest,
    profile: &DeviceProfile,
    t: Triple,
) -> Option<(ArtifactId, f64)> {
    let mut best: Option<(ArtifactId, f64)> = None;
    for i in 0..manifest.len() as u32 {
        let id = ArtifactId(i);
        let meta = manifest.meta(id);
        if !meta.accepts(t) || !profile.is_legal(&meta.config) {
            continue;
        }
        let Some(secs) = sim::modeled_secs(profile, &meta.config, t) else {
            continue;
        };
        if best.map_or(true, |(_, b)| secs < b) {
            best = Some((id, secs));
        }
    }
    best
}

/// Deadline-aware failover: re-home a failed envelope onto the
/// modeled-cheapest *healthy* sibling class, if the retry budget and the
/// remaining deadline afford its modeled service time.  On `Ok` the
/// sibling shard owns the reply channel (the exactly-one-response
/// invariant transfers with the envelope); on `Err` the caller still
/// holds the envelope and must answer it.
///
/// The shard sends while holding the table lock — the lock is only ever
/// contended by other failing shards and by [`FailoverTable::clear`],
/// which `finish()` runs *before* joining workers precisely so no send
/// can race a disconnected receiver into a panic-free-but-lost reply.
#[allow(clippy::too_many_arguments)]
fn try_failover(
    table: &FailoverTable,
    mut env: Envelope,
    own_device: DeviceId,
    manifest: &Manifest,
    breaker: &CircuitBreaker,
    counters: &ClassCounters,
    depth: &AtomicUsize,
    admission: &AdmissionGauge,
    retry_budget: u32,
    stop: &AtomicBool,
) -> std::result::Result<(), Envelope> {
    if env.retries >= retry_budget || stop.load(Ordering::Acquire) {
        return Err(env);
    }
    // Whatever remains of the client's deadline must cover the sibling's
    // modeled service time — failover that arrives late is just a slower
    // error.
    let remaining = match env.deadline {
        Some(d) => {
            let r = d.saturating_duration_since(Instant::now());
            if r.is_zero() {
                return Err(env);
            }
            Some(r.as_secs_f64())
        }
        None => None,
    };
    let t = env.req.triple();
    let classes = table.classes.lock().unwrap();
    let mut pick: Option<(usize, ArtifactId, f64)> = None;
    for (i, target) in classes.iter().enumerate() {
        if target.device == own_device || !target.breaker.is_closed() {
            continue;
        }
        if target.admission.is_full() {
            continue;
        }
        let Some((id, secs)) = cheapest_modeled_for(manifest, &target.profile, t)
        else {
            continue;
        };
        if remaining.is_some_and(|r| secs > r) {
            continue;
        }
        if pick.map_or(true, |(_, _, b)| secs < b) {
            pick = Some((i, id, secs));
        }
    }
    let Some((idx, _, _)) = pick else {
        return Err(env);
    };
    let target = &classes[idx];
    // Same reserve-then-rollback admission the front door uses: the
    // sibling's bound holds even against racing clients.
    if target.admission.try_reserve().is_none() {
        return Err(env);
    }
    // RELAXED: advisory shard-pick read and gauge bump; the bound is
    // held by the sibling gauge's AcqRel reservation above.
    let shard_idx = target
        .depths
        .iter()
        .enumerate()
        .min_by_key(|(_, d)| d.load(Ordering::Relaxed))
        .map(|(i, _)| i)
        .unwrap_or(0);
    // RELAXED: advisory depth gauge (bound held by the gauge above).
    target.depths[shard_idx].fetch_add(1, Ordering::Relaxed);
    // The probe verdict belongs to *this* device: the engine failed, so
    // the probe failed — the sibling's success must not vouch for us.
    if env.probe {
        breaker.record_probe(false);
        env.probe = false;
    }
    env.retries += 1;
    env.failover = true;
    // RELAXED: stats counters; merged after shutdown.
    counters.retries.fetch_add(1, Ordering::Relaxed);
    counters.failovers.fetch_add(1, Ordering::Relaxed);
    match target.txs[shard_idx].send(env) {
        Ok(()) => {
            // The envelope now occupies the sibling's gauges; release
            // ours.
            // RELAXED: advisory shard gauge; the admission gauge
            // release is the bound.
            depth.fetch_sub(1, Ordering::Relaxed);
            admission.release();
            Ok(())
        }
        Err(mpsc::SendError(env)) => {
            // RELAXED: advisory gauge and stats rollback on send
            // failure; the admission release is the bound.
            target.depths[shard_idx].fetch_sub(1, Ordering::Relaxed);
            target.admission.release();
            counters.failovers.fetch_sub(1, Ordering::Relaxed);
            Err(env)
        }
    }
}

/// The pressure pick: under queue pressure, find the modeled-cheapest
/// servable artifact for `t` and override the policy's resolution when
/// it is more than `slowdown` times slower — the overload feedback from
/// system state into the paper's model-driven selection.  Returns the id
/// to serve plus whether the policy's choice was overridden.
/// Allocation-free: one pass over the small immutable manifest, pure
/// arithmetic per candidate ([`sim::modeled_secs`]).
fn pressure_resolve(
    engine: &dyn ExecutionEngine,
    profile: &DeviceProfile,
    policy_id: Option<ArtifactId>,
    t: Triple,
    slowdown: f64,
) -> (Option<ArtifactId>, bool) {
    let Some((best_id, best_secs)) = engine.modeled_cheapest(profile, t) else {
        // Nothing measurable: leave the policy's resolution alone.
        return (policy_id, false);
    };
    match policy_id {
        Some(pid) if pid == best_id => (policy_id, false),
        Some(pid) => {
            let policy_secs =
                sim::modeled_secs(profile, &engine.manifest().meta(pid).config, t);
            match policy_secs {
                // Within the slowdown bound: the policy's (likely
                // throughput-optimal) pick stands — pressure never
                // churns selections that are already cheap enough.
                Some(p) if p <= best_secs * slowdown => (policy_id, false),
                _ => (Some(best_id), true),
            }
        }
        None => (Some(best_id), true),
    }
}

/// Pick the `rotation`-th alternative (wrapping) among the artifacts that
/// are shape-eligible, device-servable, and not the one that already
/// served the request.  Gracefully returns `None` — never panics — even
/// if the eligible set shrinks between the counting pass and the
/// selection pass (e.g. an engine whose servability answer changes),
/// where the old `expect("count > rotation index")` would have killed the
/// shard thread.
fn select_shadow_alternative(
    engine: &dyn ExecutionEngine,
    served: ArtifactId,
    t: Triple,
    rotation: usize,
) -> Option<ArtifactId> {
    let n = engine.manifest().len() as u32;
    let eligible = |id: &ArtifactId| {
        *id != served
            && engine.is_servable(*id)
            && engine.manifest().meta(*id).accepts(t)
    };
    let count = (0..n).map(ArtifactId).filter(&eligible).count();
    if count == 0 {
        return None;
    }
    (0..n).map(ArtifactId).filter(&eligible).nth(rotation % count)
}

/// Spend shadow budget on one request: re-execute it on an *alternative*
/// eligible artifact (rotating through the candidates) and measure it
/// under identical operands.  Runs after the reply is sent, so the cost
/// is shard throughput — the request that was shadowed never waits, but
/// later requests queued on this shard do; that is exactly the budget
/// `shadow_fraction` caps.  The candidate scan is allocation-free (two
/// passes over the small immutable manifest) and the scratch pool is
/// reused — the response already copied its result out.
///
/// `Ok(None)` means no alternative artifact was eligible; `Err` means an
/// alternative *failed* — the caller books it in the `shadow_errors`
/// ledger so a faulty shadow run never masquerades as "no candidate" and
/// never reaches the breaker or the trainer.
fn shadow_execute(
    engine: &mut dyn ExecutionEngine,
    scratch: &mut ScratchBuffers,
    served: ArtifactId,
    req: &GemmRequest,
    rotation: &mut usize,
) -> Result<Option<(crate::config::KernelConfig, f64)>> {
    let Some(alt) = select_shadow_alternative(engine, served, req.triple(), *rotation)
    else {
        return Ok(None);
    };
    *rotation = rotation.wrapping_add(1);
    // Compile outside the measurement, like the served path.
    engine.ensure_ready(alt)?;
    let times = engine.execute_pooled(alt, &gemm_input(req), scratch)?;
    Ok(Some((
        engine.manifest().meta(alt).config,
        times.total_time().as_secs_f64(),
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimEngine;
    use crate::runtime::{GemmTimes, Manifest};

    #[test]
    fn server_config_validation_edges() {
        assert!(ServerConfig::with_shards(0).validated().is_err());
        let err = ServerConfig::with_shards(0).validated().unwrap_err();
        assert!(err.to_string().contains("shards"), "{err}");
        let bad_batch = ServerConfig { max_batch: 0, ..ServerConfig::default() };
        let err = bad_batch.validated().unwrap_err();
        assert!(err.to_string().contains("max_batch"), "{err}");
        // A zero fuse cap would make every window dispatch nothing:
        // hard error; 1 is the legitimate fusion-off spelling.
        let bad_fuse = ServerConfig { max_fuse: 0, ..ServerConfig::default() };
        let err = bad_fuse.validated().unwrap_err();
        assert!(err.to_string().contains("max_fuse"), "{err}");
        let fusion_off = ServerConfig { max_fuse: 1, ..ServerConfig::default() };
        assert_eq!(fusion_off.validated().unwrap().max_fuse, 1);
        // A zero queue bound would shed everything: hard error, like
        // shards/max_batch.
        let bad_cap = ServerConfig { queue_capacity: 0, ..ServerConfig::default() };
        let err = bad_cap.validated().unwrap_err();
        assert!(err.to_string().contains("queue_capacity"), "{err}");
        // Out-of-range fractions clamp instead of erroring.
        let cfg = ServerConfig::adaptive(2, 1.5, -0.25).validated().unwrap();
        assert_eq!(cfg.telemetry_fraction, 1.0);
        assert_eq!(cfg.shadow_fraction, 0.0);
        // The pressure slowdown is a factor >= 1.0; NaN falls back to 1.0.
        let cfg = ServerConfig { pressure_slowdown: 0.25, ..ServerConfig::default() }
            .validated()
            .unwrap();
        assert_eq!(cfg.pressure_slowdown, 1.0);
        let cfg = ServerConfig { pressure_slowdown: f64::NAN, ..ServerConfig::default() }
            .validated()
            .unwrap();
        assert_eq!(cfg.pressure_slowdown, 1.0);
        // A sane config passes through unchanged.
        let cfg = ServerConfig::adaptive(4, 0.5, 0.25).validated().unwrap();
        assert_eq!((cfg.shards, cfg.max_batch, cfg.max_fuse), (4, 32, 16));
        assert_eq!((cfg.telemetry_fraction, cfg.shadow_fraction), (0.5, 0.25));
        assert_eq!(cfg.queue_capacity, 1024);
        assert_eq!(cfg.pressure_threshold, Duration::MAX);
    }

    #[test]
    fn start_rejects_invalid_config_before_spawning() {
        // Validation fires before any artifact IO: the path is bogus but
        // the error must be about the config.
        let err = GemmServer::start(
            Path::new("/nonexistent"),
            Box::new(super::super::DefaultPolicy::clblast()),
            ServerConfig::with_shards(0),
        )
        .unwrap_err();
        assert!(err.to_string().contains("shards"), "{err}");
    }

    #[test]
    fn fleet_rejects_empty_duplicate_and_zero_capacity_classes() {
        let cfg = ServerConfig::default();
        let err = GemmServer::start_fleet(Path::new("/nonexistent"), Vec::new(), cfg)
            .unwrap_err();
        assert!(err.to_string().contains("at least one"), "{err}");
        let classes = vec![
            DeviceClass::new(
                DeviceId::NvidiaP100,
                1,
                Box::new(super::super::DefaultPolicy::clblast()),
            ),
            DeviceClass::new(
                DeviceId::NvidiaP100,
                1,
                Box::new(super::super::DefaultPolicy::clblast()),
            ),
        ];
        let err = GemmServer::start_fleet(Path::new("/nonexistent"), classes, cfg)
            .unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
        // A per-class zero queue bound is rejected like the global one.
        let classes = vec![DeviceClass::new(
            DeviceId::NvidiaP100,
            1,
            Box::new(super::super::DefaultPolicy::clblast()),
        )
        .with_queue_capacity(0)];
        let err = GemmServer::start_fleet(Path::new("/nonexistent"), classes, cfg)
            .unwrap_err();
        assert!(err.to_string().contains("queue_capacity"), "{err}");
    }

    #[test]
    fn request_validation_catches_truncation_and_length_mismatch() {
        let ok = GemmRequest {
            m: 2,
            n: 3,
            k: 4,
            a: vec![0.0; 8],
            b: vec![0.0; 12],
            c: vec![0.0; 6],
            alpha: 1.0,
            beta: 0.0,
        };
        assert!(ok.validate().is_ok());
        // Oversized dimension: the old `m as u32` silently truncated
        // this to 0 and served a wrong triple.  n=k=0 keeps the operand
        // vectors empty so the case is constructible.
        let oversized = GemmRequest {
            m: u32::MAX as usize + 1,
            n: 0,
            k: 0,
            a: Vec::new(),
            b: Vec::new(),
            c: Vec::new(),
            alpha: 1.0,
            beta: 0.0,
        };
        let err = oversized.validate().unwrap_err();
        assert!(err.contains("exceeds the u32 triple limit"), "{err}");
        assert!(err.contains('m'), "{err}");
        // Mismatched operand lengths.
        let mismatched = GemmRequest { a: vec![0.0; 7], ..ok.clone() };
        let err = mismatched.validate().unwrap_err();
        assert!(err.contains("operand lengths"), "{err}");
        assert!(err.contains("a=7"), "{err}");
    }

    fn sim_engine() -> SimEngine {
        SimEngine::new(DeviceProfile::nvidia_p100(), crate::testing::sample_manifest())
    }

    #[test]
    fn pressure_pick_swaps_to_modeled_cheapest_within_bound() {
        let engine = sim_engine();
        let profile = DeviceProfile::nvidia_p100();
        let t = Triple::new(64, 64, 64); // all three artifacts accept it
        let m = engine.manifest();
        let secs = |id: ArtifactId| {
            sim::modeled_secs(&profile, &m.meta(id).config, t).unwrap()
        };
        let ids: Vec<ArtifactId> = (0..m.len() as u32)
            .map(ArtifactId)
            .filter(|id| engine.is_servable(*id) && m.meta(*id).accepts(t))
            .collect();
        assert_eq!(ids.len(), 3);
        let best = *ids
            .iter()
            .min_by(|a, b| secs(**a).total_cmp(&secs(**b)))
            .unwrap();
        let worst = *ids
            .iter()
            .max_by(|a, b| secs(**a).total_cmp(&secs(**b)))
            .unwrap();
        assert_ne!(best, worst);
        // A modeled-slow policy pick under pressure swaps to the cheapest.
        assert_eq!(
            pressure_resolve(&engine, &profile, Some(worst), t, 1.0),
            (Some(best), true)
        );
        // Within a generous slowdown bound the policy's pick stands.
        assert_eq!(
            pressure_resolve(&engine, &profile, Some(worst), t, 1e9),
            (Some(worst), false)
        );
        // The cheapest pick is never "overridden".
        assert_eq!(
            pressure_resolve(&engine, &profile, Some(best), t, 1.0),
            (Some(best), false)
        );
        // No policy resolution at all: pressure resolves to the cheapest.
        assert_eq!(
            pressure_resolve(&engine, &profile, None, t, 1.0),
            (Some(best), true)
        );
        // Nothing accepts the triple: the policy's (non-)resolution is
        // left alone.
        let huge = Triple::new(4000, 4000, 4000);
        assert_eq!(pressure_resolve(&engine, &profile, None, huge, 1.0), (None, false));
    }

    #[test]
    fn shadow_rotation_wraps_and_excludes_served() {
        let engine = sim_engine();
        let t = Triple::new(64, 64, 64); // all three artifacts accept it
        let served = engine.manifest().id_of("d1").unwrap();
        // Two alternatives (i1, i2): any rotation index wraps onto them
        // and never returns the served artifact.
        let mut seen = std::collections::HashSet::new();
        for rotation in 0..7 {
            let alt = select_shadow_alternative(&engine, served, t, rotation)
                .expect("two alternatives exist");
            assert_ne!(alt, served);
            seen.insert(alt);
            // Wrap: rotation and rotation + 2 pick the same alternative.
            assert_eq!(
                select_shadow_alternative(&engine, served, t, rotation + 2),
                Some(alt)
            );
        }
        assert_eq!(seen.len(), 2, "rotation must cover every alternative");
        // No alternative at all: the only artifact accepting 200^3 is i2.
        let served = engine.manifest().id_of("i2").unwrap();
        let none = select_shadow_alternative(&engine, served, Triple::new(200, 200, 200), 3);
        assert_eq!(none, None);
    }

    /// Engine double whose servability answer *shrinks* between the
    /// counting pass and the selection pass — the race the old
    /// `expect("count > rotation index")` would have turned into a shard
    /// panic.  The hardened selection must return None instead.
    struct ShrinkingEngine {
        inner: SimEngine,
        calls: std::cell::Cell<usize>,
    }

    impl ExecutionEngine for ShrinkingEngine {
        fn device(&self) -> DeviceId {
            self.inner.device()
        }

        fn manifest(&self) -> &Manifest {
            self.inner.manifest()
        }

        fn is_servable(&self, id: ArtifactId) -> bool {
            // First pass (counting) says yes to everything; later passes
            // deny every indirect artifact, shrinking the set under the
            // selector's feet.
            let call = self.calls.get();
            self.calls.set(call + 1);
            if call < self.manifest().len() {
                self.inner.is_servable(id)
            } else {
                id == self.manifest().id_of("d1").unwrap()
            }
        }

        fn ensure_ready(&mut self, _id: ArtifactId) -> Result<()> {
            Ok(())
        }

        fn execute_pooled(
            &mut self,
            _id: ArtifactId,
            _input: &GemmInput,
            _scratch: &mut ScratchBuffers,
        ) -> Result<GemmTimes> {
            unreachable!("selection-only test double")
        }
    }

    #[test]
    fn shadow_selection_survives_shrinking_eligible_set() {
        let engine = ShrinkingEngine {
            inner: sim_engine(),
            calls: std::cell::Cell::new(0),
        };
        let served = engine.manifest().id_of("d1").unwrap();
        // Counting pass sees 2 alternatives; the selection pass sees 0.
        // Regression: this used to be an expect() panic path.
        let got = select_shadow_alternative(&engine, served, Triple::new(64, 64, 64), 1);
        assert_eq!(got, None);
    }
}
