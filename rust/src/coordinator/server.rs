//! The adaptive GEMM server — the on-line coordinator, now a
//! *heterogeneous fleet*.
//!
//! Topology (see ARCHITECTURE.md): client threads submit [`GemmRequest`]s
//! through a [`ServerHandle`], whose device-aware router picks a device
//! class per request (policy-predicted service time on each class, scaled
//! by that class's queue depth) and then round-robins across the class's
//! dispatcher *shards*.  Each shard is one worker thread pinned to a
//! device class: it exclusively owns an [`ExecutionEngine`] built from
//! the class's [`EngineSpec`] (the real PJRT runtime for the host CPU,
//! analytical engines for the simulated devices — engines are created on
//! the shard's thread, PJRT handles never cross threads) plus a
//! [`ScratchBuffers`] pool, shares its *class's* [`PolicyHandle`] and
//! [`TelemetryRing`] (never another class's — per-device telemetry must
//! not cross-contaminate), and runs the per-artifact dynamic batcher.
//! Requests execute on the pooled, allocation-free engine path; responses
//! flow back over per-request channels carrying the serving device, the
//! routed device and the policy epoch.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::config::Triple;
use crate::device::{sim, DeviceId, DeviceProfile};
use crate::engine::{EngineSpec, ExecutionEngine};
use crate::runtime::{ArtifactId, GemmInput, ScratchBuffers};

use super::adapt::{TelemetryRecord, TelemetryRing};
use super::metrics::{RequestRecord, ServeStats};
use super::policy::{CachedPolicy, PolicyHandle, SelectPolicy};

/// An owned GEMM request.
#[derive(Debug, Clone)]
pub struct GemmRequest {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub c: Vec<f32>,
    pub alpha: f32,
    pub beta: f32,
}

impl GemmRequest {
    pub fn triple(&self) -> Triple {
        Triple::new(self.m as u32, self.n as u32, self.k as u32)
    }
}

/// A served response.
#[derive(Debug)]
pub struct GemmResponse {
    pub out: Result<Vec<f32>>,
    pub artifact: String,
    pub queue: Duration,
    pub service: Duration,
    /// Policy epoch the request was resolved under (bumped by every
    /// adaptation hot-swap of *this device's* policy; 0 until the first
    /// swap).  Epochs are per device class — a swap on one device never
    /// moves another's.
    pub epoch: u64,
    /// Device class of the shard that served the request (stamped by the
    /// worker from its pinned class).
    pub device: DeviceId,
    /// Device class the router chose at submit time (stamped by the
    /// handle).  Always equals `device` — the two independent stamps
    /// exist so routing bugs are detectable, and the router property
    /// test pins them equal under racing submitters.
    pub routed: DeviceId,
    /// Serving shard (fleet-global index).
    pub shard: usize,
}

/// Server tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Max requests coalesced into one dispatch window.
    pub max_batch: usize,
    /// How long a shard waits to fill a window.
    pub batch_window: Duration,
    /// Dispatcher shards for the homogeneous [`GemmServer::start`] path
    /// (heterogeneous fleets size each class via [`DeviceClass::shards`]).
    pub shards: usize,
    /// Fraction of successfully served requests sampled into the
    /// telemetry ring (0.0 disables the tap entirely).
    pub telemetry_fraction: f64,
    /// Shadow-execution budget: fraction of *sampled* requests that also
    /// execute one alternative eligible artifact (off the response path,
    /// after the reply is sent) so the trainer can compare configs on
    /// live traffic.
    pub shadow_fraction: f64,
    /// Telemetry ring capacity (oldest records drop under pressure).
    pub telemetry_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 32,
            batch_window: Duration::from_micros(200),
            shards: 1,
            telemetry_fraction: 0.0,
            shadow_fraction: 0.0,
            telemetry_capacity: 4096,
        }
    }
}

impl ServerConfig {
    /// Default configuration at a given shard count.
    pub fn with_shards(shards: usize) -> ServerConfig {
        ServerConfig { shards, ..ServerConfig::default() }
    }

    /// Sharded configuration with the telemetry tap and shadow budget
    /// enabled — what the adaptation loop serves under.
    pub fn adaptive(shards: usize, telemetry_fraction: f64, shadow_fraction: f64) -> ServerConfig {
        ServerConfig {
            shards,
            telemetry_fraction,
            shadow_fraction,
            ..ServerConfig::default()
        }
    }

    /// Validate at server start: zero shards or a zero-sized batch window
    /// are configuration bugs, rejected loudly instead of silently
    /// "fixed"; the sampling fractions are *rates* and are clamped into
    /// [0, 1] (out-of-range values have an obvious intent).
    pub fn validated(self) -> Result<ServerConfig> {
        ensure!(self.shards > 0, "ServerConfig.shards must be > 0");
        ensure!(self.max_batch > 0, "ServerConfig.max_batch must be > 0");
        Ok(ServerConfig {
            telemetry_fraction: self.telemetry_fraction.clamp(0.0, 1.0),
            shadow_fraction: self.shadow_fraction.clamp(0.0, 1.0),
            ..self
        })
    }
}

/// One device class of a heterogeneous fleet: a device, its shard count,
/// and the class's *own* selection policy (installed into a per-class
/// [`PolicyHandle`], so per-device adaptation retrains and hot-swaps each
/// class independently).
pub struct DeviceClass {
    pub device: DeviceId,
    pub shards: usize,
    pub policy: Box<dyn SelectPolicy>,
}

impl DeviceClass {
    pub fn new(device: DeviceId, shards: usize, policy: Box<dyn SelectPolicy>) -> DeviceClass {
        DeviceClass { device, shards, policy }
    }
}

/// When the class policy picks a config the device model cannot run at
/// all, the router charges this pessimistic service time — the class is
/// effectively avoided unless every other queue is badly backed up.
const ROUTE_FALLBACK_SECS: f64 = 1.0;

/// Router-side state of one device class.
struct ClassState {
    device: DeviceId,
    profile: DeviceProfile,
    /// The class's policy slot (shared with its shards and its
    /// adaptation loop): the router predicts with the *live* policy.
    policy: Arc<PolicyHandle>,
    /// Router-local cache of the class policy, brought up to date with
    /// one atomic epoch check per use ([`PolicyHandle::refresh`]) — so
    /// routing shares no lock with the adaptation hot-swap path except
    /// in the instant after a swap, and never clones the policy `Arc`
    /// per submit the way `snapshot()` would.
    cached: Mutex<CachedPolicy>,
    txs: Vec<mpsc::Sender<Envelope>>,
    /// Per-shard depth gauges: outstanding (submitted, not yet replied)
    /// requests.  Incremented by the handle at submit, decremented by the
    /// shard after the reply is sent.
    depths: Vec<Arc<AtomicUsize>>,
    /// Round-robin cursor within the class.
    next: AtomicUsize,
}

impl ClassState {
    fn depth(&self) -> usize {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed)).sum()
    }

    /// Predicted completion time of serving `t` on this class now: the
    /// analytical model's service time for the class policy's selection,
    /// scaled by how many requests are already queued per shard.  The
    /// depth term is both the load balancer and the tie-break — two
    /// classes with similar predicted service times split traffic by
    /// queue pressure.
    fn predicted_wait(&self, t: Triple) -> f64 {
        let cfg = {
            let mut cached = self.cached.lock().unwrap_or_else(|e| e.into_inner());
            self.policy.refresh(&mut cached);
            cached.select(t)
        };
        let secs =
            sim::modeled_secs(&self.profile, &cfg, t).unwrap_or(ROUTE_FALLBACK_SECS);
        secs * (1.0 + self.depth() as f64 / self.txs.len() as f64)
    }
}

struct Envelope {
    req: GemmRequest,
    submitted: Instant,
    reply: mpsc::Sender<GemmResponse>,
    /// Device class the router chose (echoed into the response).
    routed: DeviceId,
}

/// Handle for submitting work.  Clones share the per-class round-robin
/// cursors and depth gauges, so traffic from any number of client threads
/// spreads across the fleet consistently.
#[derive(Clone)]
pub struct ServerHandle {
    classes: Arc<Vec<ClassState>>,
}

impl ServerHandle {
    /// Pick the device class for a request.  Single-class fleets skip
    /// prediction entirely — the homogeneous hot path is unchanged.
    fn route(&self, t: Triple) -> usize {
        if self.classes.len() == 1 {
            return 0;
        }
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for (i, class) in self.classes.iter().enumerate() {
            let score = class.predicted_wait(t);
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }

    /// The device the router would choose for `t` right now (advisory:
    /// depth gauges move under live traffic).
    pub fn route_preview(&self, t: Triple) -> DeviceId {
        self.classes[self.route(t)].device
    }

    fn send_to(&self, class: &ClassState, req: GemmRequest) -> mpsc::Receiver<GemmResponse> {
        let (reply, rx) = mpsc::channel();
        let shard = class.next.fetch_add(1, Ordering::Relaxed) % class.txs.len();
        class.depths[shard].fetch_add(1, Ordering::Relaxed);
        let sent = class.txs[shard].send(Envelope {
            req,
            submitted: Instant::now(),
            reply,
            routed: class.device,
        });
        if sent.is_err() {
            // Shard gone (shutdown): roll the gauge back so the router
            // does not see a phantom queue.
            class.depths[shard].fetch_sub(1, Ordering::Relaxed);
        }
        rx
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, req: GemmRequest) -> mpsc::Receiver<GemmResponse> {
        self.send_to(&self.classes[self.route(req.triple())], req)
    }

    /// Submit a request *pinned* to a device class, bypassing the router
    /// (still round-robined within the class, depth gauges maintained).
    /// Coverage/diagnostic traffic: the hetero experiment scores every
    /// device's policy on identical pinned sweeps, so a device the
    /// router would rarely pick still gets measured (and its adaptation
    /// loop still gets telemetry).  `None` if the fleet has no such
    /// class.
    pub fn submit_to(
        &self,
        device: DeviceId,
        req: GemmRequest,
    ) -> Option<mpsc::Receiver<GemmResponse>> {
        let class = self.classes.iter().find(|c| c.device == device)?;
        Some(self.send_to(class, req))
    }

    /// Submit and wait.
    pub fn call(&self, req: GemmRequest) -> Result<GemmResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server shut down before responding"))
    }

    /// Total dispatcher shards across every device class.
    pub fn shards(&self) -> usize {
        self.classes.iter().map(|c| c.txs.len()).sum()
    }

    /// Device classes behind this handle, in class order.
    pub fn devices(&self) -> Vec<DeviceId> {
        self.classes.iter().map(|c| c.device).collect()
    }
}

/// Per-class coordination state the server keeps after startup.
struct ClassInfo {
    device: DeviceId,
    policy: Arc<PolicyHandle>,
    telemetry: Arc<TelemetryRing>,
}

/// The running server.
pub struct GemmServer {
    handle: Option<ServerHandle>,
    workers: Vec<JoinHandle<Vec<RequestRecord>>>,
    started: Instant,
    classes: Vec<ClassInfo>,
}

impl GemmServer {
    /// Start a homogeneous (host-CPU-only) server with `cfg.shards`
    /// dispatcher shards — the classic single-device path, now one
    /// degenerate fleet.  The policy is installed into a fresh
    /// epoch-counted [`PolicyHandle`] ([`policy_handle`]
    /// (Self::policy_handle)); the adaptation loop hot-swaps retrained
    /// policies through it while the server runs.
    pub fn start(
        artifacts: &Path,
        policy: Box<dyn SelectPolicy>,
        cfg: ServerConfig,
    ) -> Result<GemmServer> {
        let cfg = cfg.validated()?;
        let classes = vec![DeviceClass::new(DeviceId::HostCpu, cfg.shards, policy)];
        Self::start_fleet(artifacts, classes, cfg)
    }

    /// Start a heterogeneous fleet: one engine-backed shard group per
    /// device class, each with its own policy slot and telemetry ring.
    /// Engines are created on their shards' threads; startup errors are
    /// reported synchronously through a ready-channel once every shard
    /// has checked in (all-or-nothing).
    pub fn start_fleet(
        artifacts: &Path,
        classes: Vec<DeviceClass>,
        cfg: ServerConfig,
    ) -> Result<GemmServer> {
        let cfg = cfg.validated()?;
        ensure!(!classes.is_empty(), "fleet needs at least one device class");
        for (i, c) in classes.iter().enumerate() {
            ensure!(c.shards > 0, "device class {} needs shards > 0", c.device);
            ensure!(
                classes[..i].iter().all(|p| p.device != c.device),
                "device class {} listed twice",
                c.device
            );
        }
        let n_workers: usize = classes.iter().map(|c| c.shards).sum();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut states = Vec::with_capacity(classes.len());
        let mut infos = Vec::with_capacity(classes.len());
        let mut workers = Vec::with_capacity(n_workers);
        let mut shard = 0usize; // fleet-global shard index
        for class in classes {
            let spec = EngineSpec::for_device(class.device);
            let policy = Arc::new(PolicyHandle::new(Arc::from(class.policy)));
            let telemetry = Arc::new(TelemetryRing::new(cfg.telemetry_capacity));
            let mut txs = Vec::with_capacity(class.shards);
            let mut depths = Vec::with_capacity(class.shards);
            for _ in 0..class.shards {
                let (tx, rx) = mpsc::channel::<Envelope>();
                let depth = Arc::new(AtomicUsize::new(0));
                txs.push(tx);
                depths.push(Arc::clone(&depth));
                let ctx = ShardCtx {
                    shard,
                    spec,
                    dir: artifacts.to_path_buf(),
                    policy: Arc::clone(&policy),
                    telemetry: Arc::clone(&telemetry),
                    depth,
                    cfg,
                };
                let ready_tx = ready_tx.clone();
                workers.push(std::thread::spawn(move || worker_loop(ctx, rx, ready_tx)));
                shard += 1;
            }
            states.push(ClassState {
                device: class.device,
                profile: DeviceProfile::get(class.device),
                policy: Arc::clone(&policy),
                cached: Mutex::new(policy.snapshot()),
                txs,
                depths,
                next: AtomicUsize::new(0),
            });
            infos.push(ClassInfo { device: class.device, policy, telemetry });
        }
        drop(ready_tx);
        let handle = ServerHandle { classes: Arc::new(states) };
        let mut failures = Vec::new();
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => failures.push(msg),
                Err(_) => failures.push("server thread died during startup".to_string()),
            }
        }
        if !failures.is_empty() {
            // Drop the senders so healthy shards exit, then reap.
            drop(handle);
            for w in workers {
                let _ = w.join();
            }
            return Err(anyhow!("server startup failed: {}", failures.join("; ")));
        }
        Ok(GemmServer {
            handle: Some(handle),
            workers,
            started: Instant::now(),
            classes: infos,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.as_ref().expect("server running").clone()
    }

    /// Device classes of this fleet, in class order.
    pub fn devices(&self) -> Vec<DeviceId> {
        self.classes.iter().map(|c| c.device).collect()
    }

    /// The epoch-counted policy slot of the *first* device class — the
    /// whole fleet for homogeneous servers.  Swap a retrained policy in
    /// via [`PolicyHandle::swap`]; the class's shards pick it up at their
    /// next window boundary.
    pub fn policy_handle(&self) -> Arc<PolicyHandle> {
        Arc::clone(&self.classes[0].policy)
    }

    /// The telemetry ring of the first device class (empty unless
    /// `cfg.telemetry_fraction > 0`).
    pub fn telemetry(&self) -> Arc<TelemetryRing> {
        Arc::clone(&self.classes[0].telemetry)
    }

    /// A specific device class's policy slot.
    pub fn policy_handle_for(&self, device: DeviceId) -> Option<Arc<PolicyHandle>> {
        self.classes
            .iter()
            .find(|c| c.device == device)
            .map(|c| Arc::clone(&c.policy))
    }

    /// A specific device class's telemetry ring.  Shards only ever push
    /// to their own class's ring, so per-device training data never
    /// cross-contaminates.
    pub fn telemetry_for(&self, device: DeviceId) -> Option<Arc<TelemetryRing>> {
        self.classes
            .iter()
            .find(|c| c.device == device)
            .map(|c| Arc::clone(&c.telemetry))
    }

    /// Shut down and collect serving statistics (None if nothing served).
    pub fn shutdown(mut self) -> Option<ServeStats> {
        let wall = self.started.elapsed();
        // Drop our sender references so each shard's recv() errors out
        // once all client handles are gone.
        self.handle = None;
        let mut records = Vec::new();
        for w in self.workers.drain(..) {
            if let Ok(mut r) = w.join() {
                records.append(&mut r);
            }
        }
        if records.is_empty() {
            None
        } else {
            Some(ServeStats::from_records(&records, wall))
        }
    }
}

/// Everything a dispatcher shard needs, bundled for the thread spawn.
struct ShardCtx {
    shard: usize,
    spec: EngineSpec,
    dir: PathBuf,
    policy: Arc<PolicyHandle>,
    telemetry: Arc<TelemetryRing>,
    depth: Arc<AtomicUsize>,
    cfg: ServerConfig,
}

/// Deterministic fraction sampler: accumulate the fraction per event and
/// fire on whole-number crossings (no RNG, no state beyond one f64).
struct FractionSampler {
    fraction: f64,
    acc: f64,
}

impl FractionSampler {
    fn new(fraction: f64) -> FractionSampler {
        FractionSampler { fraction: fraction.clamp(0.0, 1.0), acc: 0.0 }
    }

    fn fire(&mut self) -> bool {
        if self.fraction <= 0.0 {
            return false;
        }
        self.acc += self.fraction;
        if self.acc >= 1.0 {
            self.acc -= 1.0;
            true
        } else {
            false
        }
    }
}

/// One dispatcher shard: batches, selects, executes on its device
/// engine's pooled path, and feeds its class's telemetry tap.
fn worker_loop(
    ctx: ShardCtx,
    rx: mpsc::Receiver<Envelope>,
    ready_tx: mpsc::Sender<Result<(), String>>,
) -> Vec<RequestRecord> {
    let ShardCtx { shard, spec, dir, policy, telemetry, depth, cfg } = ctx;
    let device = spec.device();
    let mut engine: Box<dyn ExecutionEngine> = match spec.build(&dir) {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{device}: {e:#}")));
            return Vec::new();
        }
    };
    drop(ready_tx);
    let mut scratch = ScratchBuffers::new();
    // Shard-local policy snapshot, refreshed once per window: every
    // request inside a window is resolved under exactly one policy
    // epoch, so a concurrent hot-swap can never mix configurations
    // within a request (or a window).
    let mut cached: CachedPolicy = policy.snapshot();
    let mut tele_sampler = FractionSampler::new(cfg.telemetry_fraction);
    let mut shadow_sampler = FractionSampler::new(cfg.shadow_fraction);
    // Rotates through the alternative artifacts so repeated shadow runs
    // on one triple eventually cover every candidate.
    let mut shadow_rotation = shard; // offset per shard for coverage
    // Records keep the dense id while serving; names are resolved once at
    // shard exit so the hot path does not allocate per-request Strings
    // beyond the response boundary.
    let mut raw_records: Vec<(ArtifactId, Duration, Duration, f64)> = Vec::new();
    let mut window: Vec<Envelope> = Vec::with_capacity(cfg.max_batch);
    loop {
        // Block for the first request of a window.
        match rx.recv() {
            Err(_) => break, // all senders dropped: shutdown
            Ok(env) => window.push(env),
        }
        // Fill the window for up to `batch_window`.
        let deadline = Instant::now() + cfg.batch_window;
        while window.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(env) => window.push(env),
                Err(_) => break,
            }
        }
        // Window boundary: pick up a hot-swapped policy if one was
        // published.  One atomic load when nothing changed.
        policy.refresh(&mut cached);
        // Resolve each request to a dense artifact id, then group the
        // window by id (stable sort keeps FIFO order within a group) —
        // the dynamic batcher, with no string keys on the hot path.
        let mut resolved: Vec<(Option<ArtifactId>, Envelope)> = window
            .drain(..)
            .map(|env| {
                let t = env.req.triple();
                let cfg_sel = cached.select(t);
                let id = engine.resolve(&cfg_sel, t);
                (id, env)
            })
            .collect();
        resolved.sort_by_key(|(id, _)| *id);

        for (id, env) in resolved {
            let queue = env.submitted.elapsed();
            let t0 = Instant::now();
            let mut times = None;
            let result = match id {
                None => Err(anyhow!(
                    "no artifact accepts {} on {device}",
                    env.req.triple()
                )),
                Some(id) => {
                    let input = gemm_input(&env.req);
                    engine
                        .execute_pooled(id, &input, &mut scratch)
                        // The response must outlive the scratch pool: the
                        // copy-out is the one boundary allocation.
                        .map(|t| {
                            times = Some(t);
                            scratch.out.clone()
                        })
                }
            };
            let service = t0.elapsed();
            let artifact = match id {
                Some(id) => engine.manifest().name_of(id).to_string(),
                None => String::new(),
            };
            let served_ok = result.is_ok();
            if let (true, Some(id)) = (served_ok, id) {
                raw_records.push((id, queue, service, env.req.triple().flops()));
            }
            let _ = env.reply.send(GemmResponse {
                out: result,
                artifact,
                queue,
                service,
                epoch: cached.epoch,
                device,
                routed: env.routed,
                shard,
            });
            // The request is answered: release its depth-gauge slot so
            // the router sees this shard's real backlog.
            depth.fetch_sub(1, Ordering::Relaxed);
            // Telemetry tap — after the reply, entirely off the response
            // path.  `times` excludes compile, so the sample is
            // comparable to the shadow measurement below.
            if let (true, Some(id), Some(times)) = (served_ok, id, times) {
                if tele_sampler.fire() {
                    let shadow = if shadow_sampler.fire() {
                        shadow_execute(
                            &mut *engine,
                            &mut scratch,
                            id,
                            &env.req,
                            &mut shadow_rotation,
                        )
                    } else {
                        None
                    };
                    telemetry.push(TelemetryRecord {
                        triple: env.req.triple(),
                        served: engine.manifest().meta(id).config,
                        service_secs: times.total_time().as_secs_f64(),
                        shadow,
                        epoch: cached.epoch,
                        device,
                        shard,
                    });
                }
            }
        }
    }
    raw_records
        .into_iter()
        .map(|(id, queue, service, flops)| RequestRecord {
            artifact: engine.manifest().name_of(id).to_string(),
            device,
            shard,
            queue,
            service,
            flops,
        })
        .collect()
}

fn gemm_input(req: &GemmRequest) -> GemmInput<'_> {
    GemmInput {
        m: req.m,
        n: req.n,
        k: req.k,
        a: &req.a,
        b: &req.b,
        c: &req.c,
        alpha: req.alpha,
        beta: req.beta,
    }
}

/// Pick the `rotation`-th alternative (wrapping) among the artifacts that
/// are shape-eligible, device-servable, and not the one that already
/// served the request.  Gracefully returns `None` — never panics — even
/// if the eligible set shrinks between the counting pass and the
/// selection pass (e.g. an engine whose servability answer changes),
/// where the old `expect("count > rotation index")` would have killed the
/// shard thread.
fn select_shadow_alternative(
    engine: &dyn ExecutionEngine,
    served: ArtifactId,
    t: Triple,
    rotation: usize,
) -> Option<ArtifactId> {
    let n = engine.manifest().len() as u32;
    let eligible = |id: &ArtifactId| {
        *id != served
            && engine.is_servable(*id)
            && engine.manifest().meta(*id).accepts(t)
    };
    let count = (0..n).map(ArtifactId).filter(&eligible).count();
    if count == 0 {
        return None;
    }
    (0..n).map(ArtifactId).filter(&eligible).nth(rotation % count)
}

/// Spend shadow budget on one request: re-execute it on an *alternative*
/// eligible artifact (rotating through the candidates) and measure it
/// under identical operands.  Runs after the reply is sent, so the cost
/// is shard throughput — the request that was shadowed never waits, but
/// later requests queued on this shard do; that is exactly the budget
/// `shadow_fraction` caps.  The candidate scan is allocation-free (two
/// passes over the small immutable manifest) and the scratch pool is
/// reused — the response already copied its result out.
fn shadow_execute(
    engine: &mut dyn ExecutionEngine,
    scratch: &mut ScratchBuffers,
    served: ArtifactId,
    req: &GemmRequest,
    rotation: &mut usize,
) -> Option<(crate::config::KernelConfig, f64)> {
    let alt = select_shadow_alternative(engine, served, req.triple(), *rotation)?;
    *rotation = rotation.wrapping_add(1);
    // Compile outside the measurement, like the served path.
    engine.ensure_ready(alt).ok()?;
    let times = engine.execute_pooled(alt, &gemm_input(req), scratch).ok()?;
    Some((
        engine.manifest().meta(alt).config,
        times.total_time().as_secs_f64(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimEngine;
    use crate::runtime::{GemmTimes, Manifest};

    #[test]
    fn server_config_validation_edges() {
        assert!(ServerConfig::with_shards(0).validated().is_err());
        let err = ServerConfig::with_shards(0).validated().unwrap_err();
        assert!(err.to_string().contains("shards"), "{err}");
        let bad_batch = ServerConfig { max_batch: 0, ..ServerConfig::default() };
        let err = bad_batch.validated().unwrap_err();
        assert!(err.to_string().contains("max_batch"), "{err}");
        // Out-of-range fractions clamp instead of erroring.
        let cfg = ServerConfig::adaptive(2, 1.5, -0.25).validated().unwrap();
        assert_eq!(cfg.telemetry_fraction, 1.0);
        assert_eq!(cfg.shadow_fraction, 0.0);
        // A sane config passes through unchanged.
        let cfg = ServerConfig::adaptive(4, 0.5, 0.25).validated().unwrap();
        assert_eq!((cfg.shards, cfg.max_batch), (4, 32));
        assert_eq!((cfg.telemetry_fraction, cfg.shadow_fraction), (0.5, 0.25));
    }

    #[test]
    fn start_rejects_invalid_config_before_spawning() {
        // Validation fires before any artifact IO: the path is bogus but
        // the error must be about the config.
        let err = GemmServer::start(
            Path::new("/nonexistent"),
            Box::new(super::super::DefaultPolicy::clblast()),
            ServerConfig::with_shards(0),
        )
        .unwrap_err();
        assert!(err.to_string().contains("shards"), "{err}");
    }

    #[test]
    fn fleet_rejects_empty_and_duplicate_classes() {
        let cfg = ServerConfig::default();
        let err = GemmServer::start_fleet(Path::new("/nonexistent"), Vec::new(), cfg)
            .unwrap_err();
        assert!(err.to_string().contains("at least one"), "{err}");
        let classes = vec![
            DeviceClass::new(
                DeviceId::NvidiaP100,
                1,
                Box::new(super::super::DefaultPolicy::clblast()),
            ),
            DeviceClass::new(
                DeviceId::NvidiaP100,
                1,
                Box::new(super::super::DefaultPolicy::clblast()),
            ),
        ];
        let err = GemmServer::start_fleet(Path::new("/nonexistent"), classes, cfg)
            .unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
    }

    fn sim_engine() -> SimEngine {
        SimEngine::new(DeviceProfile::nvidia_p100(), crate::testing::sample_manifest())
    }

    #[test]
    fn shadow_rotation_wraps_and_excludes_served() {
        let engine = sim_engine();
        let t = Triple::new(64, 64, 64); // all three artifacts accept it
        let served = engine.manifest().id_of("d1").unwrap();
        // Two alternatives (i1, i2): any rotation index wraps onto them
        // and never returns the served artifact.
        let mut seen = std::collections::HashSet::new();
        for rotation in 0..7 {
            let alt = select_shadow_alternative(&engine, served, t, rotation)
                .expect("two alternatives exist");
            assert_ne!(alt, served);
            seen.insert(alt);
            // Wrap: rotation and rotation + 2 pick the same alternative.
            assert_eq!(
                select_shadow_alternative(&engine, served, t, rotation + 2),
                Some(alt)
            );
        }
        assert_eq!(seen.len(), 2, "rotation must cover every alternative");
        // No alternative at all: the only artifact accepting 200^3 is i2.
        let served = engine.manifest().id_of("i2").unwrap();
        let none = select_shadow_alternative(&engine, served, Triple::new(200, 200, 200), 3);
        assert_eq!(none, None);
    }

    /// Engine double whose servability answer *shrinks* between the
    /// counting pass and the selection pass — the race the old
    /// `expect("count > rotation index")` would have turned into a shard
    /// panic.  The hardened selection must return None instead.
    struct ShrinkingEngine {
        inner: SimEngine,
        calls: std::cell::Cell<usize>,
    }

    impl ExecutionEngine for ShrinkingEngine {
        fn device(&self) -> DeviceId {
            self.inner.device()
        }

        fn manifest(&self) -> &Manifest {
            self.inner.manifest()
        }

        fn is_servable(&self, id: ArtifactId) -> bool {
            // First pass (counting) says yes to everything; later passes
            // deny every indirect artifact, shrinking the set under the
            // selector's feet.
            let call = self.calls.get();
            self.calls.set(call + 1);
            if call < self.manifest().len() {
                self.inner.is_servable(id)
            } else {
                id == self.manifest().id_of("d1").unwrap()
            }
        }

        fn ensure_ready(&mut self, _id: ArtifactId) -> Result<()> {
            Ok(())
        }

        fn execute_pooled(
            &mut self,
            _id: ArtifactId,
            _input: &GemmInput,
            _scratch: &mut ScratchBuffers,
        ) -> Result<GemmTimes> {
            unreachable!("selection-only test double")
        }
    }

    #[test]
    fn shadow_selection_survives_shrinking_eligible_set() {
        let engine = ShrinkingEngine {
            inner: sim_engine(),
            calls: std::cell::Cell::new(0),
        };
        let served = engine.manifest().id_of("d1").unwrap();
        // Counting pass sees 2 alternatives; the selection pass sees 0.
        // Regression: this used to be an expect() panic path.
        let got = select_shadow_alternative(&engine, served, Triple::new(64, 64, 64), 1);
        assert_eq!(got, None);
    }
}
