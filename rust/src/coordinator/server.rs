//! The adaptive GEMM server — the on-line coordinator.
//!
//! Topology (see ARCHITECTURE.md): client threads submit [`GemmRequest`]s
//! through a [`ServerHandle`], which routes them round-robin across N
//! dispatcher *shards*.  Each shard is one worker thread that exclusively
//! owns a `GemmRuntime` (its own PJRT client and compile cache — PJRT
//! handles never cross threads) plus a [`ScratchBuffers`] pool, shares the
//! read-only [`SelectPolicy`], and runs the per-artifact dynamic batcher:
//! the pending window is resolved to dense [`ArtifactId`]s and grouped by
//! id (consecutive executions of one executable amortize instruction/data
//! cache misses and avoid executable switching).  Requests execute on the
//! pooled, allocation-free runtime path; responses flow back over
//! per-request channels.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::Triple;
use crate::runtime::{ArtifactId, GemmInput, GemmRuntime, ScratchBuffers};

use super::adapt::{TelemetryRecord, TelemetryRing};
use super::metrics::{RequestRecord, ServeStats};
use super::policy::{CachedPolicy, PolicyHandle, SelectPolicy};

/// An owned GEMM request.
#[derive(Debug, Clone)]
pub struct GemmRequest {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub c: Vec<f32>,
    pub alpha: f32,
    pub beta: f32,
}

impl GemmRequest {
    pub fn triple(&self) -> Triple {
        Triple::new(self.m as u32, self.n as u32, self.k as u32)
    }
}

/// A served response.
#[derive(Debug)]
pub struct GemmResponse {
    pub out: Result<Vec<f32>>,
    pub artifact: String,
    pub queue: Duration,
    pub service: Duration,
    /// Policy epoch the request was resolved under (bumped by every
    /// adaptation hot-swap; 0 until the first swap).
    pub epoch: u64,
}

/// Server tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Max requests coalesced into one dispatch window.
    pub max_batch: usize,
    /// How long a shard waits to fill a window.
    pub batch_window: Duration,
    /// Dispatcher shards, each exclusively owning a runtime + compile
    /// cache.  Requests are routed round-robin across shards.
    pub shards: usize,
    /// Fraction of successfully served requests sampled into the
    /// telemetry ring (0.0 disables the tap entirely).
    pub telemetry_fraction: f64,
    /// Shadow-execution budget: fraction of *sampled* requests that also
    /// execute one alternative artifact (off the response path, after the
    /// reply is sent) so the trainer can compare configs on live traffic.
    pub shadow_fraction: f64,
    /// Telemetry ring capacity (oldest records drop under pressure).
    pub telemetry_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 32,
            batch_window: Duration::from_micros(200),
            shards: 1,
            telemetry_fraction: 0.0,
            shadow_fraction: 0.0,
            telemetry_capacity: 4096,
        }
    }
}

impl ServerConfig {
    /// Default configuration at a given shard count.
    pub fn with_shards(shards: usize) -> ServerConfig {
        ServerConfig { shards, ..ServerConfig::default() }
    }

    /// Sharded configuration with the telemetry tap and shadow budget
    /// enabled — what the adaptation loop serves under.
    pub fn adaptive(shards: usize, telemetry_fraction: f64, shadow_fraction: f64) -> ServerConfig {
        ServerConfig {
            shards,
            telemetry_fraction,
            shadow_fraction,
            ..ServerConfig::default()
        }
    }
}

struct Envelope {
    req: GemmRequest,
    submitted: Instant,
    reply: mpsc::Sender<GemmResponse>,
}

/// Handle for submitting work.  Clones share the round-robin cursor, so
/// traffic from any number of client threads spreads across all shards.
#[derive(Clone)]
pub struct ServerHandle {
    txs: Arc<Vec<mpsc::Sender<Envelope>>>,
    next: Arc<AtomicUsize>,
}

impl ServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, req: GemmRequest) -> mpsc::Receiver<GemmResponse> {
        let (reply, rx) = mpsc::channel();
        let shard = self.next.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        let _ = self.txs[shard].send(Envelope {
            req,
            submitted: Instant::now(),
            reply,
        });
        rx
    }

    /// Submit and wait.
    pub fn call(&self, req: GemmRequest) -> Result<GemmResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server shut down before responding"))
    }

    /// Number of dispatcher shards behind this handle.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }
}

/// The running server.
pub struct GemmServer {
    handle: Option<ServerHandle>,
    workers: Vec<JoinHandle<Vec<RequestRecord>>>,
    started: Instant,
    policy: Arc<PolicyHandle>,
    telemetry: Arc<TelemetryRing>,
}

impl GemmServer {
    /// Start the server with `cfg.shards` dispatcher shards.  Each PJRT
    /// runtime is *created on its shard's thread* (PJRT handles are not
    /// `Send`); startup errors are reported synchronously through a
    /// ready-channel once every shard has checked in.
    ///
    /// The policy is installed into a fresh epoch-counted [`PolicyHandle`]
    /// ([`policy_handle`](Self::policy_handle)); the adaptation loop
    /// hot-swaps retrained policies through it while the server runs.
    pub fn start(
        artifacts: &Path,
        policy: Box<dyn SelectPolicy>,
        cfg: ServerConfig,
    ) -> Result<GemmServer> {
        let policy = Arc::new(PolicyHandle::new(Arc::from(policy)));
        let telemetry = Arc::new(TelemetryRing::new(cfg.telemetry_capacity));
        let n_shards = cfg.shards.max(1);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut txs = Vec::with_capacity(n_shards);
        let mut workers = Vec::with_capacity(n_shards);
        for shard in 0..n_shards {
            let (tx, rx) = mpsc::channel::<Envelope>();
            txs.push(tx);
            let ctx = ShardCtx {
                shard,
                dir: artifacts.to_path_buf(),
                policy: Arc::clone(&policy),
                telemetry: Arc::clone(&telemetry),
                cfg,
            };
            let ready_tx = ready_tx.clone();
            workers.push(std::thread::spawn(move || worker_loop(ctx, rx, ready_tx)));
        }
        drop(ready_tx);
        let handle = ServerHandle {
            txs: Arc::new(txs),
            next: Arc::new(AtomicUsize::new(0)),
        };
        let mut failures = Vec::new();
        for _ in 0..n_shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => failures.push(msg),
                Err(_) => failures.push("server thread died during startup".to_string()),
            }
        }
        if !failures.is_empty() {
            // Drop the senders so healthy shards exit, then reap.
            drop(handle);
            for w in workers {
                let _ = w.join();
            }
            return Err(anyhow!("server startup failed: {}", failures.join("; ")));
        }
        Ok(GemmServer {
            handle: Some(handle),
            workers,
            started: Instant::now(),
            policy,
            telemetry,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.as_ref().expect("server running").clone()
    }

    /// The epoch-counted policy slot every shard selects through.  Swap
    /// a retrained policy in via [`PolicyHandle::swap`]; shards pick it
    /// up at their next window boundary.
    pub fn policy_handle(&self) -> Arc<PolicyHandle> {
        Arc::clone(&self.policy)
    }

    /// The telemetry ring shards sample served requests into (empty
    /// unless `cfg.telemetry_fraction > 0`).
    pub fn telemetry(&self) -> Arc<TelemetryRing> {
        Arc::clone(&self.telemetry)
    }

    /// Shut down and collect serving statistics (None if nothing served).
    pub fn shutdown(mut self) -> Option<ServeStats> {
        let wall = self.started.elapsed();
        // Drop our sender references so each shard's recv() errors out
        // once all client handles are gone.
        self.handle = None;
        let mut records = Vec::new();
        for w in self.workers.drain(..) {
            if let Ok(mut r) = w.join() {
                records.append(&mut r);
            }
        }
        if records.is_empty() {
            None
        } else {
            Some(ServeStats::from_records(&records, wall))
        }
    }
}

/// Everything a dispatcher shard needs, bundled for the thread spawn.
struct ShardCtx {
    shard: usize,
    dir: PathBuf,
    policy: Arc<PolicyHandle>,
    telemetry: Arc<TelemetryRing>,
    cfg: ServerConfig,
}

/// Deterministic fraction sampler: accumulate the fraction per event and
/// fire on whole-number crossings (no RNG, no state beyond one f64).
struct FractionSampler {
    fraction: f64,
    acc: f64,
}

impl FractionSampler {
    fn new(fraction: f64) -> FractionSampler {
        FractionSampler { fraction: fraction.clamp(0.0, 1.0), acc: 0.0 }
    }

    fn fire(&mut self) -> bool {
        if self.fraction <= 0.0 {
            return false;
        }
        self.acc += self.fraction;
        if self.acc >= 1.0 {
            self.acc -= 1.0;
            true
        } else {
            false
        }
    }
}

/// One dispatcher shard: batches, selects, executes on the pooled path,
/// and feeds the telemetry tap.
fn worker_loop(
    ctx: ShardCtx,
    rx: mpsc::Receiver<Envelope>,
    ready_tx: mpsc::Sender<Result<(), String>>,
) -> Vec<RequestRecord> {
    let ShardCtx { shard, dir, policy, telemetry, cfg } = ctx;
    let mut runtime = match GemmRuntime::open(&dir) {
        Ok(r) => {
            let _ = ready_tx.send(Ok(()));
            r
        }
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return Vec::new();
        }
    };
    drop(ready_tx);
    let mut scratch = ScratchBuffers::new();
    // Shard-local policy snapshot, refreshed once per window: every
    // request inside a window is resolved under exactly one policy
    // epoch, so a concurrent hot-swap can never mix configurations
    // within a request (or a window).
    let mut cached: CachedPolicy = policy.snapshot();
    let mut tele_sampler = FractionSampler::new(cfg.telemetry_fraction);
    let mut shadow_sampler = FractionSampler::new(cfg.shadow_fraction);
    // Rotates through the alternative artifacts so repeated shadow runs
    // on one triple eventually cover every candidate.
    let mut shadow_rotation = shard; // offset per shard for coverage
    // Records keep the dense id while serving; names are resolved once at
    // shard exit so the hot path does not allocate per-request Strings
    // beyond the response boundary.
    let mut raw_records: Vec<(ArtifactId, Duration, Duration, f64)> = Vec::new();
    let mut window: Vec<Envelope> = Vec::with_capacity(cfg.max_batch);
    loop {
        // Block for the first request of a window.
        match rx.recv() {
            Err(_) => break, // all senders dropped: shutdown
            Ok(env) => window.push(env),
        }
        // Fill the window for up to `batch_window`.
        let deadline = Instant::now() + cfg.batch_window;
        while window.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(env) => window.push(env),
                Err(_) => break,
            }
        }
        // Window boundary: pick up a hot-swapped policy if one was
        // published.  One atomic load when nothing changed.
        policy.refresh(&mut cached);
        // Resolve each request to a dense artifact id, then group the
        // window by id (stable sort keeps FIFO order within a group) —
        // the dynamic batcher, with no string keys on the hot path.
        let mut resolved: Vec<(Option<ArtifactId>, Envelope)> = window
            .drain(..)
            .map(|env| {
                let t = env.req.triple();
                let cfg_sel = cached.select(t);
                let id = runtime
                    .manifest
                    .artifact_id_for_config(&cfg_sel, t)
                    // Fallback: any artifact accepting t (least waste).
                    .or_else(|| runtime.manifest.eligible_id(t));
                (id, env)
            })
            .collect();
        resolved.sort_by_key(|(id, _)| *id);

        for (id, env) in resolved {
            let queue = env.submitted.elapsed();
            let t0 = Instant::now();
            let mut times = None;
            let result = match id {
                None => Err(anyhow!("no artifact accepts {}", env.req.triple())),
                Some(id) => {
                    let input = gemm_input(&env.req);
                    runtime
                        .gemm_pooled(id, &input, &mut scratch)
                        // The response must outlive the scratch pool: the
                        // copy-out is the one boundary allocation.
                        .map(|t| {
                            times = Some(t);
                            scratch.out.clone()
                        })
                }
            };
            let service = t0.elapsed();
            let artifact = match id {
                Some(id) => runtime.manifest.name_of(id).to_string(),
                None => String::new(),
            };
            let served_ok = result.is_ok();
            if let (true, Some(id)) = (served_ok, id) {
                raw_records.push((id, queue, service, env.req.triple().flops()));
            }
            let _ = env.reply.send(GemmResponse {
                out: result,
                artifact,
                queue,
                service,
                epoch: cached.epoch,
            });
            // Telemetry tap — after the reply, entirely off the response
            // path.  `times` excludes compile, so the sample is
            // comparable to the shadow measurement below.
            if let (true, Some(id), Some(times)) = (served_ok, id, times) {
                if tele_sampler.fire() {
                    let shadow = if shadow_sampler.fire() {
                        shadow_execute(
                            &mut runtime,
                            &mut scratch,
                            id,
                            &env.req,
                            &mut shadow_rotation,
                        )
                    } else {
                        None
                    };
                    telemetry.push(TelemetryRecord {
                        triple: env.req.triple(),
                        served: runtime.manifest.meta(id).config,
                        service_secs: times.total_time().as_secs_f64(),
                        shadow,
                        epoch: cached.epoch,
                        shard,
                    });
                }
            }
        }
    }
    raw_records
        .into_iter()
        .map(|(id, queue, service, flops)| RequestRecord {
            artifact: runtime.manifest.name_of(id).to_string(),
            shard,
            queue,
            service,
            flops,
        })
        .collect()
}

fn gemm_input(req: &GemmRequest) -> GemmInput<'_> {
    GemmInput {
        m: req.m,
        n: req.n,
        k: req.k,
        a: &req.a,
        b: &req.b,
        c: &req.c,
        alpha: req.alpha,
        beta: req.beta,
    }
}

/// Spend shadow budget on one request: re-execute it on an *alternative*
/// eligible artifact (rotating through the candidates) and measure it
/// under identical operands.  Runs after the reply is sent, so the cost
/// is shard throughput — the request that was shadowed never waits, but
/// later requests queued on this shard do; that is exactly the budget
/// `shadow_fraction` caps.  The candidate scan is allocation-free (two
/// passes over the small immutable manifest) and the scratch pool is
/// reused — the response already copied its result out.
fn shadow_execute(
    runtime: &mut GemmRuntime,
    scratch: &mut ScratchBuffers,
    served: ArtifactId,
    req: &GemmRequest,
    rotation: &mut usize,
) -> Option<(crate::config::KernelConfig, f64)> {
    let t = req.triple();
    let n = runtime.manifest.len() as u32;
    let eligible = |id: &ArtifactId| *id != served && runtime.manifest.meta(*id).accepts(t);
    let count = (0..n).map(ArtifactId).filter(eligible).count();
    if count == 0 {
        return None;
    }
    let alt = (0..n)
        .map(ArtifactId)
        .filter(eligible)
        .nth(*rotation % count)
        .expect("count > rotation index");
    *rotation = rotation.wrapping_add(1);
    // Compile outside the measurement, like the served path.
    runtime.ensure_compiled_id(alt).ok()?;
    let times = runtime.gemm_pooled(alt, &gemm_input(req), scratch).ok()?;
    Some((
        runtime.manifest.meta(alt).config,
        times.total_time().as_secs_f64(),
    ))
}
