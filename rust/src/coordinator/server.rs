//! The adaptive GEMM server — the on-line coordinator.
//!
//! Topology: client threads submit [`GemmRequest`]s over a channel; the
//! dispatcher thread selects a kernel configuration per request (via the
//! active [`SelectPolicy`]), resolves it to an AOT artifact, groups the
//! pending window by artifact (the dynamic batcher — consecutive
//! executions of one executable amortize instruction/data cache misses
//! and avoid executable switching), and runs them on the PJRT executor it
//! exclusively owns.  Responses flow back over per-request channels.

use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::Triple;
use crate::runtime::{GemmInput, GemmRuntime};

use super::metrics::{RequestRecord, ServeStats};
use super::policy::SelectPolicy;

/// An owned GEMM request.
#[derive(Debug, Clone)]
pub struct GemmRequest {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub c: Vec<f32>,
    pub alpha: f32,
    pub beta: f32,
}

impl GemmRequest {
    pub fn triple(&self) -> Triple {
        Triple::new(self.m as u32, self.n as u32, self.k as u32)
    }
}

/// A served response.
#[derive(Debug)]
pub struct GemmResponse {
    pub out: Result<Vec<f32>>,
    pub artifact: String,
    pub queue: Duration,
    pub service: Duration,
}

/// Server tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Max requests coalesced into one dispatch window.
    pub max_batch: usize,
    /// How long the dispatcher waits to fill a window.
    pub batch_window: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 32,
            batch_window: Duration::from_micros(200),
        }
    }
}

struct Envelope {
    req: GemmRequest,
    submitted: Instant,
    reply: mpsc::Sender<GemmResponse>,
}

/// Handle for submitting work.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Envelope>,
}

impl ServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, req: GemmRequest) -> mpsc::Receiver<GemmResponse> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Envelope { req, submitted: Instant::now(), reply });
        rx
    }

    /// Submit and wait.
    pub fn call(&self, req: GemmRequest) -> Result<GemmResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server shut down before responding"))
    }
}

/// The running server.
pub struct GemmServer {
    handle: Option<ServerHandle>,
    worker: Option<JoinHandle<Vec<RequestRecord>>>,
    started: Instant,
}

impl GemmServer {
    /// Start the server.  The PJRT runtime is *created on the dispatcher
    /// thread* (PJRT handles are not `Send`); startup errors are reported
    /// synchronously through a ready-channel.
    pub fn start(
        artifacts: &Path,
        policy: Box<dyn SelectPolicy>,
        cfg: ServerConfig,
    ) -> Result<GemmServer> {
        let dir = artifacts.to_path_buf();
        let (tx, rx) = mpsc::channel::<Envelope>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let worker = std::thread::spawn(move || {
            let mut runtime = match GemmRuntime::open(&dir) {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return Vec::new();
                }
            };
            let mut records = Vec::new();
            let mut window: Vec<Envelope> = Vec::with_capacity(cfg.max_batch);
            loop {
                // Block for the first request of a window.
                match rx.recv() {
                    Err(_) => break, // all senders dropped: shutdown
                    Ok(env) => window.push(env),
                }
                // Fill the window for up to `batch_window`.
                let deadline = Instant::now() + cfg.batch_window;
                while window.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(env) => window.push(env),
                        Err(_) => break,
                    }
                }
                // Resolve artifacts, then group the window by artifact
                // (stable sort keeps FIFO order within a group).
                let mut resolved: Vec<(String, Envelope)> = window
                    .drain(..)
                    .map(|env| {
                        let t = env.req.triple();
                        let cfg_sel = policy.select(t);
                        let artifact = runtime
                            .manifest
                            .artifact_for_config(&cfg_sel, t)
                            // Fallback: any artifact accepting t (least waste).
                            .or_else(|| runtime.manifest.eligible(t).first().copied())
                            .map(|a| a.name.clone())
                            .unwrap_or_default();
                        (artifact, env)
                    })
                    .collect();
                resolved.sort_by(|a, b| a.0.cmp(&b.0));

                for (artifact, env) in resolved {
                    let queue = env.submitted.elapsed();
                    let t0 = Instant::now();
                    let result = if artifact.is_empty() {
                        Err(anyhow!(
                            "no artifact accepts {}",
                            env.req.triple()
                        ))
                    } else {
                        runtime
                            .gemm(
                                &artifact,
                                &GemmInput {
                                    m: env.req.m,
                                    n: env.req.n,
                                    k: env.req.k,
                                    a: &env.req.a,
                                    b: &env.req.b,
                                    c: &env.req.c,
                                    alpha: env.req.alpha,
                                    beta: env.req.beta,
                                },
                            )
                            .map(|o| o.out)
                    };
                    let service = t0.elapsed();
                    if result.is_ok() {
                        records.push(RequestRecord {
                            artifact: artifact.clone(),
                            queue,
                            service,
                            flops: env.req.triple().flops(),
                        });
                    }
                    let _ = env.reply.send(GemmResponse {
                        out: result,
                        artifact,
                        queue,
                        service,
                    });
                }
            }
            records
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(GemmServer {
                handle: Some(ServerHandle { tx }),
                worker: Some(worker),
                started: Instant::now(),
            }),
            Ok(Err(msg)) => {
                let _ = worker.join();
                Err(anyhow!("server startup failed: {msg}"))
            }
            Err(_) => Err(anyhow!("server thread died during startup")),
        }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.as_ref().expect("server running").clone()
    }

    /// Shut down and collect serving statistics (None if nothing served).
    pub fn shutdown(mut self) -> Option<ServeStats> {
        let wall = self.started.elapsed();
        // Drop our sender so the worker's recv() errors out once all
        // client handles are gone.
        self.handle = None;
        let records = self.worker.take()?.join().ok()?;
        if records.is_empty() {
            None
        } else {
            Some(ServeStats::from_records(&records, wall))
        }
    }
}
